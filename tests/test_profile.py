"""Profile (staircase) query subsystem: kernel, engine and serving
properties beyond the differential harness.

Satellite invariants of the one-pass profile path:

  * every staircase is monotone non-increasing as the constraint relaxes,
    on every engine/layout/kernel mode;
  * ``profile[:, w] == query(s, t, w)`` pointwise (the L-call loop the
    profile replaces);
  * ``s == t`` yields an all-zeros profile at EVERY padded cap — the PR 3
    cap-trim regression (the trailing self entry survives trimming)
    extended to the profile path;
  * a hypothesis round-trip `PackedLabelsBuilder` -> `PackedLabels` ->
    profile kernel on adversarial level distributions (all levels equal,
    one level empty, singleton rows), via `_hypo_shim`;
  * `WCSDServer` profile semantics: profile memo + single-level serving
    from a cached profile, in-flight piggyback, read-once delivery,
    directed-mode key separation, mixed scalar+profile flushes.

Parametrized cases share session-built indices (`built_indices` in
conftest) so the matrix adds cases, not index constructions.
"""
import numpy as np
import pytest
from _hypo_shim import given, settings, st  # hypothesis or fallback

from repro.core.graph import INF_DIST
from repro.core.query import DeviceQueryEngine, ShardedQueryEngine
from repro.core.serve import WCSDServer
from repro.core.wc_index import PackedLabelsBuilder, PackedWCIndex

SOCIAL = dict(family="scale_free", num_nodes=150, m=3, num_levels=4, seed=12)
ROAD = dict(family="road_grid", rows=9, cols=9, num_levels=3, seed=2)


def _queries(idx, n, seed):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, idx.num_nodes, n).astype(np.int32),
            rng.integers(0, idx.num_nodes, n).astype(np.int32))


# ------------------------------------------------------ engine properties
@pytest.mark.parametrize("layout,use_pallas", [
    ("padded", False), ("csr", False), ("csr", True)])
@pytest.mark.parametrize("cfg", [SOCIAL, ROAD], ids=["social", "road"])
def test_staircase_monotone_and_pointwise(built_indices, cfg, layout,
                                          use_pallas):
    _, idx = built_indices(**cfg)
    eng = DeviceQueryEngine(idx, layout=layout, use_pallas=use_pallas)
    s, t = _queries(idx, 200, seed=3)
    prof = np.asarray(eng.query_profile(s, t))
    assert prof.shape == (200, idx.num_levels + 1)
    # relaxing the constraint (smaller w) never lengthens the path
    assert np.all(prof[:, :-1] <= prof[:, 1:])
    # the top level is feasible only for s == t (self entries)
    assert np.array_equal(prof[:, -1] == 0, s == t)
    for w in range(idx.num_levels + 1):
        one = np.asarray(eng.query(s, t, np.full(200, w, np.int32)))
        np.testing.assert_array_equal(prof[:, w], one, err_msg=f"w={w}")


@pytest.mark.parametrize("cap", [1, 2, 3, None])
def test_self_profile_all_zeros_at_every_cap(built_indices, cap):
    """Extends the PR 3 cap-trim regression: trimming keeps the trailing
    self entry, so s == t profiles are all-zeros at EVERY level for any
    cap >= 1 — on the profile path, not just single-level queries."""
    _, idx = built_indices(**SOCIAL)
    eng = DeviceQueryEngine(idx, cap=cap, layout="padded")
    s = np.arange(idx.num_nodes, dtype=np.int32)
    prof = np.asarray(eng.query_profile(s, s))
    assert prof.shape == (idx.num_nodes, idx.num_levels + 1)
    assert np.all(prof == 0), cap


def test_self_profile_all_zeros_csr(built_indices):
    _, idx = built_indices(**ROAD)
    eng = DeviceQueryEngine(idx, layout="csr", use_pallas=True)
    s = np.arange(idx.num_nodes, dtype=np.int32)
    assert np.all(np.asarray(eng.query_profile(s, s)) == 0)


@pytest.mark.parametrize("layout", ["padded", "csr"])
@pytest.mark.parametrize("budget", [None, 1])
def test_sharded_profile_matches_device_engine(built_indices, layout,
                                               budget):
    """Both sharded placements (replicated / row-sharded labels with the
    fused multi-array row-gather) produce bit-identical staircases on a
    1-device mesh; the 8-virtual-device proof runs via dryrun --serve."""
    from repro.launch.mesh import make_serving_mesh
    _, idx = built_indices(**SOCIAL)
    eng = ShardedQueryEngine(idx, mesh=make_serving_mesh(), layout=layout,
                             device_budget_bytes=budget)
    assert eng.mode == ("replicated" if budget is None else "sharded_labels")
    s, t = _queries(idx, 150, seed=7)
    exp = np.asarray(DeviceQueryEngine(idx,
                                       layout=layout).query_profile(s, t))
    np.testing.assert_array_equal(np.asarray(eng.query_profile(s, t)), exp)


# --------------------------------------- builder round-trip (hypothesis)
def _adversarial_entries(rng, V, W, mode):
    """Flat (v, hub, dist, wlev) label entries honoring the builder
    contract (hub < v == rank(v); sorted by (v, hub, dist)) with an
    adversarial quality-level distribution."""
    v_l, h_l, d_l, w_l = [], [], [], []
    equal_lev = int(rng.integers(0, W))
    hole = int(rng.integers(0, W))
    for v in range(V):
        hubs = [h for h in range(v) if rng.random() < 0.7]
        if mode == "singleton" and hubs:      # at most one entry per vertex
            hubs = [hubs[int(rng.integers(len(hubs)))]]
        for h in hubs:
            k = 1 if mode == "singleton" else int(rng.integers(1, 4))
            dists = np.sort(rng.integers(1, 12, size=k))
            for d in dists:
                if mode == "equal":
                    lev = equal_lev                  # all levels equal
                elif mode == "hole":                 # one level empty
                    lev = int(rng.integers(0, W - 1))
                    lev += lev >= hole
                else:
                    lev = int(rng.integers(0, W))
                v_l.append(v), h_l.append(h)
                d_l.append(int(d)), w_l.append(lev)
    order = np.lexsort((d_l, h_l, v_l)) if v_l else np.zeros(0, np.int64)
    arr = lambda x: np.asarray(x, np.int32)[order]  # noqa: E731
    return arr(v_l), arr(h_l), arr(d_l), arr(w_l)


@given(st.integers(0, 100_000),
       st.sampled_from(["equal", "hole", "singleton", "mixed"]))
@settings(max_examples=16, deadline=None, derandomize=True)
def test_builder_roundtrip_profile_on_adversarial_levels(seed, mode):
    """PackedLabelsBuilder -> PackedLabels -> profile kernel round trip:
    the staircase from the freshly finalized store equals the host
    sort-merge (`PackedWCIndex.query_one`) at every level, on stores whose
    level distributions stress the bucket min-scan (all levels equal, one
    level missing entirely, singleton label rows — plus vertex 0, whose
    row is only its self entry)."""
    rng = np.random.default_rng(seed)
    V, W = 8, 4
    v, h, d, w = _adversarial_entries(rng, V, W, mode)
    builder = PackedLabelsBuilder(V)
    split = h < V // 2          # two rank-ascending batches
    for m in (split, ~split):
        builder.append_batch(v[m], h[m], d[m], w[m])
    store, _ = builder.finalize(rank=np.arange(V, dtype=np.int32),
                                num_levels=W)
    pidx = PackedWCIndex(order=np.arange(V, dtype=np.int32),
                         rank=np.arange(V, dtype=np.int32),
                         levels=np.arange(1, W + 1, dtype=np.float64),
                         labels=store)
    eng = DeviceQueryEngine(pidx, layout="csr", use_pallas=True)
    s, t = np.meshgrid(np.arange(V), np.arange(V), indexing="ij")
    s = s.ravel().astype(np.int32)
    t = t.ravel().astype(np.int32)
    prof = np.asarray(eng.query_profile(s, t))
    assert np.all(prof[:, :-1] <= prof[:, 1:])
    for i in range(len(s)):
        for lev in range(W + 1):
            exp = min(pidx.query_one(int(s[i]), int(t[i]), lev), INF_DIST)
            assert prof[i, lev] == exp, (mode, s[i], t[i], lev)


# ------------------------------------------------------- serving surface
def test_server_profile_matches_oracle(built_indices, serve_layout):
    _, idx = built_indices(**SOCIAL)
    srv = WCSDServer(idx, max_batch=64, layout=serve_layout)
    s, t = _queries(idx, 150, seed=9)
    got = srv.query_profile_many(s, t)
    exp = np.stack([idx.query_batch(s, t, np.full(150, w, np.int32))
                    for w in range(idx.num_levels + 1)], axis=1)
    np.testing.assert_array_equal(got, exp)
    assert srv.stats.profile_requests == 150
    assert len(srv.profile_results) == 0      # read-once delivery drained


def test_cached_profile_serves_every_single_level(built_indices,
                                                  serve_layout):
    """The memo interaction the profile exists for: once a pair's
    staircase is cached, ANY single-level submit of that pair is a memo
    hit — no device batch, answers read straight off the staircase."""
    _, idx = built_indices(**SOCIAL)
    srv = WCSDServer(idx, max_batch=32, layout=serve_layout)
    rid = srv.submit_profile(3, 9)
    srv.flush()
    prof = srv.profile_result(rid)
    batches = srv.stats.batches
    for w in range(idx.num_levels + 1):
        r = srv.submit(3, 9, w)
        assert srv.result(r) == prof[w], w
        r = srv.submit(9, 3, w)            # symmetric orientation too
        assert srv.result(r) == prof[w], w
    assert srv.stats.batches == batches    # zero extra device work
    assert srv.stats.memo_hits >= 2 * (idx.num_levels + 1)
    # …and a repeated profile submit is itself a memo hit
    r2 = srv.submit_profile(9, 3)
    np.testing.assert_array_equal(srv.profile_result(r2), prof)
    assert srv.stats.batches == batches


def test_profile_piggybacks_on_inflight_batch(built_indices, serve_layout):
    _, idx = built_indices(**SOCIAL)
    srv = WCSDServer(idx, max_batch=2, layout=serve_layout)
    r1 = srv.submit_profile(3, 9)
    srv.submit(5, 11, 0)             # hits max_batch -> async dispatch
    assert srv._inflight_prof is not None and srv.stats.batches == 1
    r2 = srv.submit_profile(3, 9)    # duplicate of in-flight profile
    assert srv.stats.memo_hits == 1
    assert srv.pending_profiles == []
    p2 = srv.profile_result(r2)      # drains the in-flight slot
    np.testing.assert_array_equal(p2, srv.profile_result(r1))
    assert srv.stats.batches == 1    # no second device batch


def test_profile_memo_is_directed_gated(built_indices):
    """undirected=False must keep (s, t) and (t, s) profiles apart, same
    as the single-level memo (asymmetric stub engine simulates a directed
    index)."""
    _, idx = built_indices(**SOCIAL)
    W1 = idx.num_levels + 1
    srv = WCSDServer(idx, max_batch=1024, undirected=False)
    srv.engine.query_profile_async = None   # force the blocking fallback

    def fake_profile(s, t):
        return (np.asarray(s)[:, None] * 1000 + np.asarray(t)[:, None]
                + np.zeros(W1, np.int32)[None, :])
    srv.engine.query_profile = fake_profile
    a = srv.submit_profile(2, 7)
    srv.flush()
    b = srv.submit_profile(7, 2)             # NOT a memo hit when directed
    assert srv.stats.memo_hits == 0
    srv.flush()
    assert srv.profile_result(a)[0] == 2007
    assert srv.profile_result(b)[0] == 7002
    c = srv.submit_profile(2, 7)             # exact repeat IS memoized
    assert srv.stats.memo_hits == 1
    assert srv.profile_result(c)[0] == 2007


def test_mixed_scalar_and_profile_flush(built_indices, serve_layout):
    """One flush carries both a scalar and a profile section; both drain
    into their result maps and agree with each other pointwise."""
    _, idx = built_indices(**SOCIAL)
    srv = WCSDServer(idx, max_batch=1024, layout=serve_layout)
    rs = srv.submit(4, 17, 1)
    rp = srv.submit_profile(4, 17)
    rs2 = srv.submit(8, 23, 0)
    assert srv.stats.batches == 0
    srv.flush()
    assert srv.stats.batches == 1            # ONE in-flight slot for both
    prof = srv.profile_result(rp)
    assert srv.result(rs) == prof[1]
    assert srv.result(rs2) is not None
    assert len(srv.results) == 0 and len(srv.profile_results) == 0


def test_profile_results_do_not_grow_across_epochs(built_indices,
                                                   serve_layout):
    _, idx = built_indices(**SOCIAL)
    srv = WCSDServer(idx, max_batch=32, layout=serve_layout)
    s, t = _queries(idx, 100, seed=1)
    for epoch in range(3):
        srv.query_profile_many(s, t)
        assert len(srv.profile_results) == 0, epoch
    assert srv.stats.profile_requests == 300


def test_delivered_profile_is_a_private_copy(built_indices, serve_layout):
    """Mutating a delivered staircase must not corrupt the memo's copy —
    on the primary drain path AND the in-flight piggyback path (regression:
    piggybacked deliveries used to alias the memo's row view)."""
    _, idx = built_indices(**SOCIAL)
    srv = WCSDServer(idx, max_batch=32, layout=serve_layout)
    r1 = srv.submit_profile(3, 9)
    srv.flush()
    first = srv.profile_result(r1)
    first[:] = -42
    r2 = srv.submit_profile(3, 9)            # memo hit, fresh copy
    again = srv.profile_result(r2)
    assert np.all(again >= 0) and not np.array_equal(again, first)
    # piggybacked delivery: duplicate submitted while in flight
    srv2 = WCSDServer(idx, max_batch=1, layout=serve_layout)
    ra = srv2.submit_profile(5, 11)          # auto-flush: in flight
    rb = srv2.submit_profile(5, 11)          # piggybacks on in-flight slot
    pb = srv2.profile_result(rb)
    pb[:] = -42
    rc = srv2.submit_profile(5, 11)          # memo hit must be unpoisoned
    assert np.all(srv2.profile_result(rc) >= 0)
    w = idx.num_levels - 1
    assert srv2.result(srv2.submit(5, 11, w)) >= 0
    assert np.all(srv2.profile_result(ra) >= 0)


def test_empty_profile_batch_paths(built_indices, serve_layout):
    _, idx = built_indices(**SOCIAL)
    srv = WCSDServer(idx, max_batch=8, layout=serve_layout)
    out = srv.query_profile_many(np.array([], np.int32),
                                 np.array([], np.int32))
    assert out.shape == (0, idx.num_levels + 1)
    assert srv.stats.batches == 0

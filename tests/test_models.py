"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, shape + finiteness assertions (full configs only via the dry-run)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_arch
from repro.data.graphs import synthetic_molecules, synthetic_node_task
from repro.data.lm import TokenStream
from repro.data.recsys import CTRStream
from repro.core.generators import erdos_renyi
from repro.models import gnn, nequip, transformer as T, xdeepfm
from repro.train import optim as O
from repro.train.loop import make_train_step

LM_ARCHS = ["qwen2-moe-a2.7b", "dbrx-132b", "llama3-8b", "codeqwen1.5-7b",
            "qwen2.5-14b"]
GNN_ARCHS = ["gin-tu", "pna", "gatedgcn"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    cfg = get_arch(arch).smoke_config()
    params = T.init_params(cfg, jax.random.key(0))
    stream = TokenStream(cfg.vocab, seq_len=32, batch=2, seed=0)
    batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
    ocfg = O.OptimizerConfig(warmup_steps=1, total_steps=10)
    opt = O.init_opt_state(ocfg, params)
    step = jax.jit(make_train_step(lambda p, b: T.loss_fn(p, cfg, b), ocfg))
    p2, o2, m = step(params, opt, batch)
    l0 = float(m["loss"])
    assert np.isfinite(l0)
    for _ in range(3):
        p2, o2, m = step(p2, o2, {k: jnp.asarray(v) for k, v in
                                  stream.next_batch().items()})
    assert np.isfinite(float(m["loss"]))
    # shapes preserved
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert a.shape == b.shape


@pytest.mark.parametrize("arch", LM_ARCHS[:2])
def test_lm_smoke_prefill_decode(arch):
    cfg = get_arch(arch).smoke_config()
    params = T.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    nxt, cache = T.prefill_step(params, cfg, toks)
    assert nxt.shape == (2,)
    cache = {k: jnp.pad(v, ((0, 0), (0, 0), (0, 16), (0, 0), (0, 0)))
             for k, v in cache.items()}
    for i in range(3):
        nxt, logits, cache = T.decode_step(params, cfg, cache, nxt,
                                           jnp.int32(16 + i))
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_lm_loss_decreases():
    cfg = get_arch("llama3-8b").smoke_config()
    params = T.init_params(cfg, jax.random.key(0))
    ocfg = O.OptimizerConfig(lr=3e-3, warmup_steps=2, total_steps=60)
    opt = O.init_opt_state(ocfg, params)
    step = jax.jit(make_train_step(lambda p, b: T.loss_fn(p, cfg, b), ocfg))
    rng = np.random.default_rng(0)
    fixed = rng.integers(0, cfg.vocab, (4, 32)).astype(np.int32)
    batch = {"tokens": jnp.asarray(fixed), "labels": jnp.asarray(fixed)}
    losses = []
    for _ in range(25):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[:3] + losses[-3:]


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke(arch):
    cfg = get_arch(arch).smoke_config()
    g = erdos_renyi(60, 4.0, num_levels=3, seed=1)
    batch = {k: jnp.asarray(v) for k, v in
             synthetic_node_task(g, cfg.d_feat, cfg.n_classes).items()}
    params = gnn.init_params(cfg, jax.random.key(0))
    ocfg = O.OptimizerConfig(lr=1e-2, warmup_steps=1, total_steps=30)
    opt = O.init_opt_state(ocfg, params)
    step = jax.jit(make_train_step(lambda p, b: gnn.loss_fn(p, cfg, b),
                                   ocfg))
    losses = []
    for _ in range(10):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]  # overfits a fixed graph


def test_nequip_smoke_energy_forces():
    cfg = get_arch("nequip").smoke_config()
    batch = {k: jnp.asarray(v) for k, v in
             synthetic_molecules(8, 10, 20, cfg.d_feat, seed=2).items()}
    params = nequip.init_params(cfg, jax.random.key(0))
    e = nequip.energy_fn(params, cfg, batch, n_graphs=8)
    assert e.shape == (8,)
    loss = nequip.loss_fn(params, cfg, batch, n_graphs=8)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: nequip.loss_fn(p, cfg, batch, n_graphs=8))(params)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))


def test_nequip_rotation_invariance():
    from scipy.spatial.transform import Rotation
    cfg = get_arch("nequip").smoke_config()
    batch = {k: jnp.asarray(v) for k, v in
             synthetic_molecules(4, 8, 16, cfg.d_feat, seed=3).items()}
    params = nequip.init_params(cfg, jax.random.key(0))
    e1 = nequip.energy_fn(params, cfg, batch, n_graphs=4)
    R = Rotation.random(random_state=7).as_matrix().astype(np.float32)
    b2 = dict(batch)
    b2["pos"] = batch["pos"] @ R.T
    e2 = nequip.energy_fn(params, cfg, b2, n_graphs=4)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-4,
                               atol=1e-5)


def test_xdeepfm_smoke_and_learning():
    cfg = get_arch("xdeepfm").smoke_config()
    stream = CTRStream(cfg.field_vocabs, cfg.field_offsets, batch=256, seed=0)
    params = xdeepfm.init_params(cfg, jax.random.key(0))
    ocfg = O.OptimizerConfig(lr=1e-2, warmup_steps=1, total_steps=50)
    opt = O.init_opt_state(ocfg, params)
    step = jax.jit(make_train_step(
        lambda p, b: xdeepfm.loss_fn(p, cfg, b), ocfg))
    losses = []
    for _ in range(20):
        batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    # retrieval path
    cand = jnp.asarray(np.random.default_rng(0).standard_normal(
        (1000, cfg.embed_dim)).astype(np.float32))
    qids = jnp.asarray(stream.next_batch()["ids"][:1])
    scores, (tv, ti) = xdeepfm.retrieval_scores(params, cfg, qids, cand)
    assert scores.shape == (1000,) and tv.shape == (100,)


def test_embedding_bag_modes():
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.standard_normal((30, 6)).astype(np.float32))
    ids = jnp.asarray([3, 4, 5, 9, 9])
    bags = jnp.asarray([0, 0, 1, 1, 1])
    s = xdeepfm.embedding_bag(table, ids, bags, 2, mode="sum")
    m = xdeepfm.embedding_bag(table, ids, bags, 2, mode="mean")
    tn = np.asarray(table)
    np.testing.assert_allclose(np.asarray(s)[0], tn[[3, 4]].sum(0),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(m)[1], tn[[5, 9, 9]].mean(0),
                               rtol=1e-6)


def test_all_archs_have_cells():
    for arch in ARCHS:
        mod = get_arch(arch)
        assert len(mod.SHAPES) == 4
        cell = mod.make_cell(mod.SHAPES[0])
        assert cell.fn is not None and len(cell.args) >= 2

"""Training substrate: optimizer, accumulation, compression, checkpointing,
fault tolerance, serving, data pipelines (incl. the fanout neighbor
sampler)."""
import os
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import CheckpointManager
from repro.checkpoint.fault import FaultTolerantRunner, Heartbeat
from repro.core.generators import random_queries, scale_free
from repro.core.serve import WCSDServer
from repro.core.wc_index import build_wc_index
from repro.core.ref import wcsd_bfs
from repro.data.graphs import NeighborSampler, distance_encoding, pad_block
from repro.data.lm import TokenStream
from repro.train import optim as O
from repro.train.grad_compress import (compress_decompress, dequantize_int8,
                                       quantize_int8)
from repro.train.loop import StepTimeMonitor, Trainer, make_train_step


def _toy():
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.standard_normal((6, 1), ).astype(
        np.float32)), "b": jnp.zeros((1,))}

    def loss_fn(p, b):
        return jnp.mean((b["x"] @ p["w"] + p["b"] - b["y"]) ** 2)

    def batch(s):
        r = np.random.default_rng(s)
        x = r.standard_normal((32, 6)).astype(np.float32)
        y = x @ np.arange(1.0, 7.0, dtype=np.float32)[:, None]
        return {"x": jnp.asarray(x), "y": jnp.asarray(y)}

    return params, loss_fn, batch


# ---------------------------------------------------------------- optimizer
def test_adamw_converges():
    params, loss_fn, batch = _toy()
    ocfg = O.OptimizerConfig(lr=0.1, warmup_steps=5, total_steps=400,
                             weight_decay=0.0, clip_norm=50.0)
    opt = O.init_opt_state(ocfg, params)
    step = jax.jit(make_train_step(loss_fn, ocfg))
    for i in range(200):
        params, opt, m = step(params, opt, batch(i))
    assert float(m["loss"]) < 0.05


def test_sgd_and_schedule():
    params, loss_fn, batch = _toy()
    ocfg = O.OptimizerConfig(name="sgd", lr=0.02, warmup_steps=5,
                             total_steps=100, weight_decay=0.0,
                             clip_norm=50.0)
    opt = O.init_opt_state(ocfg, params)
    step = jax.jit(make_train_step(loss_fn, ocfg))
    l0 = None
    for i in range(50):
        params, opt, m = step(params, opt, batch(i))
        l0 = l0 or float(m["loss"])
    assert float(m["loss"]) < l0
    # warmup-cosine boundary behavior
    lr0 = O.warmup_cosine(ocfg, jnp.int32(0))
    lr_w = O.warmup_cosine(ocfg, jnp.int32(5))
    lr_end = O.warmup_cosine(ocfg, jnp.int32(100))
    assert float(lr0) == 0.0 and np.isclose(float(lr_w), ocfg.lr, rtol=1e-5)
    assert np.isclose(float(lr_end), ocfg.lr * ocfg.min_lr_ratio, rtol=1e-5)


def test_grad_accumulation_equivalence():
    params, loss_fn, batch = _toy()
    ocfg = O.OptimizerConfig(lr=0.01)
    opt = O.init_opt_state(ocfg, params)
    b = batch(0)
    s1 = jax.jit(make_train_step(loss_fn, ocfg, accum_steps=1))
    s4 = jax.jit(make_train_step(loss_fn, ocfg, accum_steps=4))
    p1, _, m1 = s1(params, opt, b)
    p4, _, m4 = s4(params, opt, b)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p4["w"]),
                               atol=1e-5)


# -------------------------------------------------------------- compression
def test_int8_quantization_bounds():
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.standard_normal(4096).astype(np.float32) * 3)
    q, s = quantize_int8(g)
    gh = dequantize_int8(q, s)
    assert float(jnp.abs(g - gh).max()) <= float(s) / 2 + 1e-6


def test_error_feedback_unbiased_over_steps():
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.standard_normal(512).astype(np.float32))
    res = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(50):
        gh, res = compress_decompress(g, res)
        acc = acc + gh
    # with error feedback the accumulated compressed signal tracks 50*g
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g),
                               atol=float(jnp.abs(g).max()) * 0.01)


# ------------------------------------------------------------- checkpointing
def test_checkpoint_roundtrip_and_gc():
    params, loss_fn, batch = _toy()
    ocfg = O.OptimizerConfig()
    opt = O.init_opt_state(ocfg, params)
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=2)
        for s in [1, 2, 3, 4]:
            cm.save(s, {"params": params, "opt_state": opt})
        assert cm.latest_step() == 4
        # gc kept only last 2
        steps = sorted(os.listdir(d))
        assert len(steps) == 2
        state, step = cm.restore({"params": params, "opt_state": opt})
        assert step == 4
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        man = cm.manifest(4)
        assert "leaves" in man and man["step"] == 4


def test_fault_tolerant_restart_replays_batches():
    params, loss_fn, batch = _toy()
    ocfg = O.OptimizerConfig(lr=0.02)
    opt = O.init_opt_state(ocfg, params)
    step = jax.jit(make_train_step(loss_fn, ocfg))
    with tempfile.TemporaryDirectory() as d:
        runner = FaultTolerantRunner(
            step, params, opt, CheckpointManager(d), ckpt_every=4,
            failure_schedule={6: RuntimeError("chip down"),
                              9: RuntimeError("again")})
        log = runner.run(None, max_steps=15, batch_for_step=batch)
        events = [l["event"] for l in log]
        assert events.count("failure") == 2
        assert runner.step == 15
        # deterministic replay: the same step ran after restore
        steps_run = [l["step"] for l in log if l["event"] == "step"]
        assert sorted(set(steps_run)) == list(range(15))


def test_heartbeat_and_elastic_remesh():
    params, loss_fn, batch = _toy()
    ocfg = O.OptimizerConfig()
    opt = O.init_opt_state(ocfg, params)
    step = jax.jit(make_train_step(loss_fn, ocfg))
    hb = Heartbeat(n_workers=4, timeout_s=0.0)  # everyone instantly dead
    hb.beat(0)
    remeshed = []

    def remesh(n_alive):
        remeshed.append(n_alive)
        return step, params, opt

    with tempfile.TemporaryDirectory() as d:
        runner = FaultTolerantRunner(step, params, opt, CheckpointManager(d),
                                     heartbeat=hb, remesh_fn=remesh)
        runner.run(None, max_steps=2, batch_for_step=batch)
    assert remeshed and remeshed[0] < 4


def test_straggler_monitor():
    m = StepTimeMonitor(alpha=0.3, z=2.0)
    flags = [m.observe(0.1) for _ in range(10)]
    assert not any(flags)
    assert m.observe(10.0) is True
    assert m.stragglers == 1


# ------------------------------------------------------------------ serving
def test_wcsd_server_batching_and_memo():
    g = scale_free(120, 3, num_levels=4, seed=31)
    idx = build_wc_index(g)
    srv = WCSDServer(idx, max_batch=32)
    s, t, wl = random_queries(g, 100, seed=8)
    out = srv.query_many(s, t, wl)
    exp = idx.query_batch(s, t, wl)
    np.testing.assert_array_equal(out, exp)
    assert srv.stats.batches >= 3
    # repeated queries hit the memo
    srv.query_many(s[:10], t[:10], wl[:10])
    assert srv.stats.memo_hits >= 10


# --------------------------------------------------------------------- data
def test_token_stream_deterministic_cursor():
    s1 = TokenStream(1000, 16, 4, seed=1)
    b1 = s1.next_batch()
    b2 = s1.next_batch()
    s2 = TokenStream(1000, 16, 4, seed=1)
    s2.set_cursor(1)
    np.testing.assert_array_equal(s2.next_batch()["tokens"], b2["tokens"])


def test_neighbor_sampler_block_structure():
    g = scale_free(500, 4, num_levels=3, seed=33)
    samp = NeighborSampler(g, seed=0)
    seeds = np.arange(32, dtype=np.int32)
    block = samp.sample(seeds, fanouts=[5, 3])
    # seeds occupy the first slots
    np.testing.assert_array_equal(block["nodes"][:32], seeds)
    # every edge endpoint is within the node set
    assert block["edges_src"].max() < len(block["nodes"])
    assert block["edges_dst"].max() < len(block["nodes"])
    # every sampled edge exists in the graph
    nodes = block["nodes"]
    for s_, d_ in list(zip(block["edges_src"][:50], block["edges_dst"][:50])):
        u, v = int(nodes[s_]), int(nodes[d_])
        assert v in g.neighbors(u)[0] or u in g.neighbors(v)[0]
    padded = pad_block(block, 4096, 8192)
    assert len(padded["nodes"]) == 4096
    assert len(padded["edges_src"]) == 8192


def test_distance_encoding_features():
    g = scale_free(100, 3, num_levels=3, seed=35)
    idx = build_wc_index(g)
    nodes = np.arange(20)
    lms = np.array([0, 50])
    feats = distance_encoding(idx, nodes, lms, w_levels=[0, 2])
    assert feats.shape == (20, 4)
    # spot check one value against the oracle
    d = wcsd_bfs(g, 5, 0, 0)
    assert feats[5, 0] == min(d, 32)

import numpy as np
import pytest

from repro.core.baselines import (LCRAdapt, NaiveIndex, WBFS, cbfs_query,
                                  dijkstra_query)
from repro.core.generators import random_queries, road_grid, scale_free
from repro.core.ref import wcsd_bfs
from repro.core.wc_index import build_wc_index


@pytest.fixture(scope="module")
def setup():
    g = scale_free(120, 3, num_levels=4, seed=21)
    s, t, wl = random_queries(g, 80, seed=5)
    exp = np.array([wcsd_bfs(g, int(a), int(b), int(w))
                    for a, b, w in zip(s, t, wl)])
    return g, s, t, wl, exp


def test_cbfs(setup):
    g, s, t, wl, exp = setup
    got = [cbfs_query(g, int(a), int(b), int(w)) for a, b, w in zip(s, t, wl)]
    assert np.array_equal(got, exp)


def test_wbfs(setup):
    g, s, t, wl, exp = setup
    wb = WBFS.build(g)
    got = [wb.query(int(a), int(b), int(w)) for a, b, w in zip(s, t, wl)]
    assert np.array_equal(got, exp)
    assert wb.memory_bytes() > g.memory_bytes()  # |w| partitions cost space


def test_dijkstra_unweighted(setup):
    g, s, t, wl, exp = setup
    got = [dijkstra_query(g, int(a), int(b), int(w))
           for a, b, w in zip(s[:40], t[:40], wl[:40])]
    assert np.array_equal(got, exp[:40])


def test_dijkstra_weighted_extension():
    g = road_grid(6, 6, num_levels=3, seed=2)
    rng = np.random.default_rng(0)
    edge_len = rng.integers(1, 5, size=len(g.nbr)).astype(np.float64)
    # symmetrize lengths
    for u in range(g.num_nodes):
        b, e = g.indptr[u], g.indptr[u + 1]
        for i in range(b, e):
            v = g.nbr[i]
            vb, ve = g.indptr[v], g.indptr[v + 1]
            j = vb + list(g.nbr[vb:ve]).index(u)
            edge_len[j] = edge_len[i]
    d = dijkstra_query(g, 0, 35, 0, edge_len=edge_len)
    assert d >= wcsd_bfs(g, 0, 35, 0)  # weighted >= hop count w/ min len 1


def test_naive_index(setup):
    g, s, t, wl, exp = setup
    nv = NaiveIndex.build(g)
    assert np.array_equal(nv.query_batch(s, t, wl), exp)
    # paper's point: |w| separate indices are bigger than one WC-INDEX
    wc = build_wc_index(g)
    assert nv.memory_bytes() > wc.memory_bytes()


def test_lcr_adapt(setup):
    g, s, t, wl, exp = setup
    lcr = LCRAdapt.build(g)
    got = [lcr.query(int(a), int(b), int(w))
           for a, b, w in zip(s[:40], t[:40], wl[:40])]
    assert np.array_equal(got, exp[:40])

"""Hypothesis pass-through with a deterministic fallback.

The property tests prefer real hypothesis (declared in pyproject's test
extra; CI installs it). When it is absent — e.g. a bare container with only
jax/numpy/pytest — this shim stands in so the test modules still *collect
and run*: each `@given` property is executed `max_examples` times (capped)
with values drawn from a seeded numpy generator instead of being shrunk by
hypothesis. Weaker fuzzing, but no skipped coverage and no collection
errors.

Only the strategy surface this repo uses is emulated: ``st.integers``,
``st.sampled_from``, ``st.tuples``, ``st.lists``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect

    import numpy as np

    _MAX_EXAMPLES_CAP = 25  # keep the fallback fuzz pass CI-sized

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    class st:  # noqa: N801 - mimic `hypothesis.strategies` module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(len(elements)))])

        @staticmethod
        def tuples(*strategies):
            return _Strategy(
                lambda rng: tuple(s.sample(rng) for s in strategies))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(lambda rng: [
                elements.sample(rng)
                for _ in range(int(rng.integers(min_size, max_size + 1)))])

    def settings(max_examples: int = 20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            # Like real hypothesis, positional strategies bind to the
            # RIGHTMOST parameters; leading ones (pytest.mark.parametrize
            # arguments, fixtures) stay visible in the signature and arrive
            # from pytest as keywords.
            params = list(inspect.signature(fn).parameters.values())
            drawn_names = [p.name for p in params[len(params)
                                                  - len(strategies):]]

            @functools.wraps(fn)
            def run(*args, **kwargs):
                n = getattr(run, "_max_examples",
                            getattr(fn, "_max_examples", 20))
                rng = np.random.default_rng(0)
                for _ in range(min(n, _MAX_EXAMPLES_CAP)):
                    drawn = {name: s.sample(rng)
                             for name, s in zip(drawn_names, strategies)}
                    fn(*args, **kwargs, **drawn)
            # pytest must not mistake the drawn parameters for fixtures
            del run.__wrapped__
            run.__signature__ = inspect.Signature(
                params[:len(params) - len(strategies)])
            return run
        return deco

import os
import sys

# src layout import without install; tests dir for the _hypo_shim helper
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import os
import sys

import pytest

# src layout import without install; tests dir for the _hypo_shim helper
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))


@pytest.fixture(scope="session")
def built_indices():
    """Session-cached index construction for parametrized serving/profile
    tests: every case that needs "a built index over graph X" shares one
    construction per distinct (generator, kwargs) key instead of paying
    the build per parametrization — the profile suite runs its whole
    layout x kernel matrix against two builds, not a dozen.

    The cache keys on the graph VERSION as well as the generator kwargs:
    a dynamic test that mutates a cached graph (`mutate_edges` bumps
    ``version``) gets a fresh (graph, index) pair instead of poisoning the
    static suite's fixture — and the static suite never sees an index that
    was built over a mutated graph (regression-locked in
    tests/test_dynamic.py)."""
    cache = {}

    def get(family: str, **kwargs):
        from repro.core import generators
        from repro.core.wc_index import build_wc_index
        key = (family, tuple(sorted(kwargs.items())))
        if key in cache:
            g, idx, built_version = cache[key]
            if getattr(g, "version", 0) == built_version:
                return g, idx
        g = getattr(generators, family)(**kwargs)
        idx = build_wc_index(g, ordering="degree")
        cache[key] = (g, idx, getattr(g, "version", 0))
        return g, idx

    return get


@pytest.fixture(scope="session")
def serve_layout():
    """Label-store layout for layout-agnostic serving tests.

    Defaults to "padded"; the CI matrix exports REPRO_LABEL_LAYOUT=csr to
    run the same tests against the CSR-packed store + segmented query path.
    Tests that assert layout-specific behavior (e.g. flush padding) pin
    their layout explicitly instead of using this fixture.
    """
    layout = os.environ.get("REPRO_LABEL_LAYOUT", "padded")
    assert layout in ("padded", "csr"), layout
    return layout

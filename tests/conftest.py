import os
import sys

import pytest

# src layout import without install; tests dir for the _hypo_shim helper
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))


@pytest.fixture(scope="session")
def serve_layout():
    """Label-store layout for layout-agnostic serving tests.

    Defaults to "padded"; the CI matrix exports REPRO_LABEL_LAYOUT=csr to
    run the same tests against the CSR-packed store + segmented query path.
    Tests that assert layout-specific behavior (e.g. flush padding) pin
    their layout explicitly instead of using this fixture.
    """
    layout = os.environ.get("REPRO_LABEL_LAYOUT", "padded")
    assert layout in ("padded", "csr"), layout
    return layout

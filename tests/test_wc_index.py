"""The paper's core claims, asserted: correctness vs the BFS oracle
(soundness+completeness), Thm. 3 monotonicity, minimality, ordering
behavior — with hypothesis fuzzing over random graphs/qualities/queries."""
import numpy as np
import pytest
from _hypo_shim import given, settings, st  # hypothesis or fallback

from repro.core.graph import Graph, INF_DIST
from repro.core.generators import erdos_renyi, road_grid, scale_free, random_queries
from repro.core.ref import wcsd_bfs, pareto_dists
from repro.core.wc_index import build_wc_index
from repro.core.wc_index_batched import build_wc_index_batched, clean_index
from repro.core.dominance import pareto_filter, pareto_filter_grouped


def _random_graph(n, avg_deg, levels, seed):
    return erdos_renyi(n, avg_deg, num_levels=levels, seed=seed)


# ------------------------------------------------------------- correctness
@pytest.mark.parametrize("ordering", ["degree", "treedec", "hybrid"])
def test_query_matches_oracle(ordering):
    g = scale_free(200, 3, num_levels=4, seed=5)
    idx = build_wc_index(g, ordering=ordering)
    s, t, wl = random_queries(g, 300, seed=1)
    exp = np.array([wcsd_bfs(g, int(a), int(b), int(w))
                    for a, b, w in zip(s, t, wl)])
    got = idx.query_batch(s, t, wl)
    assert np.array_equal(got, exp)
    for i in range(0, 50):
        assert idx.query_one(int(s[i]), int(t[i]), int(wl[i])) == exp[i]


@given(st.integers(8, 80), st.integers(1, 5), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_oracle_equivalence_fuzz(n, levels, seed):
    g = _random_graph(n, 3.5, levels, seed)
    idx = build_wc_index(g)
    s, t, wl = random_queries(g, 60, seed=seed + 1)
    exp = np.array([wcsd_bfs(g, int(a), int(b), int(w))
                    for a, b, w in zip(s, t, wl)])
    assert np.array_equal(idx.query_batch(s, t, wl), exp)


# ---------------------------------------------------------- cap trimming
def test_cap_trim_keeps_self_entry():
    """Regression: trimming to ``cap`` columns must retain each row's
    trailing self entry (rank[v], 0, inf) — dropping it answered every
    s == t (and self-hub meet) query wrongly."""
    g = scale_free(150, 3, num_levels=4, seed=2)
    idx = build_wc_index(g)
    assert int(idx.count.max()) > 2  # the trim is actually exercised
    for cap in (1, 2, 3, int(idx.count.max())):
        hub, dist, wlev, count = idx.padded_device_arrays(cap)
        assert count.max() <= cap
        last = np.maximum(count - 1, 0)
        v = np.arange(idx.num_nodes)
        assert np.array_equal(hub[v, last], idx.rank), cap
        assert np.all(dist[v, last] == 0), cap
        assert np.all(wlev[v, last] == idx.num_levels), cap
        # rows stay hub-sorted (non-decreasing: one hub spans several
        # quality tiers) and the self entry's rank exceeds all kept hubs
        for row, c in zip(hub, count):
            kept = row[:c]
            assert np.all(np.diff(kept) >= 0), (cap, kept)
            if c > 1:
                assert kept[-1] > kept[-2], (cap, kept)


@pytest.mark.parametrize("cap", [1, 2, 4])
def test_trimmed_engine_answers_self_queries(cap):
    """Acceptance: DeviceQueryEngine(idx, cap=k) answers every s == t query
    with 0 for all k >= 1."""
    from repro.core.query import DeviceQueryEngine

    g = scale_free(120, 3, num_levels=4, seed=9)
    idx = build_wc_index(g)
    eng = DeviceQueryEngine(idx, cap=cap)
    v = np.arange(g.num_nodes, dtype=np.int32)
    for wl in (0, idx.num_levels):  # any level: self entries are inf-quality
        got = np.asarray(eng.query(v, v, np.full(len(v), wl, np.int32)))
        assert np.all(got == 0), (cap, wl)


def test_trimmed_engine_keeps_central_hubs():
    """A trimmed store keeps the lowest-rank (most central) hubs plus the
    self entry, so s != t pairs meeting through a top hub stay answerable."""
    from repro.core.query import DeviceQueryEngine

    g = scale_free(120, 3, num_levels=3, seed=4)
    idx = build_wc_index(g)
    cap = max(2, int(idx.count.max()) // 2)
    eng = DeviceQueryEngine(idx, cap=cap)
    s, t, wl = random_queries(g, 200, seed=8)
    got = np.asarray(eng.query(s, t, wl))
    exp = idx.query_batch(s, t, wl)
    # trimming may only LOSE meets (overestimate), never invent shorter ones
    assert np.all(got >= exp)
    # and on this graph the top-hub meets survive: most answers unchanged
    assert (got == exp).mean() > 0.5


def test_unreachable_and_identity():
    # two disconnected components
    g = Graph.from_edges(6, np.array([0, 1, 3, 4]), np.array([1, 2, 4, 5]),
                         np.array([1.0, 2.0, 1.0, 2.0]))
    idx = build_wc_index(g)
    assert idx.query_one(0, 5, 0) == INF_DIST
    assert idx.query_one(0, 0, 0) == 0
    # level above any edge quality -> INF
    assert idx.query_one(0, 1, idx.num_levels) == INF_DIST


# ------------------------------------------------------------ Thm 3 / minimal
def test_theorem3_monotonicity():
    """Within a (vertex, hub) group both dist and wlev strictly increase."""
    g = road_grid(10, 10, num_levels=5, seed=3)
    idx = build_wc_index(g)
    for v in range(g.num_nodes):
        c = int(idx.count[v])
        h, d, w = (idx.hub_rank[v, :c], idx.dist[v, :c], idx.wlev[v, :c])
        assert np.all(np.diff(h) >= 0), "labels must be hub-sorted"
        for hub in np.unique(h):
            m = h == hub
            assert np.all(np.diff(d[m]) > 0)
            assert np.all(np.diff(w[m]) > 0)


def test_soundness_entries_are_real_paths():
    """Every index entry (hub, d, w) corresponds to an actual w-path of
    exactly that constrained distance (soundness, via the oracle)."""
    g = scale_free(80, 3, num_levels=4, seed=9)
    idx = build_wc_index(g)
    for v in range(0, g.num_nodes, 7):
        c = int(idx.count[v])
        for i in range(c):
            hub = int(idx.order[idx.hub_rank[v, i]])
            d, wl = int(idx.dist[v, i]), int(idx.wlev[v, i])
            if hub == v:
                assert d == 0
                continue
            real = wcsd_bfs(g, v, hub, min(wl, g.num_levels - 1))
            # d is the w-constrained distance at quality level wl
            assert real <= d
            # and a path of quality >= wl with length d exists:
            # oracle at level wl must be == d (completeness of entry)
            assert real == d


def test_minimality_no_dominated_entries():
    g = erdos_renyi(100, 4.0, num_levels=4, seed=11)
    idx = build_wc_index(g)
    total = 0
    for v in range(g.num_nodes):
        c = int(idx.count[v])
        h = idx.hub_rank[v, :c]
        keep = pareto_filter_grouped(h.astype(np.int64),
                                     idx.dist[v, :c].astype(np.int64),
                                     idx.wlev[v, :c].astype(np.int64))
        total += c
        assert keep.all(), f"dominated label entry at vertex {v}"


def test_completeness_against_pareto_oracle():
    """Every Pareto-optimal (distance, quality) pair is answerable."""
    g = scale_free(60, 2, num_levels=5, seed=13)
    idx = build_wc_index(g)
    s = 0
    D = pareto_dists(g, s)   # [V, W] oracle distances per level
    for t in range(1, g.num_nodes, 5):
        for l in range(g.num_levels):
            assert idx.query_one(s, t, l) == D[t, l]


# ----------------------------------------------------------- batched builder
@given(st.integers(20, 70), st.integers(2, 4), st.integers(0, 500),
       st.sampled_from([4, 16, 64]))
@settings(max_examples=10, deadline=None)
def test_batched_builder_fuzz(n, levels, seed, batch):
    g = _random_graph(n, 3.0, levels, seed)
    idx, stats = build_wc_index_batched(g, batch_size=batch)
    s, t, wl = random_queries(g, 50, seed=seed + 2)
    exp = np.array([wcsd_bfs(g, int(a), int(b), int(w))
                    for a, b, w in zip(s, t, wl)])
    assert np.array_equal(idx.query_batch(s, t, wl), exp)


def test_cleaning_restores_sequential_minimal_size():
    g = scale_free(150, 3, num_levels=4, seed=17)
    seq = build_wc_index(g)
    bat, _ = build_wc_index_batched(g, batch_size=32)
    cleaned, removed = clean_index(bat)
    assert bat.size_entries() >= seq.size_entries()
    assert cleaned.size_entries() == seq.size_entries()
    s, t, wl = random_queries(g, 200, seed=3)
    assert np.array_equal(cleaned.query_batch(s, t, wl),
                          seq.query_batch(s, t, wl))


# ------------------------------------------------------------------ pruning
def test_pruning_reduces_index_size():
    g = scale_free(150, 3, num_levels=3, seed=19)
    pruned = build_wc_index(g, prune=True)
    unpruned = build_wc_index(g, prune=False)
    assert pruned.size_entries() < unpruned.size_entries()
    s, t, wl = random_queries(g, 100, seed=4)
    assert np.array_equal(pruned.query_batch(s, t, wl),
                          unpruned.query_batch(s, t, wl))


# ---------------------------------------------------------------- dominance
@given(st.lists(st.tuples(st.integers(0, 20), st.integers(0, 10)),
                min_size=1, max_size=50))
@settings(max_examples=50, deadline=None)
def test_pareto_filter_properties(pairs):
    d = np.array([p[0] for p in pairs], dtype=np.int64)
    w = np.array([p[1] for p in pairs], dtype=np.int64)
    keep = pareto_filter(d, w)
    kept = [(int(a), int(b)) for a, b in zip(d[keep], w[keep])]
    # kept entries are mutually non-dominating
    for i, (d1, w1) in enumerate(kept):
        for j, (d2, w2) in enumerate(kept):
            if i != j:
                assert not (d1 <= d2 and w1 >= w2), (kept, i, j)
    # every dropped entry is dominated by some kept entry
    for d0, w0 in zip(d[~keep], w[~keep]):
        assert any(kd <= d0 and kw >= w0 for kd, kw in kept)

"""Differential harness: every answer path must agree EXACTLY on the full
(s, t, w_level) grid of small random instances.

Five implementations under test, none sharing a code path end-to-end:

  1. `WCIndex.query_one`          host sort-merge (paper Alg. 5)
  2. `query_batch_jnp`            padded masked outer join (XLA)
  3. `query_batch_sorted_jnp`     Thm.-3-aware segmented-min variant (XLA)
  4. segmented CSR kernel         `DeviceQueryEngine(layout="csr",
                                  use_pallas=True)` — bucket-pair planner +
                                  scalar-prefetch Pallas kernel
  5. constrained Dijkstra         per-query oracle from `core.baselines`

all checked against a sixth, structurally independent expectation: the
per-level BFS sweep `baselines.constrained_distance_grid`.

Coverage: 8 parametrized blocks x 25 hypothesis examples = 200 generated
instances (deterministic under the `_hypo_shim` fallback: the shim draws
from a seeded generator, and each block folds its id into the graph seed).
Shapes are pinned to a small set (V in {8, 10, 12}, fixed query/label
padding) so the jitted paths compile a handful of variants, not one per
instance.

Also here: property tests for the index invariants (Thm. 3 monotonicity,
post-pass minimality, sequential-vs-batched label-set equivalence) covering
the padded batched builder AND the device-resident CSR-emitting builder,
and the PROFILE differential harness (4 blocks x 25 examples = 100 more
instances): the one-pass staircase path vs the per-level query loop vs the
BFS sweep, on every layout/kernel mode and both serving memo modes.
"""
import numpy as np
import pytest
from _hypo_shim import given, settings, st  # hypothesis or fallback

import jax.numpy as jnp

from repro.core.baselines import constrained_distance_grid, dijkstra_query
from repro.core.dominance import pareto_filter_grouped
from repro.core.generators import erdos_renyi
from repro.core.graph import INF_DIST
from repro.core.query import (DeviceQueryEngine, profile_batch_jnp,
                              query_batch_jnp, query_batch_sorted_jnp)
from repro.core.serve import WCSDServer
from repro.core.wc_index import build_wc_index
from repro.core.wc_index_batched import (build_wc_index_batched,
                                         build_wc_index_batched_packed,
                                         clean_index)

FIXED_CAP = 64    # padded label width shared by every instance (V <= 12 =>
                  # counts <= (W+1) * V < 64, asserted below)
FIXED_B = 1024    # query batch padding for the jnp paths

N_BLOCKS = 8
EXAMPLES_PER_BLOCK = 25   # N_BLOCKS * EXAMPLES_PER_BLOCK = 200 instances
_instances_run = [0]

# one engine cache per (graph fingerprint): the csr engines recompile per
# tile shape only; keeping construction per-instance is the point (the
# packing path is part of what is under test)


def _full_grid(V, W):
    """Every (s, t, w_level) including the infeasible level W."""
    s, t, w = np.meshgrid(np.arange(V), np.arange(V), np.arange(W + 1),
                          indexing="ij")
    return (s.ravel().astype(np.int32), t.ravel().astype(np.int32),
            w.ravel().astype(np.int32))


def _pad_queries(s, t, wl):
    n = len(s)
    assert n <= FIXED_B
    sp = np.zeros(FIXED_B, dtype=np.int32)
    tp = np.zeros(FIXED_B, dtype=np.int32)
    wp = np.zeros(FIXED_B, dtype=np.int32)
    sp[:n], tp[:n], wp[:n] = s, t, wl
    return sp, tp, wp, n


@pytest.mark.parametrize("block", range(N_BLOCKS))
@given(st.sampled_from([8, 10, 12]), st.sampled_from([2.5, 3.5, 4.5]),
       st.sampled_from([2, 3]), st.integers(0, 100_000))
@settings(max_examples=EXAMPLES_PER_BLOCK, deadline=None, derandomize=True)
def test_five_paths_agree_on_full_grid(block, n, deg, levels, seed):
    g = erdos_renyi(n, deg, num_levels=levels, seed=seed + 7919 * block)
    V, W = g.num_nodes, g.num_levels
    idx = build_wc_index(g)
    assert int(idx.count.max()) <= FIXED_CAP

    s, t, wl = _full_grid(V, W)
    exp = constrained_distance_grid(g)[s, t, wl]

    # 1. host sort-merge, every grid point
    got1 = np.array([idx.query_one(int(a), int(b), int(w))
                     for a, b, w in zip(s, t, wl)], dtype=np.int32)
    np.testing.assert_array_equal(got1, exp)

    # 2./3. padded jnp paths (fixed shapes -> a handful of compiles)
    hub, dist, wlev, count = idx.padded_device_arrays(cap=FIXED_CAP)
    dev = tuple(jnp.asarray(a) for a in (hub, dist, wlev, count))
    sp, tp, wp, nq = _pad_queries(s, t, wl)
    qargs = (jnp.asarray(sp), jnp.asarray(tp), jnp.asarray(wp))
    got2 = np.asarray(query_batch_jnp(*dev, *qargs))[:nq]
    np.testing.assert_array_equal(got2, exp)
    got3 = np.asarray(query_batch_sorted_jnp(*dev, *qargs))[:nq]
    np.testing.assert_array_equal(got3, exp)

    # 4. segmented CSR kernel via the bucket-pair planner (pinned: this is
    # the ragged megakernel's differential oracle; the ragged path has its
    # own harness in tests/test_ragged.py)
    eng = DeviceQueryEngine(idx, layout="csr", use_pallas=True,
                            dispatch="bucket_pair")
    got4 = np.asarray(eng.query(s, t, wl))
    np.testing.assert_array_equal(got4, exp)

    # 5. constrained Dijkstra, every grid point
    got5 = np.array([dijkstra_query(g, int(a), int(b), int(w))
                     for a, b, w in zip(s, t, wl)], dtype=np.int32)
    np.testing.assert_array_equal(got5, exp)

    _instances_run[0] += 1


# ----------------------------------------------------- profile staircases
N_PROFILE_BLOCKS = 4   # x EXAMPLES_PER_BLOCK = 100 generated instances
_profile_instances_run = [0]


@pytest.mark.parametrize("block", range(N_PROFILE_BLOCKS))
@given(st.sampled_from([8, 10, 12]), st.sampled_from([2.5, 3.5, 4.5]),
       st.sampled_from([2, 3]), st.integers(0, 100_000))
@settings(max_examples=EXAMPLES_PER_BLOCK, deadline=None, derandomize=True)
def test_profile_paths_agree_on_full_grid(block, n, deg, levels, seed):
    """One-pass profile == the per-level `wcsd_query` loop == BFS sweep on
    the full (s, t) pair grid, at every constraint level at once.

    Paths under test: the padded jnp path (`profile_batch_jnp`, the XLA-
    compiled mode), the segmented CSR path in interpret-kernel AND jnp
    modes, and the serving surface under both directed and undirected memo
    canonicalization."""
    g = erdos_renyi(n, deg, num_levels=levels, seed=seed + 104729 * block)
    V, W = g.num_nodes, g.num_levels
    idx = build_wc_index(g)
    assert int(idx.count.max()) <= FIXED_CAP

    D = constrained_distance_grid(g)
    s, t = np.meshgrid(np.arange(V), np.arange(V), indexing="ij")
    s = s.ravel().astype(np.int32)
    t = t.ravel().astype(np.int32)
    exp = D[s, t, :]                                     # [V*V, W+1]

    # padded jnp path (fixed shapes -> a handful of compiles)
    hub, dist, wlev, count = idx.padded_device_arrays(cap=FIXED_CAP)
    dev = tuple(jnp.asarray(a) for a in (hub, dist, wlev, count))
    sp, tp, _, nq = _pad_queries(s, t, np.zeros_like(s))
    got = np.asarray(profile_batch_jnp(*dev, jnp.asarray(sp),
                                       jnp.asarray(tp), num_levels=W))[:nq]
    np.testing.assert_array_equal(got, exp)

    # segmented CSR path: interpret-mode Pallas kernel and jnp oracle
    eng_k = DeviceQueryEngine(idx, layout="csr", use_pallas=True)
    prof_k = np.asarray(eng_k.query_profile(s, t))
    np.testing.assert_array_equal(prof_k, exp)
    eng_j = DeviceQueryEngine(idx, layout="csr", use_pallas=False)
    np.testing.assert_array_equal(np.asarray(eng_j.query_profile(s, t)), exp)

    # pointwise: profile[:, w] == the per-level query loop it replaces
    loop = np.stack(
        [np.asarray(eng_k.query(s, t, np.full(len(s), w, np.int32)))
         for w in range(W + 1)], axis=1)
    np.testing.assert_array_equal(prof_k, loop)

    # serving surface, both memo-canonicalization modes
    for undirected in (True, False):
        srv = WCSDServer(engine=eng_k, max_batch=64, undirected=undirected)
        np.testing.assert_array_equal(srv.query_profile_many(s, t), exp)

    _profile_instances_run[0] += 1


def test_profile_differential_coverage_target():
    """Acceptance: the profile harness is configured for >= 100 generated
    instances; when blocks ran in this session, each produced exactly its
    example count (no silent early exits)."""
    assert N_PROFILE_BLOCKS * EXAMPLES_PER_BLOCK >= 100
    if _profile_instances_run[0]:
        assert _profile_instances_run[0] % EXAMPLES_PER_BLOCK == 0


# ------------------------------------------------------- index invariants
def _builders(g):
    """(name, padded WCIndex view, flat-entry arrays) for both batched
    builders; flat arrays are (v, hub, dist, wlev) vertex-major."""
    bat, _ = build_wc_index_batched(g, batch_size=16)
    packed_idx, _ = build_wc_index_batched_packed(g, batch_size=16)
    out = []
    for name, idx in [("padded-batched", bat),
                      ("csr-batched", packed_idx.to_index())]:
        c = idx.count
        rows = np.repeat(np.arange(idx.num_nodes), c)
        cols = np.concatenate([np.arange(k) for k in c]) if len(c) else \
            np.zeros(0, np.int64)
        out.append((name, idx, (rows, idx.hub_rank[rows, cols],
                                idx.dist[rows, cols], idx.wlev[rows, cols])))
    return out


@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None, derandomize=True)
def test_thm3_monotonic_within_vertex_hub_groups(seed):
    """Thm. 3: after the Pareto post-pass, dist and wlev strictly increase
    inside every (vertex, hub) group, and rows stay hub-sorted — for both
    the padded batched builder and the CSR-emitting device builder."""
    g = erdos_renyi(40, 3.5, num_levels=3, seed=seed)
    for name, idx, (v, h, d, w) in _builders(g):
        key = v.astype(np.int64) * g.num_nodes + h
        # rows hub-sorted: per-vertex key non-decreasing
        same_v = v[1:] == v[:-1]
        assert np.all(h[1:][same_v] >= h[:-1][same_v]), name
        same_g = same_v & (h[1:] == h[:-1])
        assert np.all(d[1:][same_g] > d[:-1][same_g]), name
        assert np.all(w[1:][same_g] > w[:-1][same_g]), name
        assert len(key)  # non-degenerate


@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None, derandomize=True)
def test_minimality_after_pareto_post_pass(seed):
    """No dominated entry survives the post-pass in either builder."""
    g = erdos_renyi(40, 4.0, num_levels=3, seed=seed + 1)
    for name, idx, (v, h, d, w) in _builders(g):
        keep = pareto_filter_grouped(v.astype(np.int64) * g.num_nodes + h,
                                     d.astype(np.int64), w.astype(np.int64))
        assert keep.all(), name


@given(st.integers(0, 10_000))
@settings(max_examples=6, deadline=None, derandomize=True)
def test_sequential_vs_batched_label_sets(seed):
    """After PSL-style cleaning the batched builders' label sets equal the
    sequential builder's exactly — same (vertex, hub, dist, wlev) tuples,
    not just the same sizes/answers."""
    g = erdos_renyi(50, 3.0, num_levels=3, seed=seed + 2)
    seq = build_wc_index(g)

    def entry_set(idx):
        c = idx.count
        rows = np.repeat(np.arange(idx.num_nodes), c)
        cols = np.concatenate([np.arange(k) for k in c])
        return set(zip(rows.tolist(), idx.hub_rank[rows, cols].tolist(),
                       idx.dist[rows, cols].tolist(),
                       idx.wlev[rows, cols].tolist()))

    bat, _ = build_wc_index_batched(g, batch_size=16)
    packed_idx, _ = build_wc_index_batched_packed(g, batch_size=16)
    assert entry_set(clean_index(bat)[0]) == entry_set(seq)
    assert entry_set(clean_index(packed_idx.to_index())[0]) == \
        entry_set(seq)


def test_packed_builder_store_is_byte_identical_to_pack_after_build():
    """Acceptance: the device-resident builder's directly-emitted CSR store
    equals pack-after-build on every array, bucket tables included."""
    for seed, nv in [(5, 60), (9, 90)]:
        g = erdos_renyi(nv, 3.5, num_levels=4, seed=seed)
        old, _ = build_wc_index_batched(g, batch_size=16)
        via_padded = old.packed()
        direct = build_wc_index_batched_packed(g, batch_size=16)[0].labels
        for field in ("hub_rank", "dist", "wlev", "offsets", "bucket_widths",
                      "bucket_of", "slot_of"):
            np.testing.assert_array_equal(getattr(direct, field),
                                          getattr(via_padded, field), field)


def test_unreachable_and_identity_on_packed_index():
    g = erdos_renyi(12, 1.0, num_levels=2, seed=3)  # sparse: likely islands
    pidx, _ = build_wc_index_batched_packed(g, batch_size=4)
    D = constrained_distance_grid(g)
    for s in range(g.num_nodes):
        for t in range(g.num_nodes):
            for w in range(g.num_levels + 1):
                assert pidx.query_one(s, t, w) == D[s, t, w]
    assert pidx.query_one(0, 0, g.num_levels) == 0
    assert np.any(D[:, :, 0] == INF_DIST)  # the generator made islands


# ------------------------------------------- row-sharded ragged (8 devices)
_SHARDED_DIFFERENTIAL_PROG = r'''
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
# the 200 instances reuse a handful of grid shapes (V in {8,10,12}, W in
# {2,3}); the persistent cache turns the per-instance engine compiles into
# disk hits, keeping the full sweep CI-sized
jax.config.update("jax_compilation_cache_dir",
                  tempfile.mkdtemp(prefix="wcsd-diff-cache"))
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
import numpy as np
from repro.core.baselines import constrained_distance_grid
from repro.core.generators import erdos_renyi
from repro.core.query import ShardedQueryEngine
from repro.core.wc_index import build_wc_index
from repro.launch.mesh import make_serving_mesh

assert len(jax.devices()) == 8
mesh = make_serving_mesh()
N_BLOCKS, EXAMPLES = 8, 25
ran = 0
for block in range(N_BLOCKS):
    rng = np.random.default_rng(0)  # deterministic, shim-style draws
    for _ in range(EXAMPLES):
        n = [8, 10, 12][int(rng.integers(3))]
        deg = [2.5, 3.5, 4.5][int(rng.integers(3))]
        levels = [2, 3][int(rng.integers(2))]
        seed = int(rng.integers(0, 100_001))
        g = erdos_renyi(n, deg, num_levels=levels, seed=seed + 7919 * block)
        V, W = g.num_nodes, g.num_levels
        idx = build_wc_index(g)
        s, t, w = np.meshgrid(np.arange(V), np.arange(V),
                              np.arange(W + 1), indexing="ij")
        s, t, w = (a.ravel().astype(np.int32) for a in (s, t, w))
        D = constrained_distance_grid(g)
        exp = D[s, t, w]
        ps, pt = s[::W + 1], t[::W + 1]          # the (s, t) pair grid
        exp_prof = D[ps, pt, :]
        kernel = ran % 10 == 0   # interpret-Pallas leg; jnp decode otherwise
        eng = ShardedQueryEngine(
            idx, mesh=mesh, layout="csr", dispatch="ragged",
            device_budget_bytes=1, use_pallas=kernel, interpret=True,
            compressed=(ran % 2 == 0))           # both stores, alternating
        assert eng.mode == "sharded_labels" and eng.dispatch == "ragged"
        assert eng.compressed is (ran % 2 == 0)
        np.testing.assert_array_equal(np.asarray(eng.query(s, t, w)), exp)
        np.testing.assert_array_equal(
            np.asarray(eng.query_profile(ps, pt)), exp_prof)
        if ran % 5 == 0:        # the row-sharded bucket-pair loop agrees too
            bp = ShardedQueryEngine(
                idx, mesh=mesh, layout="csr", dispatch="bucket_pair",
                device_budget_bytes=1, use_pallas=kernel, interpret=True)
            assert bp.mode == "sharded_labels" and bp.dispatch == "bucket_pair"
            np.testing.assert_array_equal(np.asarray(bp.query(s, t, w)), exp)
            np.testing.assert_array_equal(
                np.asarray(bp.query_profile(ps, pt)), exp_prof)
        ran += 1
assert ran == N_BLOCKS * EXAMPLES == 200
print(f"OK sharded differential {ran} instances")
'''


def test_sharded_ragged_differential_200_instances_on_8_devices():
    """The sharded-ragged differential leg: the full 200-instance harness
    grid re-run with ROW-SHARDED (device_budget_bytes=1) engines on 8
    virtual devices — ragged dispatch (compressed and uncompressed stores,
    jnp decode and interpret-Pallas kernels) vs the BFS sweep on every
    instance, and vs the row-sharded bucket-pair loop on a rotating
    subset; query AND profile answers bit-identical. Hop distances stay
    inside bfloat16's exact-integer range, so the compressed legs are
    exact, not approximate. Subprocess: the parent pins one CPU device."""
    import os
    import subprocess
    import sys

    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = {**os.environ, "PYTHONPATH": src, "JAX_PLATFORMS": "cpu"}
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SHARDED_DIFFERENTIAL_PROG],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "OK sharded differential 200 instances" in r.stdout


def test_differential_coverage_target():
    """Acceptance: the harness is configured for >= 200 generated instances
    (asserted statically so the check holds under any test subselection);
    when blocks did run in this session, each must have produced exactly
    its example count — no silent early exits."""
    assert N_BLOCKS * EXAMPLES_PER_BLOCK >= 200
    if _instances_run[0]:
        assert _instances_run[0] % EXAMPLES_PER_BLOCK == 0

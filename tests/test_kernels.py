"""Pallas kernels vs ref.py oracles (interpret mode), shape/dtype sweeps +
hypothesis property fuzz."""
import numpy as np
import pytest
from _hypo_shim import given, settings, st  # hypothesis or fallback

import jax
import jax.numpy as jnp

from repro.core.generators import random_queries, scale_free
from repro.core.query import DeviceQueryEngine
from repro.core.wc_index import build_wc_index
from repro.kernels import ops
from repro.kernels import ref as kref


# ------------------------------------------------------------- wcsd_query
@pytest.mark.parametrize("B,L", [(8, 128), (16, 128), (64, 256), (3, 128),
                                 (100, 384)])
def test_wcsd_query_kernel_shapes(B, L):
    rng = np.random.default_rng(B * 1000 + L)
    hs = rng.integers(-1, 50, size=(B, L)).astype(np.int32)
    ht = rng.integers(-1, 50, size=(B, L)).astype(np.int32)
    ds = rng.integers(0, 100, size=(B, L)).astype(np.int32)
    dt = rng.integers(0, 100, size=(B, L)).astype(np.int32)
    from repro.kernels.wcsd_query import wcsd_query_gathered
    got = wcsd_query_gathered(jnp.asarray(hs), jnp.asarray(ds),
                              jnp.asarray(ht), jnp.asarray(dt)) \
        if B % 8 == 0 else None
    exp = kref.wcsd_query_gathered_ref(jnp.asarray(hs), jnp.asarray(ds),
                                       jnp.asarray(ht), jnp.asarray(dt))
    if got is not None:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


def test_wcsd_query_end_to_end_vs_host():
    g = scale_free(150, 3, num_levels=5, seed=23)
    idx = build_wc_index(g)
    s, t, wl = random_queries(g, 130, seed=7)
    eng = DeviceQueryEngine(idx, use_pallas=True)
    got = np.asarray(eng.query(s, t, wl))
    exp = idx.query_batch(s, t, wl)
    np.testing.assert_array_equal(got, exp)


@given(st.integers(1, 40), st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_wcsd_query_kernel_fuzz(B, seed):
    rng = np.random.default_rng(seed)
    L = 128
    hs = rng.integers(-1, 20, size=(B, L)).astype(np.int32)
    ht = rng.integers(-2, 20, size=(B, L)).astype(np.int32)
    ds = rng.integers(0, 1 << 29, size=(B, L)).astype(np.int32)
    dt = rng.integers(0, 1000, size=(B, L)).astype(np.int32)
    hub = jnp.asarray(np.concatenate([hs, ht], 1))
    # use the public op (handles padding + masking) against a brute force
    V = 40
    hubp = rng.integers(-1, 30, size=(V, L)).astype(np.int32)
    hubp.sort(axis=1)
    dist = rng.integers(0, 64, size=(V, L)).astype(np.int32)
    wlev = rng.integers(-1, 6, size=(V, L)).astype(np.int32)
    count = rng.integers(0, L + 1, size=V).astype(np.int32)
    s = rng.integers(0, V, size=B).astype(np.int32)
    t = rng.integers(0, V, size=B).astype(np.int32)
    w = rng.integers(0, 6, size=B).astype(np.int32)
    got = np.asarray(ops.wcsd_query(jnp.asarray(hubp), jnp.asarray(dist),
                                    jnp.asarray(wlev), jnp.asarray(count),
                                    jnp.asarray(s), jnp.asarray(t),
                                    jnp.asarray(w)))
    ref = np.asarray(ops.wcsd_query(jnp.asarray(hubp), jnp.asarray(dist),
                                    jnp.asarray(wlev), jnp.asarray(count),
                                    jnp.asarray(s), jnp.asarray(t),
                                    jnp.asarray(w), use_kernel=False))
    np.testing.assert_array_equal(got, ref)


# ---------------------------------------------------------------- frontier
@pytest.mark.parametrize("V,D", [(64, 4), (256, 16), (100, 7), (512, 32)])
def test_frontier_kernel_shapes(V, D):
    rng = np.random.default_rng(V + D)
    nbr = rng.integers(-1, V, size=(V, D)).astype(np.int32)
    lvl = np.where(nbr >= 0, rng.integers(0, 6, size=(V, D)), -1).astype(
        np.int32)
    Fw = rng.integers(-1, 7, size=V).astype(np.int32)
    R = rng.integers(-1, 7, size=V).astype(np.int32)
    a = ops.frontier_relax(jnp.asarray(nbr), jnp.asarray(lvl),
                           jnp.asarray(Fw), jnp.asarray(R))
    b = ops.frontier_relax(jnp.asarray(nbr), jnp.asarray(lvl),
                           jnp.asarray(Fw), jnp.asarray(R), use_kernel=False)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_frontier_kernel_matches_bfs_round():
    """One kernel round == one round of the reference constrained BFS."""
    g = scale_free(200, 4, num_levels=4, seed=29)
    nbr_pad, lvl_pad = g.padded_adjacency()
    root = 5
    Fw = np.full(g.num_nodes, -1, np.int32)
    Fw[root] = g.num_levels
    R = Fw.copy()
    newF, newR = ops.frontier_relax(jnp.asarray(nbr_pad),
                                    jnp.asarray(lvl_pad),
                                    jnp.asarray(Fw), jnp.asarray(R))
    newF = np.asarray(newF)
    nbrs, lvls = g.neighbors(root)
    for v, l in zip(nbrs, lvls):
        assert newF[v] == max(lvl for u, lvl in zip(nbrs, lvls) if u == v)


# ----------------------------------------------------- rank-batched round
@pytest.mark.parametrize("B,V,cap,W1", [(4, 64, 8, 4), (8, 100, 16, 6),
                                        (3, 256, 8, 3)])
def test_wc_prune_emit_kernel_shapes(B, V, cap, W1):
    rng = np.random.default_rng(B * V)
    F = rng.integers(-1, W1, size=(B, V)).astype(np.int32)
    T = rng.integers(0, 1 << 30, size=(B, V, W1)).astype(np.int32)
    hub = rng.integers(-1, V, size=(V, cap)).astype(np.int32)
    dist = rng.integers(0, 1 << 30, size=(V, cap)).astype(np.int32)
    wlev = rng.integers(-1, W1, size=(V, cap)).astype(np.int32)
    d = jnp.int32(rng.integers(1, 5))
    args = (jnp.asarray(F), jnp.asarray(T), jnp.asarray(hub),
            jnp.asarray(dist), jnp.asarray(wlev), d)
    got = np.asarray(ops.wc_prune_emit(*args))
    exp = np.asarray(ops.wc_prune_emit(*args, use_kernel=False))
    np.testing.assert_array_equal(got, exp)


@pytest.mark.parametrize("B,V,D", [(4, 64, 5), (8, 100, 12), (3, 256, 3)])
def test_wc_relax_batched_kernel_shapes(B, V, D):
    rng = np.random.default_rng(B * V + D)
    emit_w = rng.integers(-1, 6, size=(B, V)).astype(np.int32)
    nbr = rng.integers(-1, V, size=(V, D)).astype(np.int32)
    lvl = np.where(nbr >= 0, rng.integers(0, 6, size=(V, D)), -1).astype(
        np.int32)
    rank = rng.permutation(V).astype(np.int32)
    rr = rng.integers(0, V, size=B).astype(np.int32)
    R = rng.integers(-1, 6, size=(B, V)).astype(np.int32)
    args = (jnp.asarray(emit_w), jnp.asarray(nbr), jnp.asarray(lvl),
            jnp.asarray(rank), jnp.asarray(rr), jnp.asarray(R))
    got = ops.wc_relax_batched(*args)
    exp = ops.wc_relax_batched(*args, use_kernel=False)
    for x, y in zip(got, exp):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_wc_batched_round_kernels_fuzz(seed):
    """Kernel vs jnp ref on one full prune+relax round over a real graph's
    padded adjacency and a random partial index."""
    rng = np.random.default_rng(seed)
    g = scale_free(80, 3, num_levels=4, seed=seed % 7)
    V, W1 = g.num_nodes, g.num_levels + 1
    B, cap = 8, 8
    nbr, lvl = g.padded_adjacency()
    F = rng.integers(-1, W1, size=(B, V)).astype(np.int32)
    T = rng.integers(0, 40, size=(B, V, W1)).astype(np.int32)
    hub = np.sort(rng.integers(-1, V, size=(V, cap)), 1).astype(np.int32)
    dist = rng.integers(0, 40, size=(V, cap)).astype(np.int32)
    wlev = rng.integers(-1, W1, size=(V, cap)).astype(np.int32)
    rank = rng.permutation(V).astype(np.int32)
    rr = rng.integers(0, V, size=B).astype(np.int32)
    d = jnp.int32(rng.integers(1, 4))
    emit_k = ops.wc_prune_emit(jnp.asarray(F), jnp.asarray(T),
                               jnp.asarray(hub), jnp.asarray(dist),
                               jnp.asarray(wlev), d)
    emit_r = ops.wc_prune_emit(jnp.asarray(F), jnp.asarray(T),
                               jnp.asarray(hub), jnp.asarray(dist),
                               jnp.asarray(wlev), d, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(emit_k), np.asarray(emit_r))
    R = np.where(F >= 0, F, -1).astype(np.int32)
    relax_args = (emit_k, jnp.asarray(nbr), jnp.asarray(lvl),
                  jnp.asarray(rank), jnp.asarray(rr), jnp.asarray(R))
    got = ops.wc_relax_batched(*relax_args)
    exp = ops.wc_relax_batched(*relax_args, use_kernel=False)
    for x, y in zip(got, exp):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --------------------------------------------------------------------- cin
@pytest.mark.parametrize("B,H,M,D,K", [(8, 16, 8, 4, 8), (20, 13, 7, 6, 11),
                                       (4, 200, 39, 10, 200)])
def test_cin_kernel_shapes(B, H, M, D, K):
    rng = np.random.default_rng(B)
    x1 = rng.standard_normal((B, H, D)).astype(np.float32)
    x0 = rng.standard_normal((B, M, D)).astype(np.float32)
    w = rng.standard_normal((K, H, M)).astype(np.float32)
    got = np.asarray(ops.cin_layer(jnp.asarray(x1), jnp.asarray(x0),
                                   jnp.asarray(w)))
    exp = np.asarray(kref.cin_layer_ref(jnp.asarray(x1), jnp.asarray(x0),
                                        jnp.asarray(w)))
    # tolerance scales with the H*M-length fp32 reduction (different
    # contraction order kernel vs ref)
    np.testing.assert_allclose(got, exp, rtol=1e-4,
                               atol=1e-5 * H * M ** 0.5)


def test_cin_kernel_bf16():
    rng = np.random.default_rng(0)
    x1 = rng.standard_normal((8, 16, 8)).astype(np.float32)
    x0 = rng.standard_normal((8, 8, 8)).astype(np.float32)
    w = rng.standard_normal((16, 16, 8)).astype(np.float32)
    got = np.asarray(ops.cin_layer(jnp.asarray(x1, jnp.bfloat16),
                                   jnp.asarray(x0, jnp.bfloat16),
                                   jnp.asarray(w, jnp.bfloat16)))
    exp = np.asarray(kref.cin_layer_ref(jnp.asarray(x1), jnp.asarray(x0),
                                        jnp.asarray(w)))
    np.testing.assert_allclose(got, exp, rtol=5e-2, atol=0.5)  # bf16 inputs

import numpy as np
import pytest
from _hypo_shim import given, settings, st  # hypothesis or fallback

from repro.core.graph import Graph, expand_frontier_csr
from repro.core.generators import road_grid, scale_free, erdos_renyi


def test_from_edges_dedup_and_symmetry():
    g = Graph.from_edges(4, np.array([0, 1, 0, 0]), np.array([1, 0, 2, 0]),
                         np.array([1.0, 3.0, 2.0, 9.0]))
    # self loop dropped; duplicate (0,1)/(1,0) kept once with max quality
    assert g.num_edges == 2
    nbrs, lvls = g.neighbors(0)
    assert set(nbrs.tolist()) == {1, 2}
    # (0,1) quality should be max(1.0, 3.0) = 3.0
    q01 = g.levels[lvls[list(nbrs).index(1)]]
    assert q01 == 3.0


def test_levels_are_sorted_unique():
    g = erdos_renyi(100, 5.0, num_levels=4, seed=0)
    assert np.all(np.diff(g.levels) > 0)
    assert g.num_levels <= 4
    assert g.edges_level.max() < g.num_levels


def test_level_of_threshold_semantics():
    g = Graph.from_edges(3, np.array([0, 1]), np.array([1, 2]),
                         np.array([1.0, 2.5]))
    assert g.level_of(0.5) == 0     # every edge qualifies
    assert g.level_of(1.0) == 0
    assert g.level_of(1.1) == 1     # only the 2.5 edge
    assert g.level_of(3.0) == 2     # nothing qualifies


def test_filtered_preserves_global_levels():
    g = erdos_renyi(60, 4.0, num_levels=5, seed=1)
    sub = g.filtered(2)
    assert np.array_equal(sub.levels, g.levels)
    if len(sub.edges_level):
        assert sub.edges_level.min() >= 2


def test_expand_frontier_matches_neighbors():
    g = road_grid(5, 5, num_levels=3, seed=2)
    nodes = np.array([0, 7, 12], dtype=np.int32)
    src_pos, nbrs, lvls = expand_frontier_csr(g, nodes)
    for i, v in enumerate(nodes):
        exp_n, exp_l = g.neighbors(int(v))
        got = nbrs[src_pos == i]
        assert sorted(got.tolist()) == sorted(exp_n.tolist())


def test_padded_adjacency_roundtrip():
    g = scale_free(50, 3, num_levels=3, seed=3)
    nbr_pad, lvl_pad = g.padded_adjacency()
    for v in range(g.num_nodes):
        exp_n, exp_l = g.neighbors(v)
        got = nbr_pad[v][nbr_pad[v] >= 0]
        assert sorted(got.tolist()) == sorted(exp_n.tolist())


@given(st.integers(10, 60), st.integers(1, 5), st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_graph_invariants_fuzz(n, levels, seed):
    g = erdos_renyi(n, 4.0, num_levels=levels, seed=seed)
    # CSR consistent with edge list
    assert g.indptr[-1] == len(g.nbr)
    assert len(g.edges_src) == len(g.nbr)
    deg = g.degree()
    assert deg.sum() == len(g.nbr)
    # symmetry: (u, v) present iff (v, u) present with same level
    key = g.edges_src.astype(np.int64) * n + g.edges_dst
    rkey = g.edges_dst.astype(np.int64) * n + g.edges_src
    assert set(key.tolist()) == set(rkey.tolist())

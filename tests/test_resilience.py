"""Fault-tolerant serving (docs/resilience.md): flush watchdog +
retry/backoff, the degraded-mode fallback ladder, the crash-safe update
WAL, and the seeded chaos schedule that ties them together.

The acceptance block at the bottom runs the full >= 200-step chaos
harness (`checkpoint/fault.run_chaos_schedule`): randomized submits /
updates / injected engine raises / flush hangs / bit-flips / torn WAL
tails plus one mid-update crash with a WAL-replay warm restart — every
answer differentially checked against the BFS oracle, zero lost or
double-delivered requests, server back in its top mode at the end.
"""
import numpy as np
import pytest

from repro.checkpoint.ckpt import UpdateWAL
from repro.checkpoint.fault import (FaultSchedule, FaultyEngine,
                                    InjectedEngineError, _HangingResult,
                                    crashing_open, run_chaos_schedule,
                                    tear_file_tail)
from repro.core.generators import erdos_renyi, random_queries
from repro.core.resilience import (FlushRetryExhausted, RetryPolicy,
                                   UnknownRequestError, WALError,
                                   WALReplayError, build_fallback_ladder)
from repro.core.serve import WCSDServer
from repro.core.wc_index import build_wc_index


@pytest.fixture(scope="module")
def graph():
    return erdos_renyi(40, 3.0, num_levels=4, seed=2)


@pytest.fixture(scope="module")
def index(graph):
    return build_wc_index(graph, ordering="degree")


def _fast_server(index, **kw):
    base = dict(layout="csr", dispatch="ragged", max_batch=1024,
                backoff_base_ms=0.01, retry_seed=0)
    base.update(kw)
    return WCSDServer(index, **base)


# ---------------------------------------------------------------- taxonomy
def test_unknown_rid_raises_typed_error(index):
    srv = _fast_server(index)
    with pytest.raises(UnknownRequestError, match="unknown or already"):
        srv.result(7)
    with pytest.raises(UnknownRequestError):
        srv.profile_result(7)
    assert issubclass(UnknownRequestError, KeyError)  # except KeyError works
    err = UnknownRequestError(42)
    assert err.rid == 42 and "42" in str(err)


def test_latency_summary_empty_is_zeros(index):
    srv = _fast_server(index)
    assert srv.latency_summary() == {"count": 0, "n": 0,
                                     "p50_us": 0.0, "p99_us": 0.0}


# ------------------------------------------------------------------ ladder
def test_fallback_ladder_full_chain():
    cfg = dict(backend="sharded", use_pallas=True, interpret=True,
               layout="csr", dispatch="ragged", compressed=True,
               mesh="M", device_budget_bytes=1, multi_pod=False)
    names = [n for n, _ in build_fallback_ladder(cfg)]
    assert names == ["primary", "uncompressed", "replicated",
                     "single_device", "bucket_pair", "oracle"]
    # each rung drops exactly the declared capability
    ladder = dict(build_fallback_ladder(cfg))
    assert ladder["uncompressed"]["compressed"] is False
    assert ladder["replicated"]["device_budget_bytes"] is None
    assert ladder["single_device"]["backend"] == "device"
    assert ladder["bucket_pair"]["dispatch"] == "bucket_pair"
    assert ladder["oracle"]["layout"] == "padded"
    assert ladder["oracle"]["use_pallas"] is False


def test_fallback_ladder_skips_noop_rungs():
    csr = dict(backend="device", use_pallas=False, interpret=None,
               layout="csr", dispatch="ragged", compressed=False,
               mesh=None, device_budget_bytes=None, multi_pod=False)
    assert [n for n, _ in build_fallback_ladder(csr)] == \
        ["primary", "bucket_pair", "oracle"]
    # a padded no-pallas single-device primary IS the oracle: one rung
    oracle = dict(csr, layout="padded")
    assert [n for n, _ in build_fallback_ladder(oracle)] == ["primary"]


def test_retry_policy_backoff_is_exponential_and_jittered():
    p = RetryPolicy(backoff_base_ms=2.0, backoff_factor=2.0, jitter=0.0)
    rng = np.random.default_rng(0)
    assert p.backoff_s(1, rng) == pytest.approx(0.002)
    assert p.backoff_s(3, rng) == pytest.approx(0.008)
    pj = RetryPolicy(backoff_base_ms=2.0, jitter=0.5)
    draws = {pj.backoff_s(1, rng) for _ in range(16)}
    assert len(draws) > 1                       # jitter actually varies
    assert all(0.001 <= d <= 0.003 for d in draws)


# ---------------------------------------------------------------- watchdog
def test_watchdog_times_out_hung_flush(graph, index):
    """A handle that never reports ready is abandoned at the deadline and
    the SAME batch re-dispatched — the caller just gets the answer."""
    srv = _fast_server(index, flush_timeout_ms=30.0, max_retries=3)
    real = srv.engine
    calls = {"n": 0}

    class Wedge:
        def __getattr__(self, name):
            return getattr(real, name)

        def query_async(self, s, t, w):
            calls["n"] += 1
            h = real.query_async(s, t, w)
            return _HangingResult(h) if calls["n"] == 1 else h

    srv.engine = Wedge()
    s, t, wl = random_queries(graph, 8, seed=4)
    got = srv.query_many(s, t, wl)
    assert np.array_equal(got, index.query_batch(s, t, wl))
    assert srv.stats.timeout_retries == 1 and calls["n"] == 2
    assert srv.mode == "primary"                # absorbed, not demoted


def test_exhaustion_demotes_then_health_promotes(graph, index):
    """Retry-budget exhaustion steps one rung down the ladder (the batch
    is answered by the demoted engine, still correct); probe_interval
    healthy flushes step back up."""
    sched = FaultSchedule(fixed={0: "engine_raise", 1: "engine_raise"})
    srv = _fast_server(index, max_retries=1, probe_interval=2,
                       engine_wrapper=lambda e: FaultyEngine(e, sched))
    s, t, wl = random_queries(graph, 6, seed=9)
    got = srv.query_many(s, t, wl)              # raise, retry-raise, demote
    assert np.array_equal(got, index.query_batch(s, t, wl))
    assert srv.stats.error_retries == 1 and srv.stats.exhausted == 1
    assert srv.stats.demotions == 1 and srv.mode == "bucket_pair"
    # answers carry the mode that produced them
    rid = srv.submit(int(s[0]) ^ 1, int(t[0]) ^ 1, int(wl[0]))
    val, mode = srv.result_with_mode(rid)
    assert mode == "bucket_pair"
    # two clean drains later the server probes its way back up
    for i in range(4):
        srv.submit(2 * i, 2 * i + 1, 1)
        srv.flush()
    assert srv.stats.promotions >= 1 and srv.mode == "primary"


def test_exhausted_bottom_rung_requeues_and_preserves_piggybacks(index):
    """FlushRetryExhausted at the bottom of the ladder (an engine= server
    has none): the batch goes back to the FRONT of the pending queue with
    its piggyback rids intact — nothing lost, nothing double-delivered."""
    from repro.core.query import DeviceQueryEngine

    eng = DeviceQueryEngine(index, layout="csr")
    calls = {"n": 0}

    class Flaky:
        layout = "csr"

        def __getattr__(self, name):
            return getattr(eng, name)

        def query(self, s, t, w):
            calls["n"] += 1
            if calls["n"] <= 2:
                raise InjectedEngineError("dead collective")
            return eng.query(s, t, w)

        query_async = None                      # force the blocking path

    srv = WCSDServer(engine=Flaky(), max_batch=1024, max_retries=1,
                     backoff_base_ms=0.01)
    r1 = srv.submit(3, 9, 1)
    r2 = srv.submit(9, 3, 1)                    # piggybacks on r1's slot
    assert srv.stats.memo_hits == 1 and len(srv.pending) == 1
    with pytest.raises(FlushRetryExhausted):
        srv.flush()
    assert len(srv.pending) == 1                # requeued, still one slot
    assert srv._pending_rids == {r1, r2}        # piggyback survived
    a, b = srv.result(r1), srv.result(r2)       # result() retries the flush
    assert a is not None and a == b
    for rid in (r1, r2):                        # read-once: no double copy
        with pytest.raises(UnknownRequestError):
            srv.result(rid)


def test_poll_mid_retry_is_a_noop(graph, index):
    """Regression (half-retried slot): a poll() issued re-entrantly while
    the watchdog is re-dispatching a timed-out batch must NOT harvest the
    abandoned handle or dispatch the queued next batch over the retry —
    before the ``_retrying`` guard this meddling poll dispatched batch B
    mid-retry (stats.batches moved) and could deliver from a dead handle."""
    srv = _fast_server(index, flush_timeout_ms=30.0, max_retries=3)
    real = srv.engine
    calls = {"n": 0}
    seen = {}

    class Meddler:
        def __getattr__(self, name):
            return getattr(real, name)

        def query_async(self, s, t, w):
            calls["n"] += 1
            if calls["n"] == 1:                 # first dispatch: wedge it
                return _HangingResult(real.query_async(s, t, w))
            if calls["n"] == 2:                 # the watchdog's redispatch
                seen["batches_before"] = srv.stats.batches
                seen["pending_before"] = len(srv.pending)
                srv.poll()                      # re-entrant tick mid-retry
                seen["batches_after"] = srv.stats.batches
                seen["pending_after"] = len(srv.pending)
            return real.query_async(s, t, w)

    srv.engine = Meddler()
    rids_a = [srv.submit(i, i + 11, 1) for i in range(3)]
    srv.flush_async()                           # batch A in flight (hung)
    rids_b = [srv.submit(i + 20, i + 5, 0) for i in range(2)]
    srv.flush()                                 # timeout -> redispatch
    # the meddling poll did nothing: no nested dispatch, queue untouched
    assert seen["batches_after"] == seen["batches_before"]
    assert seen["pending_after"] == seen["pending_before"] == 2
    assert srv.stats.timeout_retries == 1
    got = [srv.result(r) for r in rids_a + rids_b]
    assert all(v is not None for v in got)      # delivered exactly once
    for r in rids_a + rids_b:
        with pytest.raises(UnknownRequestError):
            srv.result(r)


# --------------------------------------------------------------------- WAL
def test_wal_round_trip_and_reopen(tmp_path):
    p = str(tmp_path / "u.wal")
    wal = UpdateWAL(p, base_version=3)
    assert wal.base_version() == 3 and wal.records() == []
    wal.append(inserts=[(0, 5, 1.0)], graph_version=4)
    wal.append(deletes=[(2, 7)], graph_version=5)
    recs = wal.records()
    assert [r["graph_version"] for r in recs] == [4, 5]
    assert recs[0]["inserts"] == [[0, 5, 1.0]] and recs[0]["deletes"] == []
    assert recs[1]["deletes"] == [[2, 7]]
    # reopening an existing log must NOT reset it
    wal2 = UpdateWAL(p, base_version=0)
    assert wal2.base_version() == 3
    assert [r["graph_version"] for r in wal2.records()] == [4, 5]
    # replay from a mid-log checkpoint skips the already-applied prefix
    assert [r["graph_version"] for r in wal2.replay(4)] == [5]


def test_wal_torn_tail_drops_only_the_uncommitted_record(tmp_path):
    p = str(tmp_path / "u.wal")
    wal = UpdateWAL(p, base_version=0)
    for v in (1, 2, 3):
        wal.append(inserts=[(v, v + 1, 0.0)], graph_version=v)
    tear_file_tail(p, 5)                        # rip into record 3
    assert [r["graph_version"] for r in wal.records()] == [1, 2]
    # garbage appended after the committed prefix is equally invisible
    with open(p, "ab") as f:
        f.write(b"\x99\x00\x00\x00\xde\xad")
    assert [r["graph_version"] for r in wal.records()] == [1, 2]
    # and a fresh append after the tear re-commits cleanly on top
    wal.truncate(2)
    wal.append(inserts=[(9, 1, 0.0)], graph_version=3)
    assert [r["graph_version"] for r in wal.records()] == [3]


def test_wal_crash_mid_append_is_a_torn_tail(tmp_path):
    p = str(tmp_path / "u.wal")
    UpdateWAL(p, base_version=0).append(inserts=[(1, 2, 0.0)],
                                        graph_version=1)
    from repro.checkpoint.fault import MidWriteCrash
    torn = UpdateWAL(p, _open=crashing_open(6))  # dies 6 bytes into rec 2
    with pytest.raises(MidWriteCrash):
        torn.append(inserts=[(3, 4, 0.0)], graph_version=2)
    assert [r["graph_version"] for r in UpdateWAL(p).records()] == [1]


def test_wal_sequence_gap_is_a_typed_error(tmp_path):
    p = str(tmp_path / "u.wal")
    wal = UpdateWAL(p, base_version=0)
    wal.append(graph_version=1)
    wal.append(graph_version=3)                 # hole: v2 never logged
    with pytest.raises(WALError, match="sequence gap"):
        wal.records()


def test_wal_replay_refuses_compacted_past_checkpoint(tmp_path):
    p = str(tmp_path / "u.wal")
    wal = UpdateWAL(p, base_version=0)
    for v in (1, 2, 3):
        wal.append(graph_version=v)
    wal.truncate(3)                             # compaction folded 1..3 in
    assert wal.base_version() == 3 and wal.records() == []
    with pytest.raises(WALReplayError, match="compacted past"):
        wal.replay(1)                           # stale checkpoint at v1
    assert wal.replay(3) == []                  # current checkpoint is fine
    assert issubclass(WALReplayError, WALError)


def test_wal_rejects_foreign_file(tmp_path):
    p = str(tmp_path / "not.wal")
    with open(p, "wb") as f:
        f.write(b"something else entirely")
    with pytest.raises(WALError, match="not a WCSD WAL"):
        UpdateWAL(p).records()


# ------------------------------------------------------- chaos acceptance
def test_chaos_schedule_with_crash_recovers(tmp_path):
    """The ISSUE's acceptance run: >= 200 seeded steps mixing submits,
    profile submits, updates, injected raises/hangs/bit-flips/torn WAL
    tails, and one mid-update crash answered by a checkpoint + WAL-replay
    warm restart. Every answer is differentially checked against the BFS
    oracle inside the harness; here the run-level invariants."""
    s = run_chaos_schedule(steps=200, seed=3, crash_step=100,
                           workdir=str(tmp_path))
    assert s["submitted"] == s["answered"]      # nothing lost or doubled
    assert s["final_mode"] == "primary"         # back at the top rung
    assert s["crashes"] == 1 and s["replayed_records"] >= 1
    assert s["injected"] > 0                    # faults actually fired
    assert s["error_retries"] >= 1 and s["timeout_retries"] >= 1
    assert s["exhausted"] >= 1 and s["demotions"] >= 1
    assert s["integrity_probes"] >= 1 and s["wal_probes"] >= 1
    assert s["updates"] == s["wal_appends"] >= 1


def test_chaos_schedule_is_seed_deterministic(tmp_path):
    """Same seed -> same schedule: the summary (counters included) must
    replay identically, so a chaos failure is reproducible by seed."""
    a = run_chaos_schedule(steps=60, seed=11, workdir=str(tmp_path / "a"))
    b = run_chaos_schedule(steps=60, seed=11, workdir=str(tmp_path / "b"))
    assert a == b
    assert a["submitted"] == a["answered"] and a["final_mode"] == "primary"

"""CompressedArena property + adversarial suite (docs/index-format.md §6).

Four guarantees, each pinned by its own tests:

  1. Round-trip: compress -> decode reproduces the uncompressed
     `LabelArena` tile for tile on real stores (hypothesis property over
     random graphs; hop distances sit in bfloat16's exact-integer range,
     so even the float leg is bit-exact here).
  2. Hub ids and quality levels are ALWAYS bit-exact — including
     adversarial hub-rank gaps right at the int16 delta boundary.
  3. Overflow is flagged, never silent: a tile the narrow format cannot
     hold goes verbatim into the int32 side tables, `decode` restores it
     exactly, and the engines refuse `compressed=True` for that store
     (``compressed is False``, ``compression_overflow is True``) while
     still answering bit-identically from the uncompressed arena.
  4. The documented distance bound holds against the int32 oracle:
     bfloat16 exact <= 256 / relative error <= 2^-8 beyond; float16
     exact <= 2048 / relative error <= 2^-11 beyond (up to its 65000
     finite headroom, past which the tile overflows instead).
"""
import numpy as np
import pytest
from _hypo_shim import given, settings, st  # hypothesis or fallback

from repro.core.baselines import constrained_distance_grid
from repro.core.generators import erdos_renyi
from repro.core.query import DeviceQueryEngine, ShardedQueryEngine
from repro.core.wc_index import (INF_DIST, CompressedArena, PackedLabels,
                                 PackedWCIndex, build_wc_index)

_I16_MAX = np.iinfo(np.int16).max   # 32767, the hub-delta ceiling
_I8_MAX = np.iinfo(np.int8).max     # 127, the wlev ceiling


def _assert_arenas_equal(got, exp):
    np.testing.assert_array_equal(got.hub, exp.hub)
    np.testing.assert_array_equal(got.dist, exp.dist)
    np.testing.assert_array_equal(got.wlev, exp.wlev)
    np.testing.assert_array_equal(got.tile_base, exp.tile_base)
    np.testing.assert_array_equal(got.tile_cnt, exp.tile_cnt)
    np.testing.assert_array_equal(got.tile_lo, exp.tile_lo)
    np.testing.assert_array_equal(got.tile_hi, exp.tile_hi)


def _full_grid(V, W):
    s, t, w = np.meshgrid(np.arange(V), np.arange(V), np.arange(W + 1),
                          indexing="ij")
    return (s.ravel().astype(np.int32), t.ravel().astype(np.int32),
            w.ravel().astype(np.int32))


# ------------------------------------------------------------- round-trip
@pytest.mark.parametrize("lane", [128, 8])
@given(st.sampled_from([8, 12, 20]), st.sampled_from([2.5, 4.0]),
       st.sampled_from([2, 4]), st.integers(0, 100_000))
@settings(max_examples=20, deadline=None, derandomize=True)
def test_roundtrip_property_on_real_stores(lane, n, deg, levels, seed):
    """compress -> decode == the uncompressed arena, tile for tile, for
    both float formats. lane=8 forces multi-tile rows so tile_lo deltas
    are exercised across tile boundaries, not just at offset 0."""
    idx = build_wc_index(erdos_renyi(n, deg, num_levels=levels, seed=seed))
    packed = idx.packed(lane=lane)
    ar = packed.arena(lane=lane)
    for dtype in ("bfloat16", "float16"):
        comp = CompressedArena.from_arena(ar, dtype=dtype)
        assert comp.num_overflow_tiles == 0
        _assert_arenas_equal(comp.decode(), ar)
    # the per-store cache hands back the same object per (lane, dtype)
    assert packed.compressed_arena(lane=lane) is \
        packed.compressed_arena(lane=lane)


# ------------------------------------------- adversarial int16 delta gaps
def _gap_store(gap: int, lane: int = 8, extra_wlev: int = 2):
    """Two vertices sharing hub ranks {0, gap}: one tile per row, so the
    in-tile hub delta IS the gap. Rows stay hub-sorted (invariant I1)."""
    hub = np.array([0, gap, 0, gap], np.int32)
    dist = np.array([3, 5, 4, 6], np.int32)
    wlev = np.array([extra_wlev, 1, extra_wlev, 1], np.int32)
    offsets = np.array([0, 2, 4], np.int64)
    return PackedLabels.from_flat(hub, dist, wlev, offsets, lane=lane)


@given(st.integers(_I16_MAX - 600, _I16_MAX + 600))
@settings(max_examples=25, deadline=None, derandomize=True)
def test_delta_boundary_flags_exactly_past_int16(gap):
    """Hub gaps straddling 32767: delta == int16 max still compresses;
    one past it flags the tile — and decode is exact on BOTH sides."""
    packed = _gap_store(gap)
    ar = packed.arena(lane=8)
    comp = CompressedArena.from_arena(ar)
    if gap > _I16_MAX:
        assert comp.num_overflow_tiles == ar.num_tiles  # every tile gaps
        assert comp.overflow.all()
    else:
        assert comp.num_overflow_tiles == 0
        # the widest representable delta really is stored as a delta
        assert int(comp.hub_delta.max()) == gap
    _assert_arenas_equal(comp.decode(), ar)


def test_wlev_and_fp16_range_overflow_are_flagged():
    """The other two overflow triggers: a quality level past int8, and
    (float16 only) a finite distance past the format's headroom."""
    ar_w = _gap_store(5, extra_wlev=_I8_MAX + 1).arena(lane=8)
    comp_w = CompressedArena.from_arena(ar_w)
    assert comp_w.num_overflow_tiles == ar_w.num_tiles
    _assert_arenas_equal(comp_w.decode(), ar_w)

    hub = np.array([0, 1], np.int32)
    dist = np.array([70_000, 2], np.int32)      # finite, > 65000
    wlev = np.array([1, 1], np.int32)
    packed = PackedLabels.from_flat(hub, dist, wlev,
                                    np.array([0, 2], np.int64), lane=8)
    ar = packed.arena(lane=8)
    assert CompressedArena.from_arena(ar, dtype="bfloat16") \
        .num_overflow_tiles == 0                # bf16 range is fine
    comp16 = CompressedArena.from_arena(ar, dtype="float16")
    assert comp16.num_overflow_tiles == 1
    _assert_arenas_equal(comp16.decode(), ar)

    with pytest.raises(ValueError, match="dtype"):
        CompressedArena.from_arena(ar, dtype="float32")


def test_overflow_store_is_served_uncompressed_and_flagged():
    """An engine asked for compressed=True on an overflowing store must
    NOT silently corrupt hub ids: it serves the uncompressed arena and
    says so via ``compression_overflow``. Answers stay bit-identical."""
    gap = _I16_MAX + 10
    packed = _gap_store(gap)
    pidx = PackedWCIndex(order=np.arange(2, dtype=np.int64),
                         rank=np.arange(2, dtype=np.int64),
                         levels=np.array([1.0, 2.0, 3.0]), labels=packed)
    s, t, wl = _full_grid(2, pidx.num_levels)
    kw = dict(layout="csr", dispatch="ragged", use_pallas=True,
              interpret=True, lane=8)
    plain = DeviceQueryEngine(pidx, **kw)
    eng = DeviceQueryEngine(pidx, compressed=True, **kw)
    assert eng.compressed is False
    assert eng.compression_overflow is True
    np.testing.assert_array_equal(np.asarray(eng.query(s, t, wl)),
                                  np.asarray(plain.query(s, t, wl)))
    np.testing.assert_array_equal(np.asarray(eng.query_profile(s, t)),
                                  np.asarray(plain.query_profile(s, t)))
    # sanity: both hubs are joinable, so the gap actually matters
    assert int(np.asarray(plain.query(
        np.array([0], np.int32), np.array([1], np.int32),
        np.array([0], np.int32)))[0]) == 7

    from repro.launch.mesh import make_serving_mesh
    sh = ShardedQueryEngine(pidx, mesh=make_serving_mesh(),
                            compressed=True, device_budget_bytes=1, **kw)
    assert sh.compressed is False and sh.compression_overflow is True
    np.testing.assert_array_equal(np.asarray(sh.query(s, t, wl)),
                                  np.asarray(plain.query(s, t, wl)))


def test_compressed_requires_csr_ragged():
    idx = build_wc_index(erdos_renyi(8, 2.5, num_levels=2, seed=3))
    with pytest.raises(ValueError, match="csr"):
        DeviceQueryEngine(idx, layout="padded", compressed=True)
    with pytest.raises(ValueError, match="csr"):
        DeviceQueryEngine(idx, layout="csr", dispatch="bucket_pair",
                          compressed=True)


# ----------------------------------------------- documented distance bound
def _dist_store(dists: np.ndarray, lane: int = 16) -> PackedLabels:
    n = len(dists)
    hub = np.arange(n, dtype=np.int32)          # hub-sorted single row
    wlev = np.ones(n, dtype=np.int32)
    return PackedLabels.from_flat(hub, dists.astype(np.int32), wlev,
                                  np.array([0, n], np.int64), lane=lane)


@pytest.mark.parametrize("dtype,exact_to,rel_bound,dmax", [
    ("bfloat16", 256, 2.0 ** -8, 1_000_000),
    ("float16", 2048, 2.0 ** -11, 60_000),
])
def test_documented_distance_bound_vs_int32_oracle(dtype, exact_to,
                                                   rel_bound, dmax):
    """The docstring's precision claim, asserted: distances <= exact_to
    round-trip bit-exactly; beyond that the decoded value stays within
    rel_bound of the int32 oracle. INF_DIST always survives exactly."""
    rng = np.random.default_rng(7)
    dists = np.concatenate([
        np.arange(exact_to + 1),                       # the exact range
        rng.integers(exact_to + 1, dmax, 4096),        # the rounded range
        [INF_DIST],                                    # no-path sentinel
    ]).astype(np.int64)
    packed = _dist_store(dists)
    ar = packed.arena(lane=16)
    comp = CompressedArena.from_arena(ar, dtype=dtype)
    assert comp.num_overflow_tiles == 0
    dec = comp.decode()
    np.testing.assert_array_equal(dec.hub, ar.hub)     # ids: always exact
    np.testing.assert_array_equal(dec.wlev, ar.wlev)
    real = ar.hub >= 0
    orig = ar.dist[real].astype(np.int64)
    got = dec.dist[real].astype(np.int64)
    inf = orig == INF_DIST
    np.testing.assert_array_equal(got[inf], orig[inf])
    small = ~inf & (orig <= exact_to)
    np.testing.assert_array_equal(got[small], orig[small])
    big = ~inf & (orig > exact_to)
    assert big.any()
    err = np.abs(got[big] - orig[big])
    assert (err <= orig[big] * rel_bound).all(), int(err.max())


# --------------------------------------------------------- bytes-per-row
def test_memory_ratio_beats_1p8x():
    """The capacity claim behind ``device_budget_bytes``: the compressed
    store holds >= 1.8x the rows per byte of the int32 arena (per-cell
    the encoding is 12 -> 5 bytes; shared index tables dilute it)."""
    from benchmarks.bench_wcsd import make_skewed_store
    pidx, _ = make_skewed_store(lane=32, rng=np.random.default_rng(11))
    packed = pidx.packed(lane=32)
    ratio = packed.arena(lane=32).memory_bytes() \
        / packed.compressed_arena(lane=32).memory_bytes()
    assert ratio >= 1.8, ratio
    assert packed.compressed_arena(lane=32).num_overflow_tiles == 0


# ------------------------------------------------- end-to-end engine legs
@pytest.mark.parametrize("use_pallas", [True, False])
def test_compressed_engine_matches_uncompressed_and_bfs(use_pallas):
    """Full (s, t, w) grid + profiles on a real graph: the compressed
    device engine (kernel and jnp decode paths) == uncompressed == BFS.
    Hop distances < 256 here, so bf16 makes this bit-exact, not approx."""
    g = erdos_renyi(12, 3.5, num_levels=3, seed=41)
    idx = build_wc_index(g)
    s, t, wl = _full_grid(g.num_nodes, g.num_levels)
    exp = constrained_distance_grid(g)[s, t, wl]
    kw = dict(layout="csr", dispatch="ragged", use_pallas=use_pallas,
              interpret=True, lane=16)
    eng = DeviceQueryEngine(idx, compressed=True, **kw)
    assert eng.compressed is True and eng.compression_overflow is False
    np.testing.assert_array_equal(np.asarray(eng.query(s, t, wl)), exp)
    plain = DeviceQueryEngine(idx, **kw)
    np.testing.assert_array_equal(np.asarray(eng.query_profile(s, t)),
                                  np.asarray(plain.query_profile(s, t)))

import numpy as np
import pytest

from repro.core.generators import road_grid, scale_free
from repro.core.ordering import (degree_order, hybrid_order, make_order,
                                 mde_elimination, tree_decomposition_order)
from repro.core.wc_index import build_wc_index


def test_orders_are_permutations():
    g = scale_free(100, 3, num_levels=3, seed=1)
    for name in ["degree", "treedec", "hybrid"]:
        o = make_order(g, name)
        assert sorted(o.tolist()) == list(range(g.num_nodes))


def test_degree_order_monotone():
    g = scale_free(100, 3, num_levels=3, seed=2)
    o = degree_order(g)
    deg = g.degree()
    assert np.all(np.diff(deg[o]) <= 0)


def test_mde_restricted_elimination():
    g = road_grid(6, 6, num_levels=3, seed=3)
    allowed = np.zeros(g.num_nodes, dtype=bool)
    allowed[:18] = True
    seq = mde_elimination(g, eliminate=allowed)
    assert set(seq.tolist()) <= set(range(18))
    assert len(seq) == 18


def test_paper_observation_2_3_ordering_effect():
    """Obs. 2/3: tree decomposition wins on road-like graphs, degree wins on
    scale-free graphs (index-size proxy)."""
    road = road_grid(12, 12, num_levels=4, seed=4)
    ba = scale_free(300, 3, num_levels=4, seed=4)
    road_deg = build_wc_index(road, ordering="degree").size_entries()
    road_td = build_wc_index(road, ordering="treedec").size_entries()
    ba_deg = build_wc_index(ba, ordering="degree").size_entries()
    ba_td = build_wc_index(ba, ordering="treedec").size_entries()
    assert road_td < road_deg
    assert ba_deg < ba_td


def test_hybrid_between_extremes_on_scale_free():
    g = scale_free(300, 3, num_levels=4, seed=5)
    sizes = {o: build_wc_index(g, ordering=o).size_entries()
             for o in ["degree", "treedec", "hybrid"]}
    assert sizes["hybrid"] <= sizes["treedec"]

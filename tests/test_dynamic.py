"""Dynamic-index differential harness: serving must stay EXACT through
arbitrary interleavings of edge inserts, deletes, queries and compactions.

The headline schedule-replay harness generates 200 randomized instances
(deterministic under the `_hypo_shim` fallback), each a small random graph
plus a random update/compact schedule, and after EVERY mutation checks the
full (s, t, w_level) grid three ways:

  dynamic engine over the delta-extended store   (the system under test)
  a from-scratch `build_wc_index_batched_packed` rebuild on the mutated
  graph, queried via the host sort-merge          (the rebuild oracle)
  the per-level BFS sweep                         (structurally independent)

Coverage: 6 in-process blocks x 25 examples run the single-device engine
modes (padded, csr ragged, csr ragged compressed, csr bucket_pair, and the
dynamic `WCSDServer` surface incl. staleness flags), and one 8-virtual-
device subprocess runs 2 blocks x 25 through `ShardedQueryEngine` in
replicated AND row-sharded (`device_budget_bytes=1`) modes, compressed
alternating — 6 * 25 + 50 = 200 instances.

Also here: the compaction-equivalence property test (`compact()` output
byte-identical to a from-scratch packed build on the mutated graph — the
PR 2 pack-after-build lock extended to dynamic stores), persistence
round-trip + fault-injection tests (truncated file, corrupted magic,
version mismatch, mid-write crash), `mutate_edges` unit tests, and the
`built_indices` version-keyed-cache regression test.
"""
import dataclasses
import json
import os
import shutil
import subprocess
import sys

import numpy as np
import pytest
from _hypo_shim import given, settings, st  # hypothesis or fallback

from repro.checkpoint.ckpt import (IndexHeaderError, IndexPersistenceError,
                                   IndexTruncatedError, IndexVersionError,
                                   WCX_MAGIC, load_packed_index,
                                   save_packed_index)
from repro.checkpoint.fault import MidWriteCrash, crashing_open
from repro.core.baselines import constrained_distance_grid
from repro.core.generators import erdos_renyi
from repro.core.graph import Graph, mutate_edges
from repro.core.query import DeviceQueryEngine
from repro.core.resilience import UnknownRequestError
from repro.core.serve import WCSDServer
from repro.core.wc_index import DynamicWCIndex, build_wc_index
from repro.core.wc_index_batched import (affected_vertices,
                                         build_wc_index_batched_packed,
                                         rebuild_affected_rows)

# one build config shared by the base build, `compact()` and the rebuild
# oracle, so compaction equivalence is a pure byte comparison
BUILD_KW = dict(ordering="degree", batch_size=16, use_kernel=False)

N_BLOCKS = 6
EXAMPLES_PER_BLOCK = 25
N_SHARDED = 50          # subprocess instances; total = 6 * 25 + 50 = 200
_instances_run = [0]


def _full_grid(V, W):
    s, t, w = np.meshgrid(np.arange(V), np.arange(V), np.arange(W + 1),
                          indexing="ij")
    return (s.ravel().astype(np.int32), t.ravel().astype(np.int32),
            w.ravel().astype(np.int32))


def _random_mutation(rng, g):
    """One randomized update batch: 1-2 inserts/deletes over ``g``."""
    inserts, deletes = [], []
    for _ in range(int(rng.integers(1, 3))):
        half = np.flatnonzero(g.edges_src < g.edges_dst)
        if rng.random() < 0.45 and len(half):
            e = int(rng.choice(half))
            deletes.append((int(g.edges_src[e]), int(g.edges_dst[e])))
        else:
            u, v = (int(x) for x in rng.choice(g.num_nodes, 2, replace=False))
            inserts.append((u, v, float(rng.choice(g.levels))))
    return inserts, deletes


def _check_exact(answer_fn, g, tag):
    """Full-grid equality vs the BFS sweep AND the from-scratch rebuild."""
    V, W = g.num_nodes, g.num_levels
    s, t, wl = _full_grid(V, W)
    exp = constrained_distance_grid(g)[s, t, wl]
    got = np.asarray(answer_fn(s, t, wl))
    np.testing.assert_array_equal(got, exp, err_msg=tag)
    oracle, _ = build_wc_index_batched_packed(g, **BUILD_KW)
    reb = np.array([oracle.query_one(int(a), int(b), int(c))
                    for a, b, c in zip(s, t, wl)], dtype=np.int32)
    np.testing.assert_array_equal(got, reb, err_msg=tag + " vs rebuild")


# mode per block: layout/dispatch/compressed/kernel and whether the
# schedule drives a DeviceQueryEngine directly or the WCSDServer surface
_MODES = [
    dict(layout="padded", dispatch="ragged", compressed=False,
         use_pallas=False, server=False),
    dict(layout="csr", dispatch="ragged", compressed=False,
         use_pallas=True, server=False),
    dict(layout="csr", dispatch="ragged", compressed=True,
         use_pallas=True, server=False),
    dict(layout="csr", dispatch="bucket_pair", compressed=False,
         use_pallas=True, server=False),
    dict(layout="csr", dispatch="ragged", compressed=False,
         use_pallas=False, server=True),
    dict(layout="padded", dispatch="ragged", compressed=False,
         use_pallas=False, server=True),
]


@pytest.mark.parametrize("block", range(N_BLOCKS))
@given(st.sampled_from([8, 10, 12]), st.sampled_from([2.5, 3.5, 4.5]),
       st.sampled_from([2, 3]), st.integers(0, 100_000))
@settings(max_examples=EXAMPLES_PER_BLOCK, deadline=None, derandomize=True)
def test_schedule_replay_differential(block, n, deg, levels, seed):
    mode = _MODES[block]
    rng = np.random.default_rng(seed + 15485863 * block)
    g = erdos_renyi(n, deg, num_levels=levels, seed=seed + 7919 * block)
    idx, _ = build_wc_index_batched_packed(g, **BUILD_KW)

    if mode["server"]:
        srv = WCSDServer(idx, graph=g, layout=mode["layout"],
                         dispatch=mode["dispatch"],
                         compressed=mode["compressed"],
                         use_pallas=mode["use_pallas"], interpret=True,
                         max_batch=2048, compact_threshold=None,
                         compact_kwargs=BUILD_KW)
        target = srv
        answer = srv.query_many
    else:
        target = DynamicWCIndex(idx, g)

        lane_kw = {"lane": 16} if mode["layout"] == "csr" else {}

        def answer(s, t, wl):
            eng = DeviceQueryEngine(target, layout=mode["layout"],
                                    dispatch=mode["dispatch"],
                                    compressed=mode["compressed"],
                                    use_pallas=mode["use_pallas"],
                                    interpret=True, **lane_kw)
            return eng.query(s, t, wl)

    n_ops = int(rng.integers(2, 4))
    for op in range(n_ops):
        gcur = target.graph if not mode["server"] else target.index.graph
        inserts, deletes = _random_mutation(rng, gcur)
        target.apply_updates(inserts=inserts, deletes=deletes)
        gcur = target.graph if not mode["server"] else target.index.graph
        _check_exact(answer, gcur, f"block={block} op={op} after update")
        if rng.random() < 0.3:
            target.compact(**({} if mode["server"] else BUILD_KW))
            dyn = target if not mode["server"] else target.index
            assert dyn.delta.is_empty()
            _check_exact(answer, gcur, f"block={block} op={op} after compact")
    _instances_run[0] += 1


def test_differential_coverage_target():
    """Acceptance: harness configured for >= 200 generated instances
    (6 x 25 in-process + 50 sharded in the subprocess leg below)."""
    assert N_BLOCKS * EXAMPLES_PER_BLOCK + N_SHARDED >= 200
    if _instances_run[0]:
        assert _instances_run[0] % EXAMPLES_PER_BLOCK == 0


# ------------------------------------------- sharded modes (8 devices)
_SHARDED_DYNAMIC_PROG = r'''
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_compilation_cache_dir",
                  tempfile.mkdtemp(prefix="wcsd-dyn-cache"))
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
import numpy as np
from repro.core.baselines import constrained_distance_grid
from repro.core.generators import erdos_renyi
from repro.core.query import ShardedQueryEngine
from repro.core.wc_index import DynamicWCIndex
from repro.core.wc_index_batched import build_wc_index_batched_packed
from repro.launch.mesh import make_serving_mesh

assert len(jax.devices()) == 8
mesh = make_serving_mesh()
BUILD_KW = dict(ordering="degree", batch_size=16, use_kernel=False)
N = 50
ran = 0
rng = np.random.default_rng(20260808)
for i in range(N):
    n = [8, 10, 12][int(rng.integers(3))]
    deg = [2.5, 3.5, 4.5][int(rng.integers(3))]
    levels = [2, 3][int(rng.integers(2))]
    g = erdos_renyi(n, deg, num_levels=levels,
                    seed=int(rng.integers(0, 100_001)))
    idx, _ = build_wc_index_batched_packed(g, **BUILD_KW)
    dyn = DynamicWCIndex(idx, g)
    # replicated on even instances, row-sharded labels on odd; compressed
    # alternating independently
    budget = None if i % 2 == 0 else 1
    compressed = i % 4 < 2
    for op in range(2):
        gcur = dyn.graph
        inserts, deletes = [], []
        half = np.flatnonzero(gcur.edges_src < gcur.edges_dst)
        if rng.random() < 0.45 and len(half):
            e = int(rng.choice(half))
            deletes.append((int(gcur.edges_src[e]), int(gcur.edges_dst[e])))
        else:
            u, v = (int(x) for x in
                    rng.choice(gcur.num_nodes, 2, replace=False))
            inserts.append((u, v, float(rng.choice(gcur.levels))))
        dyn.apply_updates(inserts=inserts, deletes=deletes)
        if op == 1 and i % 5 == 0:
            dyn.compact(**BUILD_KW)
            assert dyn.delta.is_empty()
        g2 = dyn.graph
        V, W = g2.num_nodes, g2.num_levels
        s, t, w = np.meshgrid(np.arange(V), np.arange(V), np.arange(W + 1),
                              indexing="ij")
        s, t, w = (a.ravel().astype(np.int32) for a in (s, t, w))
        D = constrained_distance_grid(g2)
        exp = D[s, t, w]
        eng = ShardedQueryEngine(
            dyn, mesh=mesh, layout="csr", dispatch="ragged",
            device_budget_bytes=budget, use_pallas=(ran % 7 == 0),
            interpret=True, compressed=compressed)
        assert eng.mode == ("replicated" if budget is None
                            else "sharded_labels")
        np.testing.assert_array_equal(np.asarray(eng.query(s, t, w)), exp)
        ps, pt = s[::W + 1], t[::W + 1]
        np.testing.assert_array_equal(
            np.asarray(eng.query_profile(ps, pt)), D[ps, pt, :])
        # rebuild-oracle identity, not just BFS agreement
        oracle, _ = build_wc_index_batched_packed(g2, **BUILD_KW)
        reb = np.array([oracle.query_one(int(a), int(b), int(c))
                        for a, b, c in zip(s, t, w)], dtype=np.int32)
        np.testing.assert_array_equal(np.asarray(eng.query(s, t, w)), reb)
    ran += 1
assert ran == N == 50
print(f"OK sharded dynamic {ran} instances")
'''


def test_sharded_dynamic_differential_on_8_devices():
    """Replicated AND row-sharded `ShardedQueryEngine` over the delta-
    extended store, compressed alternating, on 8 virtual devices: 50
    schedule-replay instances, every answer bit-identical to the BFS sweep
    and the from-scratch rebuild (query + profile)."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = {**os.environ, "PYTHONPATH": src, "JAX_PLATFORMS": "cpu"}
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SHARDED_DYNAMIC_PROG],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "OK sharded dynamic 50 instances" in r.stdout


# --------------------------------------------------- compaction equivalence
@given(st.integers(0, 100_000))
@settings(max_examples=10, deadline=None, derandomize=True)
def test_compact_byte_identical_to_fresh_build(seed):
    """For any update schedule, `compact()` leaves the dynamic index's base
    store byte-identical to `build_wc_index_batched_packed` on the mutated
    graph — every CSR array AND the bucket routing tables (extends the
    PR 2 pack-after-build lock to dynamic stores)."""
    rng = np.random.default_rng(seed)
    g = erdos_renyi(int(rng.integers(10, 30)), 3.0, num_levels=3,
                    seed=seed + 13)
    idx, _ = build_wc_index_batched_packed(g, **BUILD_KW)
    dyn = DynamicWCIndex(idx, g)
    for _ in range(int(rng.integers(1, 4))):
        inserts, deletes = _random_mutation(rng, dyn.graph)
        dyn.apply_updates(inserts=inserts, deletes=deletes)
    dyn.compact(**BUILD_KW)
    ref, _ = build_wc_index_batched_packed(dyn.graph, **BUILD_KW)
    np.testing.assert_array_equal(dyn.base.order, ref.order)
    np.testing.assert_array_equal(dyn.base.rank, ref.rank)
    for field in ("hub_rank", "dist", "wlev", "offsets", "bucket_widths",
                  "bucket_of", "slot_of"):
        np.testing.assert_array_equal(getattr(dyn.base.labels, field),
                                      getattr(ref.labels, field), field)
    assert dyn.delta.is_empty() and dyn.delta_ratio() == 0.0


def test_delta_store_accounting():
    """Delta bookkeeping: corrections/tombstones count the symmetric
    difference vs the base store, rows identical to base drop out, and
    `delta_ratio` drives the server's auto-compaction trigger."""
    # sequential-built base: the incremental recompute IS the sequential
    # loop, so undoing an update drains every corrected row back to its
    # base row (the batched-built base keeps deferred-prune extras the
    # sequential recompute drops, so its delta only shrinks, not empties)
    g = erdos_renyi(30, 3.0, num_levels=3, seed=4)
    idx = build_wc_index(g, ordering="degree")
    dyn = DynamicWCIndex(idx, g)
    assert dyn.delta.is_empty() and dyn.delta_ratio() == 0.0
    u, v = int(g.edges_src[0]), int(g.edges_dst[0])
    dyn.apply_updates(deletes=[(u, v)])
    assert not dyn.delta.is_empty()
    assert dyn.delta.delta_entries() > 0
    lvl = float(g.levels[int(g.edges_level[0])])
    dyn.apply_updates(inserts=[(u, v, lvl)])
    assert dyn.delta.is_empty()
    assert dyn.graph_version == 2  # version still advances monotonically

    # auto-compaction: a tiny threshold triggers on the first update
    g2 = erdos_renyi(20, 3.0, num_levels=3, seed=5)
    idx2, _ = build_wc_index_batched_packed(g2, **BUILD_KW)
    srv = WCSDServer(idx2, graph=g2, layout="csr", interpret=True,
                     compact_threshold=1e-9, compact_kwargs=BUILD_KW)
    stats = srv.apply_updates(
        deletes=[(int(g2.edges_src[0]), int(g2.edges_dst[0]))])
    assert stats["compacted"] is True
    assert srv.index.delta.is_empty()


# ------------------------------------------------------- server semantics
def test_server_staleness_flags():
    """Answers computed against an older graph version read back stale;
    post-update answers do not. The staleness stamp survives the memo."""
    g = erdos_renyi(24, 3.0, num_levels=3, seed=11)
    idx, _ = build_wc_index_batched_packed(g, **BUILD_KW)
    srv = WCSDServer(idx, graph=g, layout="csr", interpret=True,
                     max_batch=512, compact_threshold=None,
                     compact_kwargs=BUILD_KW)
    r_old = srv.submit(0, 5, 1)
    p_old = srv.submit_profile(1, 6)
    assert srv.graph_version == 0
    srv.apply_updates(inserts=[(0, 5, float(g.levels[0]))])
    assert srv.graph_version == 1
    _, stale = srv.result_with_staleness(r_old)
    assert stale is True
    prof, pstale = srv.profile_result_with_staleness(p_old)
    assert pstale is True and prof is not None
    r_new = srv.submit(0, 5, 0)
    val, stale = srv.result_with_staleness(r_new)
    D = constrained_distance_grid(srv.index.graph)
    assert val == int(D[0, 5, 0]) and stale is False
    # memo hit after an update serves the post-update answer, not stale
    r_memo = srv.submit(0, 5, 0)
    val2, stale2 = srv.result_with_staleness(r_memo)
    assert val2 == val and stale2 is False
    assert srv.stats.memo_hits >= 1
    # unknown rid is the typed read-once contract
    with pytest.raises(UnknownRequestError):
        srv.result_with_staleness(10_000)


def test_server_requires_graph_for_updates():
    g = erdos_renyi(10, 3.0, num_levels=2, seed=0)
    idx, _ = build_wc_index_batched_packed(g, **BUILD_KW)
    srv = WCSDServer(idx, layout="csr", interpret=True)
    with pytest.raises(ValueError, match="dynamic server"):
        srv.apply_updates(inserts=[(0, 1, float(g.levels[0]))])
    with pytest.raises(ValueError, match="dynamic server"):
        srv.compact()
    eng = DeviceQueryEngine(idx, layout="csr", interpret=True)
    with pytest.raises(ValueError, match="injected engine"):
        WCSDServer(engine=eng, graph=g)


# ----------------------------------------------------------- mutate_edges
def test_mutate_edges_semantics():
    g = erdos_renyi(12, 3.0, num_levels=3, seed=7)
    u, v = int(g.edges_src[0]), int(g.edges_dst[0])
    # upsert replaces the quality of an existing edge (from_edges alone
    # would keep the max-quality duplicate)
    q_new = float(g.levels[0])
    g2 = mutate_edges(g, inserts=[(u, v, q_new)])
    m = ((g2.edges_src == u) & (g2.edges_dst == v))
    assert g2.levels[g2.edges_level[m]][0] == q_new
    assert g2.version == g.version + 1
    np.testing.assert_array_equal(g2.levels, g.levels)  # table preserved
    # deletes are orientation-insensitive
    g3 = mutate_edges(g2, deletes=[(v, u)])
    assert not ((g3.edges_src == u) & (g3.edges_dst == v)).any()
    # the level table survives even when a delete removes the last edge of
    # a quality level
    assert len(g3.levels) == len(g.levels)
    with pytest.raises(ValueError, match="not in the graph's level table"):
        mutate_edges(g, inserts=[(0, 1, 123.456)])
    with pytest.raises(ValueError, match="self loop"):
        mutate_edges(g, inserts=[(3, 3, float(g.levels[0]))])


def test_affected_vertices_is_component_closure():
    # two disjoint components: 0-1-2 and 3-4; touching 0 must never mark
    # the other component as affected
    u = np.array([0, 1, 3], dtype=np.int32)
    v = np.array([1, 2, 4], dtype=np.int32)
    q = np.array([1.0, 1.0, 1.0])
    g = Graph.from_edges(5, u, v, q)
    g2 = mutate_edges(g, deletes=[(0, 1)])
    aff = affected_vertices(g, g2, [0, 1])
    assert set(aff.tolist()) == {0, 1, 2}
    # an insert bridging the components affects both closures
    g3 = mutate_edges(g, inserts=[(2, 3, 1.0)])
    aff2 = affected_vertices(g, g3, [2, 3])
    assert set(aff2.tolist()) == {0, 1, 2, 3, 4}


# ------------------------------------------------------------- persistence
def _build_small(seed=3):
    g = erdos_renyi(30, 3.0, num_levels=4, seed=seed)
    idx, _ = build_wc_index_batched_packed(g, **BUILD_KW)
    return g, idx


def test_save_load_round_trip_bit_identical(tmp_path):
    """save() -> load() round-trips every array bit-identically, the mmap
    load is zero-copy (arrays stay backed by the file mapping), and an
    engine over the loaded index serves bit-identically to the builder's."""
    g, idx = _build_small()
    p = str(tmp_path / "idx.wcx")
    save_packed_index(p, idx, graph_version=g.version)
    loaded, header = load_packed_index(p)
    assert header["graph_version"] == g.version
    assert header["num_nodes"] == g.num_nodes
    np.testing.assert_array_equal(loaded.order, idx.order)
    np.testing.assert_array_equal(loaded.rank, idx.rank)
    np.testing.assert_array_equal(loaded.levels, idx.levels)
    for field in ("hub_rank", "dist", "wlev", "offsets", "bucket_widths",
                  "bucket_of", "slot_of"):
        np.testing.assert_array_equal(getattr(loaded.labels, field),
                                      getattr(idx.labels, field), field)

    def mmap_backed(a):
        while a is not None and not isinstance(a, np.memmap):
            a = getattr(a, "base", None)
        return isinstance(a, np.memmap)

    assert all(mmap_backed(getattr(loaded.labels, f))
               for f in ("hub_rank", "dist", "wlev", "offsets"))

    s, t, wl = _full_grid(g.num_nodes, g.num_levels)
    for eng_idx in (idx, loaded):
        eng = DeviceQueryEngine(eng_idx, layout="csr", dispatch="ragged",
                                interpret=True)
        np.testing.assert_array_equal(
            np.asarray(eng.query(s, t, wl)),
            constrained_distance_grid(g)[s, t, wl])
    # eager (non-mmap) load agrees bit-for-bit too
    eager, _ = load_packed_index(p, mmap=False)
    np.testing.assert_array_equal(eager.labels.hub_rank,
                                  loaded.labels.hub_rank)


def test_load_rejects_corrupted_magic(tmp_path):
    g, idx = _build_small()
    p = str(tmp_path / "idx.wcx")
    save_packed_index(p, idx)
    with open(p, "r+b") as f:
        f.write(b"NOTANIDX")
    with pytest.raises(IndexHeaderError, match="magic"):
        load_packed_index(p)
    # typed errors share the IndexPersistenceError base
    assert issubclass(IndexHeaderError, IndexPersistenceError)
    assert issubclass(IndexTruncatedError, IndexPersistenceError)
    assert issubclass(IndexVersionError, IndexPersistenceError)


def test_load_rejects_truncated_file(tmp_path):
    g, idx = _build_small()
    p = str(tmp_path / "idx.wcx")
    save_packed_index(p, idx)
    data = open(p, "rb").read()
    # every truncation point must refuse cleanly — header, table, payload
    for frac in (0.01, 0.3, 0.99):
        cut = str(tmp_path / f"cut{frac}.wcx")
        with open(cut, "wb") as f:
            f.write(data[:int(len(data) * frac)])
        with pytest.raises(IndexTruncatedError):
            load_packed_index(cut)


def test_load_rejects_version_mismatch(tmp_path):
    g, idx = _build_small()
    p = str(tmp_path / "idx.wcx")
    save_packed_index(p, idx)
    data = open(p, "rb").read()
    hlen = int.from_bytes(data[len(WCX_MAGIC):len(WCX_MAGIC) + 8], "little")
    hdr = data[len(WCX_MAGIC) + 8:len(WCX_MAGIC) + 8 + hlen]
    # same-length patch keeps every offset in the file valid
    patched = hdr.replace(b'"version": 2', b'"version":99')
    assert patched != hdr and len(patched) == len(hdr)
    vf = str(tmp_path / "ver.wcx")
    with open(vf, "wb") as f:
        f.write(data[:len(WCX_MAGIC) + 8] + patched
                + data[len(WCX_MAGIC) + 8 + hlen:])
    with pytest.raises(IndexVersionError, match="format version"):
        load_packed_index(vf)


def test_mid_write_crash_never_tears_the_served_file(tmp_path):
    """A crash mid-write (injected via checkpoint/fault.crashing_open)
    leaves the target path untouched — the previous complete index keeps
    serving — and the torn tmp file itself refuses to load."""
    g, idx = _build_small()
    p = str(tmp_path / "idx.wcx")
    save_packed_index(p, idx, graph_version=1)
    before = open(p, "rb").read()
    for budget in (4, 100, len(before) // 2, len(before) - 16):
        with pytest.raises(MidWriteCrash):
            save_packed_index(p, idx, graph_version=2,
                              _open=crashing_open(budget))
        assert open(p, "rb").read() == before  # target never replaced
        tmp = p + ".tmp"
        if os.path.exists(tmp):
            with pytest.raises((IndexTruncatedError, IndexHeaderError)):
                load_packed_index(tmp)
            os.remove(tmp)
    _, header = load_packed_index(p)
    assert header["graph_version"] == 1  # still the pre-crash version


def test_load_rejects_bit_flips_in_every_blob(tmp_path):
    """Fault matrix, corruption leg (docs/resilience.md §integrity): ONE
    flipped byte in ANY payload blob must surface as a typed
    IndexIntegrityError at load — never a silent load that would serve a
    wrong distance. Probes one byte per blob (first, middle, last)."""
    from repro.checkpoint.ckpt import _WCX_ALIGN, _wcx_arrays
    from repro.checkpoint.fault import flip_byte_on_disk
    from repro.core.resilience import IndexIntegrityError

    g, idx = _build_small(seed=5)
    p = str(tmp_path / "idx.wcx")
    save_packed_index(p, idx)
    data = open(p, "rb").read()
    hlen = int.from_bytes(data[len(WCX_MAGIC):len(WCX_MAGIC) + 8], "little")
    header = json.loads(data[len(WCX_MAGIC) + 8:len(WCX_MAGIC) + 8 + hlen])
    assert set(header["arrays"]) == set(_wcx_arrays(idx))
    raw = len(WCX_MAGIC) + 8 + hlen
    payload0 = -(-raw // _WCX_ALIGN) * _WCX_ALIGN  # save()'s aligned base
    for name, spec in header["arrays"].items():
        nbytes = int(spec["nbytes"])
        if nbytes == 0:
            continue
        for rel in (0, nbytes // 2, nbytes - 1):
            off = payload0 + spec["offset"] + rel
            orig = flip_byte_on_disk(p, off, mask=0x40)
            with pytest.raises(IndexIntegrityError, match=name):
                load_packed_index(p, mmap=False)
            # verify=False documents the override exists; then restore
            load_packed_index(p, mmap=False, verify=False)
            assert flip_byte_on_disk(p, off, mask=0x40) == orig ^ 0x40
    loaded, _ = load_packed_index(p, mmap=False)   # healed file loads clean
    np.testing.assert_array_equal(loaded.labels.hub_rank,
                                  idx.labels.hub_rank)


def test_verify_integrity_on_demand(tmp_path):
    """`verify_integrity()` on a live index/arena: passes on clean state,
    names the corrupted blob after an in-memory bit-flip, and passes
    again once the flip is undone."""
    from repro.checkpoint.fault import flip_array_cell
    from repro.core.resilience import IndexIntegrityError

    g, idx = _build_small(seed=7)
    idx.verify_integrity()                  # stamps the baseline
    idx.verify_integrity()                  # clean re-check passes
    undo = flip_array_cell(idx.labels.dist, flat_index=1, mask=4)
    with pytest.raises(IndexIntegrityError, match="dist"):
        idx.verify_integrity()
    undo()
    idx.verify_integrity()
    # the lane-tiled arena carries its own checksums
    ar = idx.labels.arena(lane=16)
    ar.verify_integrity()
    undo = flip_array_cell(ar.hub, flat_index=0, mask=1)
    with pytest.raises(IndexIntegrityError, match="hub"):
        ar.verify_integrity()
    undo()
    ar.verify_integrity()
    # a loaded index carries the on-disk checksums as its baseline
    p = str(tmp_path / "idx.wcx")
    save_packed_index(p, idx)
    loaded, _ = load_packed_index(p, mmap=False)
    loaded.verify_integrity()


def test_warm_start_then_serve_dynamic(tmp_path):
    """The warm-start scenario end to end: persist, mmap-load in a fresh
    index object, wrap dynamic, apply updates, stay exact."""
    g, idx = _build_small(seed=9)
    p = str(tmp_path / "idx.wcx")
    save_packed_index(p, idx, graph_version=g.version)
    loaded, _ = load_packed_index(p)
    dyn = DynamicWCIndex(loaded, g)
    dyn.apply_updates(inserts=[(0, 9, float(g.levels[1]))])
    g2 = dyn.graph
    s, t, wl = _full_grid(g2.num_nodes, g2.num_levels)
    eng = DeviceQueryEngine(dyn, layout="csr", dispatch="ragged",
                            interpret=True)
    np.testing.assert_array_equal(
        np.asarray(eng.query(s, t, wl)),
        constrained_distance_grid(g2)[s, t, wl])


# -------------------------------------------------- conftest cache keying
def test_built_indices_cache_keys_on_graph_version(built_indices):
    """Regression (dynamic tests must not poison static fixtures): if the
    cached graph object's version moves — i.e. a dynamic test mutated the
    fixture in place — the next `built_indices` call rebuilds instead of
    returning the stale (graph, index) pair."""
    kwargs = dict(num_nodes=14, avg_degree=3.0, num_levels=2, seed=12345)
    g1, idx1 = built_indices("erdos_renyi", **kwargs)
    g1b, idx1b = built_indices("erdos_renyi", **kwargs)
    assert g1 is g1b and idx1 is idx1b  # cache hit while version unchanged
    # simulate a dynamic test bumping the cached graph's version in place
    object.__setattr__(g1, "version", g1.version + 1)
    g2, idx2 = built_indices("erdos_renyi", **kwargs)
    assert g2 is not g1 and idx2 is not idx1
    assert g2.version == 0  # fresh build over a fresh graph
    g3, idx3 = built_indices("erdos_renyi", **kwargs)
    assert g3 is g2 and idx3 is idx2  # fresh pair is cached again

"""Ragged single-launch query megakernel: the differential harness.

Paths under test: the ragged arena path (`DeviceQueryEngine(layout="csr",
dispatch="ragged")`, interpret-mode Pallas kernel AND jnp oracle, plus the
sharded engine) against the bucket-pair dispatch loop it replaced
(`dispatch="bucket_pair"`, kept as the oracle), the padded numpy outer
join, and the per-level BFS sweep — on real graphs (full (s, t, w) grids)
and on ADVERSARIAL skewed label-length distributions built directly as
synthetic CSR stores spanning several length buckets.

Also here: the launch-count regression test (ONE `pallas_call` trace per
flush shape, however many buckets the batch mixes), the plan-free-flush
guarantee (the host bucket-pair planner is never invoked on the ragged
path), the device worklist emission vs a numpy reference, and the
`resolve_interpret` resolution-table lock.
"""
import numpy as np
import pytest
from _hypo_shim import given, settings, st  # hypothesis or fallback

import jax
import jax.numpy as jnp

from repro.core.baselines import constrained_distance_grid
from repro.core.generators import erdos_renyi
from repro.core.query import (DeviceQueryEngine, ShardedQueryEngine,
                              emit_ragged_worklist, ragged_worklist_len)
from repro.core.serve import WCSDServer
from repro.core.wc_index import WCIndex, build_wc_index
from repro.kernels import ops

EXAMPLES_PER_BLOCK = 25
_instances_run = [0]


def _full_grid(V, W):
    s, t, w = np.meshgrid(np.arange(V), np.arange(V), np.arange(W + 1),
                          indexing="ij")
    return (s.ravel().astype(np.int32), t.ravel().astype(np.int32),
            w.ravel().astype(np.int32))


# ------------------------------------------------------- real-graph grids
@pytest.mark.parametrize("lane", [128, 16])
@given(st.sampled_from([8, 10, 12]), st.sampled_from([2.5, 3.5, 4.5]),
       st.sampled_from([2, 3]), st.integers(0, 100_000))
@settings(max_examples=EXAMPLES_PER_BLOCK, deadline=None, derandomize=True)
def test_ragged_agrees_with_bucket_pair_and_bfs(lane, n, deg, levels, seed):
    """Full (s, t, w) grid: ragged (kernel + jnp) == bucket-pair == BFS
    sweep, single-level AND profile. lane=16 forces multi-tile rows and
    multi-bucket stores even on tiny graphs, so the worklist emission and
    the in-kernel tile walk are exercised, not just the 1-tile fast case."""
    g = erdos_renyi(n, deg, num_levels=levels, seed=seed + 4801 * lane)
    V, W = g.num_nodes, g.num_levels
    idx = build_wc_index(g)
    s, t, wl = _full_grid(V, W)
    D = constrained_distance_grid(g)
    exp = D[s, t, wl]

    eng_k = DeviceQueryEngine(idx, layout="csr", use_pallas=True, lane=lane)
    assert eng_k.dispatch == "ragged"
    np.testing.assert_array_equal(np.asarray(eng_k.query(s, t, wl)), exp)
    eng_j = DeviceQueryEngine(idx, layout="csr", use_pallas=False, lane=lane)
    np.testing.assert_array_equal(np.asarray(eng_j.query(s, t, wl)), exp)

    oracle = DeviceQueryEngine(idx, layout="csr", use_pallas=True, lane=lane,
                               dispatch="bucket_pair")
    np.testing.assert_array_equal(np.asarray(oracle.query(s, t, wl)), exp)

    # profile staircases, every level from the one launch
    s2, t2 = np.meshgrid(np.arange(V), np.arange(V), indexing="ij")
    s2 = s2.ravel().astype(np.int32)
    t2 = t2.ravel().astype(np.int32)
    np.testing.assert_array_equal(np.asarray(eng_k.query_profile(s2, t2)),
                                  D[s2, t2, :])
    np.testing.assert_array_equal(np.asarray(oracle.query_profile(s2, t2)),
                                  D[s2, t2, :])
    _instances_run[0] += 1


# ------------------------------------------------- adversarial skew stores
def _padded_oracle(pidx):
    hub, dist, wlev, count = pidx.labels.to_padded()
    return WCIndex(order=pidx.order, rank=pidx.rank, levels=pidx.levels,
                   hub_rank=hub, dist=dist, wlev=wlev, count=count)


@given(st.integers(0, 100_000), st.sampled_from([2, 3, 4]))
@settings(max_examples=15, deadline=None, derandomize=True)
def test_ragged_adversarial_skewed_lengths(seed, buckets):
    """Skewed length mixes across up to 4 buckets: the ragged megakernel
    (kernel + jnp), the bucket-pair loop, and the padded numpy outer join
    agree exactly — single-level and profile — on batches that hit every
    (short x short / short x heavy / heavy x heavy) pair shape. The store
    builder is SHARED with benchmarks/bench_wcsd.py: the configuration
    the perf row measures is the one this block proves correct."""
    from benchmarks.bench_wcsd import make_skewed_store
    rng = np.random.default_rng(seed)
    V, W, lane = 48, 3, 8
    pidx, heavy = make_skewed_store(V=V, W=W, lane=lane, buckets=buckets,
                                    rng=rng)
    oracle = _padded_oracle(pidx)
    B = 160
    s = rng.integers(0, V, B).astype(np.int32)
    t = rng.integers(0, V, B).astype(np.int32)
    s[:buckets] = np.resize(heavy, buckets)   # force heavy x heavy pairs
    t[:buckets] = np.resize(heavy[::-1], buckets)
    wl = rng.integers(0, W + 1, B).astype(np.int32)
    exp = oracle.query_batch(s, t, wl)

    eng_k = DeviceQueryEngine(pidx, layout="csr", use_pallas=True, lane=lane)
    eng_j = DeviceQueryEngine(pidx, layout="csr", use_pallas=False, lane=lane)
    bp = DeviceQueryEngine(pidx, layout="csr", use_pallas=False, lane=lane,
                           dispatch="bucket_pair")
    np.testing.assert_array_equal(np.asarray(eng_k.query(s, t, wl)), exp)
    np.testing.assert_array_equal(np.asarray(eng_j.query(s, t, wl)), exp)
    np.testing.assert_array_equal(np.asarray(bp.query(s, t, wl)), exp)

    exp_prof = np.stack([oracle.query_batch(s, t, np.full(B, w, np.int32))
                         for w in range(W + 1)], axis=1)
    np.testing.assert_array_equal(np.asarray(eng_k.query_profile(s, t)),
                                  exp_prof)
    np.testing.assert_array_equal(np.asarray(bp.query_profile(s, t)),
                                  exp_prof)


# ----------------------------------------------------------- both engines
def test_sharded_ragged_matches_device_engine():
    """ShardedQueryEngine(dispatch="ragged") == DeviceQueryEngine bit for
    bit (1-device mesh in-process; the 8-virtual-device sweep runs in
    launch.dryrun --serve) — in BOTH placements: replicated arena, and
    the row-sharded store (which used to silently fall back to
    bucket_pair and now keeps the megakernel via the worklist tile
    gather), compressed arena included."""
    from repro.launch.mesh import make_serving_mesh
    g = erdos_renyi(40, 3.5, num_levels=3, seed=9)
    idx = build_wc_index(g)
    rng = np.random.default_rng(1)
    s = rng.integers(0, 40, 300).astype(np.int32)
    t = rng.integers(0, 40, 300).astype(np.int32)
    wl = rng.integers(0, 4, 300).astype(np.int32)
    dev = DeviceQueryEngine(idx, layout="csr", use_pallas=True)
    exp = np.asarray(dev.query(s, t, wl))
    exp_prof = np.asarray(dev.query_profile(s, t))
    sh = ShardedQueryEngine(idx, mesh=make_serving_mesh(), layout="csr",
                            use_pallas=True)
    assert sh.dispatch == "ragged"
    np.testing.assert_array_equal(np.asarray(sh.query(s, t, wl)), exp)
    np.testing.assert_array_equal(np.asarray(sh.query_profile(s, t)),
                                  exp_prof)
    # row-sharded labels keep the ragged megakernel: the flush gathers
    # each device's worklist tiles with ONE reduce-scatter
    for compressed in (False, True):
        rs = ShardedQueryEngine(idx, mesh=make_serving_mesh(), layout="csr",
                                device_budget_bytes=1, dispatch="ragged",
                                use_pallas=True, compressed=compressed)
        assert rs.mode == "sharded_labels" and rs.dispatch == "ragged"
        assert rs.compressed is compressed
        np.testing.assert_array_equal(np.asarray(rs.query(s, t, wl)), exp)
        np.testing.assert_array_equal(np.asarray(rs.query_profile(s, t)),
                                      exp_prof)


# ------------------------------------------------------------ launch count
def test_one_pallas_launch_per_flush():
    """Acceptance: a 4096-query batch mixing several length buckets is
    served by EXACTLY ONE ragged `pallas_call` trace per flush shape —
    where the bucket-pair dispatch traces one kernel per bucket pair —
    and the answers are bit-identical to the bucket-pair path and the BFS
    sweep."""
    import repro.kernels.wcsd_query as wq

    g = erdos_renyi(60, 4.0, num_levels=4, seed=77)
    idx = build_wc_index(g)
    lane = 16
    packed = idx.packed(lane=lane)
    assert packed.num_buckets >= 2, "config no longer mixes buckets"
    D = constrained_distance_grid(g)
    rng = np.random.default_rng(3)
    B = 4096
    s = rng.integers(0, g.num_nodes, B).astype(np.int32)
    t = rng.integers(0, g.num_nodes, B).astype(np.int32)
    wl = rng.integers(0, g.num_levels + 1, B).astype(np.int32)
    exp = D[s, t, wl]

    calls = []
    real = wq.pl.pallas_call

    def counting(*a, **k):
        calls.append(a)
        return real(*a, **k)

    wq.pl.pallas_call = counting
    try:
        eng = DeviceQueryEngine(idx, layout="csr", use_pallas=True,
                                lane=lane)
        got = np.asarray(eng.query(s, t, wl))
        assert len(calls) == 1, \
            f"expected ONE ragged launch per flush, traced {len(calls)}"
        # same flush shape again: the compiled call is reused, no re-trace
        got2 = np.asarray(eng.query(s, t, wl))
        assert len(calls) == 1
        # the bucket-pair loop traces one kernel per (bucket_s, bucket_t)
        calls.clear()
        bp = DeviceQueryEngine(idx, layout="csr", use_pallas=True,
                               lane=lane, dispatch="bucket_pair")
        exp_bp = np.asarray(bp.query(s, t, wl))
        n_pairs = len(
            {(packed.bucket_of[a], packed.bucket_of[b])
             for a, b in zip(s.tolist(), t.tolist())})
        assert len(calls) == n_pairs > 1
    finally:
        wq.pl.pallas_call = real
    np.testing.assert_array_equal(got, exp)
    np.testing.assert_array_equal(got2, exp)
    np.testing.assert_array_equal(exp_bp, exp)


def test_rowsharded_one_launch_one_collective_per_flush():
    """Acceptance for the ROW-SHARDED ragged path, on 8 virtual devices
    (subprocess — the device count must be fixed before jax initializes):
    a mixed-bucket flush with the label store tile-row-sharded traces
    EXACTLY ONE ragged `pallas_call` (the per-device launch is one SPMD
    trace) plus ONE `psum_scatter` (the fused worklist tile gather), a
    repeat flush traces nothing new, and the answers are bit-identical to
    the single-device engine."""
    import os
    import subprocess
    import sys

    prog = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
import repro.kernels.wcsd_query as wq
from repro.core.generators import erdos_renyi
from repro.core.query import DeviceQueryEngine, ShardedQueryEngine
from repro.core.wc_index import build_wc_index
from repro.launch.mesh import make_serving_mesh

g = erdos_renyi(60, 4.0, num_levels=4, seed=77)
idx = build_wc_index(g)
lane = 16
assert idx.packed(lane=lane).num_buckets >= 2, "config no longer mixes buckets"
rng = np.random.default_rng(3)
B = 1024
s = rng.integers(0, g.num_nodes, B).astype(np.int32)
t = rng.integers(0, g.num_nodes, B).astype(np.int32)
wl = rng.integers(0, g.num_levels + 1, B).astype(np.int32)
dev = DeviceQueryEngine(idx, layout="csr", use_pallas=True, lane=lane)
exp = np.asarray(dev.query(s, t, wl))
exp_prof = np.asarray(dev.query_profile(s, t))

pallas_traces, coll_traces = [], []
real_pc, real_ps = wq.pl.pallas_call, jax.lax.psum_scatter
def counting_pc(*a, **k):
    pallas_traces.append(a)
    return real_pc(*a, **k)
def counting_ps(*a, **k):
    coll_traces.append(a)
    return real_ps(*a, **k)
wq.pl.pallas_call = counting_pc
jax.lax.psum_scatter = counting_ps
try:
    eng = ShardedQueryEngine(idx, mesh=make_serving_mesh(), layout="csr",
                             lane=lane, use_pallas=True,
                             device_budget_bytes=1, dispatch="ragged")
    assert eng.mode == "sharded_labels" and eng.dispatch == "ragged"
    got = np.asarray(eng.query(s, t, wl))
    assert len(pallas_traces) == 1, f"{len(pallas_traces)} pallas traces"
    assert len(coll_traces) == 1, f"{len(coll_traces)} collective traces"
    # same flush shape again: compiled call reused, nothing re-traced
    got2 = np.asarray(eng.query(s, t, wl))
    assert len(pallas_traces) == 1 and len(coll_traces) == 1
    # the profile flush pays the same budget: one launch + one gather
    pallas_traces.clear(); coll_traces.clear()
    prof = np.asarray(eng.query_profile(s, t))
    assert len(pallas_traces) == 1, f"{len(pallas_traces)} pallas traces"
    assert len(coll_traces) == 1, f"{len(coll_traces)} collective traces"
finally:
    wq.pl.pallas_call = real_pc
    jax.lax.psum_scatter = real_ps
np.testing.assert_array_equal(got, exp)
np.testing.assert_array_equal(got2, exp)
np.testing.assert_array_equal(prof, exp_prof)
print("OK one launch one collective")
"""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = {**os.environ, "PYTHONPATH": src, "JAX_PLATFORMS": "cpu"}
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=env, timeout=420)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "OK one launch one collective" in r.stdout


def test_delta_serving_one_pallas_launch_per_flush():
    """Acceptance (dynamic serving): a flush over the main + delta arenas
    still traces EXACTLY ONE ragged `pallas_call` — the delta region is
    appended tiles in the SAME arena, its worklist items ride the same
    launch (docs/dynamic-index.md) — and the answers are bit-identical to
    the BFS sweep on the mutated graph."""
    import repro.kernels.wcsd_query as wq
    from repro.core.wc_index import DynamicWCIndex

    g = erdos_renyi(60, 4.0, num_levels=4, seed=77)
    idx = build_wc_index(g)
    lane = 16
    base_tiles = idx.packed(lane=lane).arena(lane=lane).num_tiles
    dyn = DynamicWCIndex(idx, g)
    dyn.apply_updates(
        inserts=[(0, 30, float(g.levels[1]))],
        deletes=[(int(g.edges_src[0]), int(g.edges_dst[0]))])
    assert not dyn.delta.is_empty()
    ext = dyn.packed(lane=lane).arena(lane=lane)
    assert ext.num_tiles > base_tiles, "no delta region appended"

    D = constrained_distance_grid(dyn.graph)
    rng = np.random.default_rng(3)
    B = 4096
    s = rng.integers(0, g.num_nodes, B).astype(np.int32)
    t = rng.integers(0, g.num_nodes, B).astype(np.int32)
    wl = rng.integers(0, g.num_levels + 1, B).astype(np.int32)
    exp = D[s, t, wl]

    calls = []
    real = wq.pl.pallas_call

    def counting(*a, **k):
        calls.append(a)
        return real(*a, **k)

    wq.pl.pallas_call = counting
    try:
        eng = DeviceQueryEngine(dyn, layout="csr", use_pallas=True,
                                lane=lane)
        got = np.asarray(eng.query(s, t, wl))
        assert len(calls) == 1, \
            f"expected ONE launch over main+delta, traced {len(calls)}"
        got2 = np.asarray(eng.query(s, t, wl))
        assert len(calls) == 1  # compiled call reused
    finally:
        wq.pl.pallas_call = real
    np.testing.assert_array_equal(got, exp)
    np.testing.assert_array_equal(got2, exp)


def test_rowsharded_delta_one_launch_one_collective_per_flush():
    """The row-sharded flavor of the delta launch lock, on 8 virtual
    devices (subprocess): one `pallas_call` trace + one `psum_scatter`
    trace per flush with the delta-extended arena tile-sharded over the
    mesh, answers bit-identical to the single-device dynamic engine."""
    import os
    import subprocess
    import sys

    prog = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
import repro.kernels.wcsd_query as wq
from repro.core.generators import erdos_renyi
from repro.core.query import DeviceQueryEngine, ShardedQueryEngine
from repro.core.wc_index import DynamicWCIndex, build_wc_index
from repro.launch.mesh import make_serving_mesh

g = erdos_renyi(60, 4.0, num_levels=4, seed=77)
idx = build_wc_index(g)
lane = 16
dyn = DynamicWCIndex(idx, g)
dyn.apply_updates(inserts=[(0, 30, float(g.levels[1]))],
                  deletes=[(int(g.edges_src[0]), int(g.edges_dst[0]))])
assert not dyn.delta.is_empty()
rng = np.random.default_rng(3)
B = 1024
s = rng.integers(0, g.num_nodes, B).astype(np.int32)
t = rng.integers(0, g.num_nodes, B).astype(np.int32)
wl = rng.integers(0, g.num_levels + 1, B).astype(np.int32)
dev = DeviceQueryEngine(dyn, layout="csr", use_pallas=True, lane=lane)
exp = np.asarray(dev.query(s, t, wl))

pallas_traces, coll_traces = [], []
real_pc, real_ps = wq.pl.pallas_call, jax.lax.psum_scatter
def counting_pc(*a, **k):
    pallas_traces.append(a)
    return real_pc(*a, **k)
def counting_ps(*a, **k):
    coll_traces.append(a)
    return real_ps(*a, **k)
wq.pl.pallas_call = counting_pc
jax.lax.psum_scatter = counting_ps
try:
    eng = ShardedQueryEngine(dyn, mesh=make_serving_mesh(), layout="csr",
                             lane=lane, use_pallas=True,
                             device_budget_bytes=1, dispatch="ragged")
    assert eng.mode == "sharded_labels" and eng.dispatch == "ragged"
    got = np.asarray(eng.query(s, t, wl))
    assert len(pallas_traces) == 1, f"{len(pallas_traces)} pallas traces"
    assert len(coll_traces) == 1, f"{len(coll_traces)} collective traces"
    got2 = np.asarray(eng.query(s, t, wl))
    assert len(pallas_traces) == 1 and len(coll_traces) == 1
finally:
    wq.pl.pallas_call = real_pc
    jax.lax.psum_scatter = real_ps
np.testing.assert_array_equal(got, exp)
np.testing.assert_array_equal(got2, exp)
print("OK delta one launch one collective")
"""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = {**os.environ, "PYTHONPATH": src, "JAX_PLATFORMS": "cpu"}
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=env, timeout=420)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "OK delta one launch one collective" in r.stdout


def test_ragged_flush_never_calls_host_planner(monkeypatch):
    """The ragged path's batch plan is emitted on device: the host
    bucket-pair planner must not run on any flush (that is what makes
    `WCSDServer.flush_async` plan-free)."""
    import repro.core.query as q

    def boom(*a, **k):
        raise AssertionError("host planner invoked on the ragged path")

    monkeypatch.setattr(q, "plan_query_batch", boom)
    g = erdos_renyi(30, 3.0, num_levels=3, seed=4)
    idx = build_wc_index(g)
    srv = WCSDServer(idx, max_batch=32, layout="csr")
    rng = np.random.default_rng(0)
    s = rng.integers(0, 30, 100).astype(np.int32)
    t = rng.integers(0, 30, 100).astype(np.int32)
    wl = rng.integers(0, 3, 100).astype(np.int32)
    got = srv.query_many(s, t, wl)
    np.testing.assert_array_equal(got, idx.query_batch(s, t, wl))
    np.testing.assert_array_equal(srv.query_profile_many(s[:20], t[:20]),
                                  np.stack([idx.query_batch(
                                      s[:20], t[:20],
                                      np.full(20, w, np.int32))
                                      for w in range(4)], axis=1))


# ------------------------------------------------------- worklist emission
def test_emit_ragged_worklist_matches_numpy_reference():
    rng = np.random.default_rng(11)
    V = 20
    tile_cnt = rng.integers(1, 5, V).astype(np.int32)
    tile_base = np.zeros(V, dtype=np.int32)
    np.cumsum(tile_cnt[:-1], out=tile_base[1:])
    Q = 16
    s = rng.integers(0, V, Q).astype(np.int32)
    t = rng.integers(0, V, Q).astype(np.int32)
    total = int((tile_cnt[s].astype(np.int64) * tile_cnt[t]).sum())
    WL = ragged_worklist_len(tile_cnt, s, t)
    assert WL >= total and WL & (WL - 1) == 0

    qidx, stile, ttile, first = (np.asarray(a) for a in emit_ragged_worklist(
        jnp.asarray(tile_base), jnp.asarray(tile_cnt),
        jnp.asarray(s), jnp.asarray(t), worklist_len=WL))
    # numpy reference: query-major expansion of every tile pair
    c = (tile_cnt[s].astype(np.int64) * tile_cnt[t])
    exp_q = np.repeat(np.arange(Q), c)
    local = np.arange(total) - np.repeat(np.cumsum(c) - c, c)
    exp_s = tile_base[s[exp_q]] + local // tile_cnt[t[exp_q]]
    exp_t = tile_base[t[exp_q]] + local % tile_cnt[t[exp_q]]
    np.testing.assert_array_equal(qidx[:total], exp_q)
    np.testing.assert_array_equal(stile[:total], exp_s)
    np.testing.assert_array_equal(ttile[:total], exp_t)
    # first marks each output row's first work item, exactly once per row
    np.testing.assert_array_equal(
        np.flatnonzero(first[:total]),
        np.concatenate([[0], 1 + np.flatnonzero(np.diff(exp_q))]))
    # pads: trash row Q, tile 0, and the trash row is init'd too
    assert np.all(qidx[total:] == Q)
    assert np.all(stile[total:] == 0) and np.all(ttile[total:] == 0)
    if WL > total:
        assert first[total] == 1
    # qidx non-decreasing: output blocks are revisited only consecutively
    assert np.all(np.diff(qidx.astype(np.int64)) >= 0)


def test_ragged_empty_and_identity_edge_cases():
    g = erdos_renyi(10, 2.0, num_levels=2, seed=2)
    idx = build_wc_index(g)
    eng = DeviceQueryEngine(idx, layout="csr", use_pallas=True)
    empty = np.array([], dtype=np.int32)
    assert len(np.asarray(eng.query(empty, empty, empty))) == 0
    assert eng.query_profile(empty, empty).shape == (0, 3)
    v = np.arange(10, dtype=np.int32)
    # s == t is 0 at EVERY level, including the infeasible one (self entry)
    for w in range(3):
        np.testing.assert_array_equal(
            np.asarray(eng.query(v, v, np.full(10, w, np.int32))), 0)


def test_ragged_batch_pads_use_minimal_tile_vertex():
    """Batch-pad lanes must point at a minimal-tile-count vertex: padding
    with vertex 0 would cost tile_cnt[0]^2 worklist items PER PAD LANE
    whenever vertex 0 happens to be hub-heavy."""
    from benchmarks.bench_wcsd import make_skewed_store
    pidx, heavy = make_skewed_store(V=32, W=3, lane=8, buckets=3,
                                    rng=np.random.default_rng(0))
    eng = DeviceQueryEngine(pidx, layout="csr", lane=8)
    assert int(eng._tile_cnt_np[eng._pad_vertex]) == \
        int(eng._tile_cnt_np.min()) == 1
    # a 3-query batch pads to 4: the pad lane carries the cheap vertex
    h = np.resize(heavy, 3).astype(np.int32)
    stq = eng._stage_ragged(h, h, np.zeros(3, np.int32))
    assert stq.shape[1] == 4
    assert stq[0, 3] == stq[1, 3] == eng._pad_vertex


# ------------------------------------------------------ interpret default
@pytest.mark.parametrize("arg,backend,want", [
    (True, "cpu", True), (True, "tpu", True),
    (False, "cpu", False), (False, "tpu", False),
    (None, "cpu", True), (None, "gpu", True), (None, "tpu", False),
])
def test_resolve_interpret_table(monkeypatch, arg, backend, want):
    """The ONE resolution point for the interpret flag: explicit values are
    honored; None means compiled kernels exactly on TPU (the only backend
    that lowers these Mosaic kernels) and interpret emulation elsewhere —
    including GPU, where pltpu scalar prefetch cannot compile."""
    monkeypatch.setattr(jax, "default_backend", lambda: backend)
    assert ops.resolve_interpret(arg) is want


def test_engines_resolve_interpret_through_ops(monkeypatch):
    """use_pallas=True engines (and the server) default to COMPILED kernels
    on TPU — interpret only when explicitly requested or the backend
    cannot lower Mosaic. The engine must consume the resolved bool, not
    the raw None."""
    g = erdos_renyi(12, 2.5, num_levels=2, seed=6)
    idx = build_wc_index(g)
    # this test host is CPU: None resolves to interpret=True
    assert DeviceQueryEngine(idx, use_pallas=True).interpret is True
    assert DeviceQueryEngine(idx, use_pallas=True,
                             interpret=False).interpret is False
    srv = WCSDServer(idx, layout="csr", use_pallas=True)
    assert srv.engine.interpret is True
    # on an accelerator backend the same default resolves to compiled
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert DeviceQueryEngine(idx, use_pallas=True).interpret is False
    assert DeviceQueryEngine(idx, use_pallas=True,
                             interpret=True).interpret is True


def test_rowsharded_engine_resolves_interpret_once_through_ops(monkeypatch):
    """The sharded engine resolves the interpret flag EXACTLY ONCE, at
    construction, through `kernels.ops.resolve_interpret` — and the
    row-sharded ragged flush consumes that resolved bool (it used to
    bypass the kernels entirely on the jnp fallback, so neither
    `interpret` nor `use_pallas` reached the flush). Locked in both
    placements; the resolution TABLE itself is locked by
    `test_resolve_interpret_table`."""
    from repro.launch.mesh import make_serving_mesh
    g = erdos_renyi(12, 2.5, num_levels=2, seed=6)
    idx = build_wc_index(g)
    calls = []
    real = ops.resolve_interpret

    def counting(arg):
        calls.append(arg)
        return real(arg)

    monkeypatch.setattr(ops, "resolve_interpret", counting)
    for budget in (None, 1):
        calls.clear()
        eng = ShardedQueryEngine(idx, mesh=make_serving_mesh(),
                                 layout="csr", dispatch="ragged",
                                 use_pallas=True, device_budget_bytes=budget)
        assert calls == [None], f"resolved {len(calls)}x at construction"
        assert eng.interpret is True        # CPU test host: None -> True
        v = np.arange(12, dtype=np.int32)
        np.testing.assert_array_equal(
            np.asarray(eng.query(v, v, np.zeros(12, np.int32))), 0)
        assert calls == [None], "flush re-resolved the interpret flag"


def test_ragged_harness_coverage_target():
    """>= 50 generated real-graph instances (2 lane blocks x 25) plus the
    adversarial-skew block; when blocks ran in this session each produced
    its full example count (no silent early exits)."""
    assert 2 * EXAMPLES_PER_BLOCK >= 50
    if _instances_run[0]:
        assert _instances_run[0] % EXAMPLES_PER_BLOCK == 0

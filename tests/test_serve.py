"""WCSDServer semantics: memo hits + LRU eviction, power-of-two flush
padding, result() forcing a flush, and CSR-layout serving correctness."""
import numpy as np
import pytest

from repro.core.generators import scale_free
from repro.core.serve import WCSDServer
from repro.core.wc_index import build_wc_index


@pytest.fixture(scope="module")
def small_index():
    return build_wc_index(scale_free(120, 3, num_levels=4, seed=5),
                          ordering="degree")


# ------------------------------------------------------------------- memo
def test_memo_hit_skips_device(small_index, serve_layout):
    srv = WCSDServer(small_index, max_batch=64, layout=serve_layout)
    r1 = srv.submit(3, 9, 1)
    srv.flush()
    batches_before = srv.stats.batches
    r2 = srv.submit(3, 9, 1)          # memoized -> no pending, no flush
    assert srv.stats.memo_hits == 1
    assert srv.pending == []
    assert srv.result(r2) == srv.result(r1)
    assert srv.stats.batches == batches_before


def test_memo_is_symmetric(small_index, serve_layout):
    srv = WCSDServer(small_index, max_batch=64, layout=serve_layout)
    srv.submit(7, 2, 0)
    srv.flush()
    srv.submit(2, 7, 0)               # reversed endpoints hit the same key
    assert srv.stats.memo_hits == 1


def test_memo_distinguishes_levels(small_index, serve_layout):
    srv = WCSDServer(small_index, max_batch=64, layout=serve_layout)
    srv.submit(7, 2, 0)
    srv.flush()
    srv.submit(7, 2, 1)               # different level -> miss
    assert srv.stats.memo_hits == 0


def test_memo_lru_eviction(small_index, serve_layout):
    srv = WCSDServer(small_index, max_batch=1024, memo_capacity=4,
                     layout=serve_layout)
    for i in range(6):                 # 6 distinct keys through capacity 4
        srv.submit(i, i + 10, 0)
    srv.flush()
    assert len(srv.memo) == 4
    # oldest two evicted, newest four retained
    assert (0, 10, 0) not in srv.memo and (1, 11, 0) not in srv.memo
    assert (5, 15, 0) in srv.memo
    # re-submitting an evicted key is a miss; a retained key is a hit
    srv.submit(0, 10, 0)
    assert srv.stats.memo_hits == 0
    srv.submit(5, 15, 0)
    assert srv.stats.memo_hits == 1


def test_memo_hit_refreshes_lru_order(small_index, serve_layout):
    srv = WCSDServer(small_index, max_batch=1024, memo_capacity=2,
                     layout=serve_layout)
    srv.submit(1, 11, 0)
    srv.submit(2, 12, 0)
    srv.flush()
    srv.submit(1, 11, 0)               # hit refreshes (1, 11, 0)
    srv.submit(3, 13, 0)               # inserting a third evicts (2, 12, 0)
    srv.flush()
    assert (1, 11, 0) in srv.memo
    assert (2, 12, 0) not in srv.memo


# ------------------------------------------------------------------ flush
def test_flush_pads_to_power_of_two(small_index):
    srv = WCSDServer(small_index, max_batch=1024)
    seen = []
    inner = srv.engine.query_async   # bound class method, pre-stub
    # stub out the async handle so the server takes the blocking-query
    # fallback path through the instrumented lambda
    srv.engine.query_async = None
    srv.engine.query = lambda s, t, w: (seen.append(len(np.asarray(s)))
                                        or inner(s, t, w).wait())
    key = 0
    for n, want in [(1, 1), (2, 2), (3, 4), (5, 8), (8, 8), (9, 16)]:
        for _ in range(n):             # fresh keys -> every submit a miss
            srv.submit(key, key + 1, 0)
            key += 2
        srv.flush()
        assert seen[-1] == want, (n, seen[-1])


def test_flush_at_max_batch(small_index, serve_layout):
    srv = WCSDServer(small_index, max_batch=4, layout=serve_layout)
    rng = np.random.default_rng(0)
    for i in range(4):                 # distinct keys -> 4 misses
        srv.submit(int(rng.integers(50)), int(60 + i), 0)
    assert srv.stats.batches == 1      # auto-flushed on hitting max_batch
    assert srv.pending == []


def test_result_forces_flush(small_index, serve_layout):
    srv = WCSDServer(small_index, max_batch=1024, layout=serve_layout)
    rid = srv.submit(4, 8, 1)
    assert srv.pending and srv.stats.batches == 0
    got = srv.result(rid)              # pending rid -> flush happens inline
    assert got is not None
    assert srv.stats.batches == 1
    assert srv.pending == []
    assert srv.result(12345) is None   # unknown rid: no flush, None


def test_result_unknown_rid_never_flushes_pending(small_index, serve_layout):
    """Regression for the O(pending) scan fix: an unknown rid must return
    None WITHOUT flushing the queued requests, however many are pending."""
    srv = WCSDServer(small_index, max_batch=1024, layout=serve_layout)
    for i in range(37):
        srv.submit(i, i + 40, 0)
    assert len(srv.pending) == 37
    assert srv.result(999_999) is None
    assert len(srv.pending) == 37      # untouched
    assert srv.stats.batches == 0


def test_pending_rid_set_tracks_queue(small_index, serve_layout):
    """The pending-rid set mirrors the pending list through submit / memo
    hit / auto-flush / result-before-flush."""
    srv = WCSDServer(small_index, max_batch=4, layout=serve_layout)
    r1 = srv.submit(1, 21, 0)
    assert srv._pending_rids == {r1}
    srv.flush()
    assert srv._pending_rids == set()
    r2 = srv.submit(1, 21, 0)          # memo hit: never enters the queue
    assert srv._pending_rids == set() and srv.result(r2) == srv.result(r1)
    rids = [srv.submit(i, i + 50, 0) for i in range(2, 6)]  # hits max_batch
    assert srv.stats.batches == 2 and srv._pending_rids == set()
    r3 = srv.submit(9, 33, 1)
    assert srv.result(r3) is not None  # result-before-flush still works
    assert srv._pending_rids == set()
    assert all(srv.result(r) is not None for r in rids)


# -------------------------------------------------------------- directed
def test_directed_mode_keeps_memo_keys_apart(small_index):
    """undirected=False must not canonicalize (s, t): on a directed graph
    d(s, t) != d(t, s) and the swap would alias distinct answers. The
    engine is stubbed with an asymmetric function to simulate that."""
    srv = WCSDServer(small_index, max_batch=1024, undirected=False)
    srv.engine.query_async = None   # force the blocking-query fallback
    srv.engine.query = lambda s, t, w: np.asarray(s) * 1000 + np.asarray(t)
    a = srv.submit(2, 7, 0)
    srv.flush()
    b = srv.submit(7, 2, 0)            # NOT a memo hit in directed mode
    assert srv.stats.memo_hits == 0
    srv.flush()
    assert srv.result(a) == 2007 and srv.result(b) == 7002
    # an exact repeat IS still memoized
    c = srv.submit(2, 7, 0)
    assert srv.stats.memo_hits == 1 and srv.result(c) == 2007


def test_undirected_gate_still_canonicalizes_by_default(small_index):
    srv = WCSDServer(small_index, max_batch=64)
    assert srv.undirected
    r1 = srv.submit(11, 3, 1)
    srv.flush()
    r2 = srv.submit(3, 11, 1)
    assert srv.stats.memo_hits == 1
    assert srv.result(r1) == srv.result(r2)


# ------------------------------------------------------------ correctness
@pytest.mark.parametrize("layout", ["padded", "csr"])
def test_query_many_matches_oracle(small_index, layout):
    g_queries = random_queries_for(small_index, 300, seed=9)
    srv = WCSDServer(small_index, max_batch=64, layout=layout)
    s, t, wl = g_queries
    got = srv.query_many(s, t, wl)
    exp = small_index.query_batch(s, t, wl)
    assert np.array_equal(got, exp)
    assert srv.stats.requests == 300
    assert srv.stats.batches >= 1


def test_serve_from_packed_index_no_repack():
    """A PackedWCIndex from the device-resident builder is served as-is:
    the engine adopts the store object (no repack) and answers match the
    padded oracle."""
    from repro.core.generators import erdos_renyi
    from repro.core.wc_index_batched import build_wc_index_batched_packed

    g = erdos_renyi(90, 3.5, num_levels=4, seed=8)
    pidx, _ = build_wc_index_batched_packed(g, batch_size=16)
    srv = WCSDServer(pidx, max_batch=64, layout="csr")
    assert srv.engine.packed is pidx.labels   # same object, zero repack
    s, t, wl = random_queries_for(pidx, 200, seed=4)
    got = srv.query_many(s, t, wl)
    exp = pidx.to_index().query_batch(s, t, wl)
    assert np.array_equal(got, exp)


def random_queries_for(idx, n, seed):
    rng = np.random.default_rng(seed)
    s = rng.integers(0, idx.num_nodes, n).astype(np.int32)
    t = rng.integers(0, idx.num_nodes, n).astype(np.int32)
    wl = rng.integers(0, idx.num_levels, n).astype(np.int32)
    return s, t, wl


# ------------------------------------------------------- result eviction
def test_results_do_not_grow_across_epochs(small_index, serve_layout):
    """Regression for the unbounded-results leak: delivered rids are popped
    (read-once), so the dict stays empty after each query_many epoch
    instead of accumulating one entry per request forever."""
    srv = WCSDServer(small_index, max_batch=32, layout=serve_layout)
    s, t, wl = random_queries_for(small_index, 100, seed=1)
    for epoch in range(3):
        srv.query_many(s, t, wl)
        assert len(srv.results) == 0, epoch
    assert srv.stats.requests == 300


def test_result_is_read_once(small_index, serve_layout):
    srv = WCSDServer(small_index, max_batch=64, layout=serve_layout)
    rid = srv.submit(3, 9, 1)
    first = srv.result(rid)
    assert first is not None
    assert srv.result(rid) is None         # delivered -> evicted
    # the memo still answers a re-submission without device work
    rid2 = srv.submit(3, 9, 1)
    assert srv.stats.memo_hits == 1 and srv.result(rid2) == first


# ----------------------------------------------------------- async flush
def test_auto_flush_is_async_and_double_buffered(small_index, serve_layout):
    """Hitting max_batch dispatches the batch (batches increments, pending
    clears) but does NOT materialize results; the host keeps queueing the
    next batch while one is in flight, and at most one is in flight."""
    srv = WCSDServer(small_index, max_batch=4, layout=serve_layout)
    rids = [srv.submit(i, i + 30, 0) for i in range(4)]
    assert srv.stats.batches == 1
    assert srv._inflight is not None       # dispatched, not drained
    assert len(srv.results) == 0           # nothing materialized yet
    more = [srv.submit(i + 10, i + 60, 0) for i in range(4)]  # batch k+1
    assert srv.stats.batches == 2          # launching k+1 drained k
    assert all(r in srv.results for r in rids)
    out = [srv.result(r) for r in rids + more]   # drains batch k+1
    assert all(o is not None for o in out)
    assert srv._inflight is None and len(srv.results) == 0


def test_duplicate_submitted_while_in_flight_hits_memo(small_index,
                                                       serve_layout):
    """A hot key re-submitted while its batch is still in flight must
    piggyback on the in-flight computation (a memo hit), not queue a
    second device batch — the heavy-tailed workload the memo exists for."""
    srv = WCSDServer(small_index, max_batch=2, layout=serve_layout)
    r1 = srv.submit(3, 9, 1)
    srv.submit(5, 11, 0)               # hits max_batch -> async dispatch
    assert srv._inflight is not None and srv.stats.batches == 1
    r3 = srv.submit(3, 9, 1)           # duplicate of in-flight r1
    assert srv.stats.memo_hits == 1
    assert srv.pending == []           # piggybacked, not re-queued
    got3 = srv.result(r3)              # drains the in-flight batch
    assert got3 is not None and got3 == srv.result(r1)
    assert srv.stats.batches == 1      # no second device batch


def test_async_results_match_sync(small_index, serve_layout):
    s, t, wl = random_queries_for(small_index, 200, seed=3)
    srv = WCSDServer(small_index, max_batch=16, layout=serve_layout)
    got = srv.query_many(s, t, wl)           # many async auto-flushes
    exp = small_index.query_batch(s, t, wl)
    assert np.array_equal(got, exp)


# ------------------------------------------------------- engine plumbing
def test_interpret_and_backend_plumbing(small_index):
    """Regression: serving must be able to reach the compiled kernel path —
    use_pallas / interpret / layout flow through to the engine instead of
    being hardwired."""
    srv = WCSDServer(small_index, layout="csr", use_pallas=True,
                     interpret=False)
    assert srv.engine.use_pallas and srv.engine.interpret is False
    assert srv.engine.layout == "csr"
    srv2 = WCSDServer(small_index, interpret=True)
    assert srv2.engine.interpret is True
    from repro.core.query import DeviceQueryEngine, ShardedQueryEngine
    from repro.launch.mesh import make_serving_mesh
    assert isinstance(srv.engine, DeviceQueryEngine)
    srv3 = WCSDServer(small_index, backend="sharded", layout="csr",
                      interpret=False, mesh=make_serving_mesh())
    assert isinstance(srv3.engine, ShardedQueryEngine)
    assert srv3.engine.interpret is False
    with pytest.raises(ValueError):
        WCSDServer(small_index, backend="nope")


def test_prebuilt_engine_injection(small_index):
    from repro.core.query import DeviceQueryEngine
    eng = DeviceQueryEngine(small_index, layout="csr")
    srv = WCSDServer(engine=eng, max_batch=32)
    assert srv.engine is eng
    s, t, wl = random_queries_for(small_index, 50, seed=6)
    assert np.array_equal(srv.query_many(s, t, wl),
                          small_index.query_batch(s, t, wl))


# ------------------------------------------------------------ edge cases
def test_empty_batch_paths(small_index, serve_layout):
    """Empty pending through flush()/flush_async(), and an empty
    query_many, must be no-ops."""
    srv = WCSDServer(small_index, max_batch=8, layout=serve_layout)
    srv.flush()
    srv.flush_async()
    assert srv.stats.batches == 0
    out = srv.query_many(np.array([], np.int32), np.array([], np.int32),
                         np.array([], np.int32))
    assert out.shape == (0,) and srv.stats.batches == 0


def test_plan_query_batch_empty():
    from repro.core.query import plan_query_batch
    bucket_of = np.zeros(10, np.int32)
    assert plan_query_batch(bucket_of, np.array([], np.int32),
                            np.array([], np.int32)) == []


def test_single_bucket_store_serves(small_index):
    """A store whose every label row fits one bucket exercises the planner's
    single-sub-batch path end to end."""
    packed = small_index.packed()
    assert packed.num_buckets == 1   # 120-vertex index: all rows < 128
    srv = WCSDServer(small_index, max_batch=32, layout="csr")
    s, t, wl = random_queries_for(small_index, 80, seed=2)
    assert np.array_equal(srv.query_many(s, t, wl),
                          small_index.query_batch(s, t, wl))


def test_duplicate_keys_both_orientations_one_flush(small_index):
    """undirected=True: both orientations of (s, t) plus exact duplicates
    inside ONE flush canonicalize to a single memo entry and all get the
    same (correct) answer."""
    srv = WCSDServer(small_index, max_batch=1024, undirected=True)
    exp = int(small_index.query_batch(np.array([7]), np.array([2]),
                                      np.array([0]))[0])
    rids = [srv.submit(7, 2, 0), srv.submit(2, 7, 0),
            srv.submit(7, 2, 0), srv.submit(2, 7, 0)]
    assert srv.stats.memo_hits == 0          # nothing flushed yet
    srv.flush()                              # one batch answers all four
    assert srv.stats.batches == 1
    assert [srv.result(r) for r in rids] == [exp] * 4
    assert (2, 7, 0) in srv.memo and (7, 2, 0) not in srv.memo
    assert len([k for k in srv.memo if k[2] == 0]) == 1

"""WCSDServer semantics: memo hits + LRU eviction, power-of-two flush
padding, result() forcing a flush, and CSR-layout serving correctness."""
import numpy as np
import pytest

from repro.core.generators import scale_free
from repro.core.resilience import FlushRetryExhausted, UnknownRequestError
from repro.core.serve import WCSDServer
from repro.core.wc_index import build_wc_index


@pytest.fixture(scope="module")
def small_index():
    return build_wc_index(scale_free(120, 3, num_levels=4, seed=5),
                          ordering="degree")


# ------------------------------------------------------------------- memo
def test_memo_hit_skips_device(small_index, serve_layout):
    srv = WCSDServer(small_index, max_batch=64, layout=serve_layout)
    r1 = srv.submit(3, 9, 1)
    srv.flush()
    batches_before = srv.stats.batches
    r2 = srv.submit(3, 9, 1)          # memoized -> no pending, no flush
    assert srv.stats.memo_hits == 1
    assert srv.pending == []
    assert srv.result(r2) == srv.result(r1)
    assert srv.stats.batches == batches_before


def test_memo_is_symmetric(small_index, serve_layout):
    srv = WCSDServer(small_index, max_batch=64, layout=serve_layout)
    srv.submit(7, 2, 0)
    srv.flush()
    srv.submit(2, 7, 0)               # reversed endpoints hit the same key
    assert srv.stats.memo_hits == 1


def test_memo_distinguishes_levels(small_index, serve_layout):
    srv = WCSDServer(small_index, max_batch=64, layout=serve_layout)
    srv.submit(7, 2, 0)
    srv.flush()
    srv.submit(7, 2, 1)               # different level -> miss
    assert srv.stats.memo_hits == 0


def test_memo_lru_eviction(small_index, serve_layout):
    srv = WCSDServer(small_index, max_batch=1024, memo_capacity=4,
                     layout=serve_layout)
    for i in range(6):                 # 6 distinct keys through capacity 4
        srv.submit(i, i + 10, 0)
    srv.flush()
    assert len(srv.memo) == 4
    # oldest two evicted, newest four retained
    assert (0, 10, 0) not in srv.memo and (1, 11, 0) not in srv.memo
    assert (5, 15, 0) in srv.memo
    # re-submitting an evicted key is a miss; a retained key is a hit
    srv.submit(0, 10, 0)
    assert srv.stats.memo_hits == 0
    srv.submit(5, 15, 0)
    assert srv.stats.memo_hits == 1


def test_memo_hit_refreshes_lru_order(small_index, serve_layout):
    srv = WCSDServer(small_index, max_batch=1024, memo_capacity=2,
                     layout=serve_layout)
    srv.submit(1, 11, 0)
    srv.submit(2, 12, 0)
    srv.flush()
    srv.submit(1, 11, 0)               # hit refreshes (1, 11, 0)
    srv.submit(3, 13, 0)               # inserting a third evicts (2, 12, 0)
    srv.flush()
    assert (1, 11, 0) in srv.memo
    assert (2, 12, 0) not in srv.memo


# ------------------------------------------------------------------ flush
def test_flush_pads_to_power_of_two(small_index):
    srv = WCSDServer(small_index, max_batch=1024)
    seen = []
    inner = srv.engine.query_async   # bound class method, pre-stub
    # stub out the async handle so the server takes the blocking-query
    # fallback path through the instrumented lambda
    srv.engine.query_async = None
    srv.engine.query = lambda s, t, w: (seen.append(len(np.asarray(s)))
                                        or inner(s, t, w).wait())
    key = 0
    for n, want in [(1, 1), (2, 2), (3, 4), (5, 8), (8, 8), (9, 16)]:
        for _ in range(n):             # fresh keys -> every submit a miss
            srv.submit(key, key + 1, 0)
            key += 2
        srv.flush()
        assert seen[-1] == want, (n, seen[-1])


def test_flush_at_max_batch(small_index, serve_layout):
    srv = WCSDServer(small_index, max_batch=4, layout=serve_layout)
    rng = np.random.default_rng(0)
    for i in range(4):                 # distinct keys -> 4 misses
        srv.submit(int(rng.integers(50)), int(60 + i), 0)
    assert srv.stats.batches == 1      # auto-flushed on hitting max_batch
    assert srv.pending == []


def test_result_forces_flush(small_index, serve_layout):
    srv = WCSDServer(small_index, max_batch=1024, layout=serve_layout)
    rid = srv.submit(4, 8, 1)
    assert srv.pending and srv.stats.batches == 0
    got = srv.result(rid)              # pending rid -> flush happens inline
    assert got is not None
    assert srv.stats.batches == 1
    assert srv.pending == []
    with pytest.raises(UnknownRequestError):  # unknown rid: typed error
        srv.result(12345)


def test_result_unknown_rid_never_flushes_pending(small_index, serve_layout):
    """Regression for the O(pending) scan fix: an unknown rid must raise
    WITHOUT flushing the queued requests, however many are pending."""
    srv = WCSDServer(small_index, max_batch=1024, layout=serve_layout)
    for i in range(37):
        srv.submit(i, i + 40, 0)
    assert len(srv.pending) == 37
    with pytest.raises(UnknownRequestError, match="999999"):
        srv.result(999_999)
    assert len(srv.pending) == 37      # untouched
    assert srv.stats.batches == 0


def test_pending_rid_set_tracks_queue(small_index, serve_layout):
    """The pending-rid set mirrors the pending list through submit / memo
    hit / auto-flush / result-before-flush."""
    srv = WCSDServer(small_index, max_batch=4, layout=serve_layout)
    r1 = srv.submit(1, 21, 0)
    assert srv._pending_rids == {r1}
    srv.flush()
    assert srv._pending_rids == set()
    r2 = srv.submit(1, 21, 0)          # memo hit: never enters the queue
    assert srv._pending_rids == set() and srv.result(r2) == srv.result(r1)
    rids = [srv.submit(i, i + 50, 0) for i in range(2, 6)]  # hits max_batch
    assert srv.stats.batches == 2 and srv._pending_rids == set()
    r3 = srv.submit(9, 33, 1)
    assert srv.result(r3) is not None  # result-before-flush still works
    assert srv._pending_rids == set()
    assert all(srv.result(r) is not None for r in rids)


# -------------------------------------------------------------- directed
def test_directed_mode_keeps_memo_keys_apart(small_index):
    """undirected=False must not canonicalize (s, t): on a directed graph
    d(s, t) != d(t, s) and the swap would alias distinct answers. The
    engine is stubbed with an asymmetric function to simulate that."""
    srv = WCSDServer(small_index, max_batch=1024, undirected=False)
    srv.engine.query_async = None   # force the blocking-query fallback
    srv.engine.query = lambda s, t, w: np.asarray(s) * 1000 + np.asarray(t)
    a = srv.submit(2, 7, 0)
    srv.flush()
    b = srv.submit(7, 2, 0)            # NOT a memo hit in directed mode
    assert srv.stats.memo_hits == 0
    srv.flush()
    assert srv.result(a) == 2007 and srv.result(b) == 7002
    # an exact repeat IS still memoized
    c = srv.submit(2, 7, 0)
    assert srv.stats.memo_hits == 1 and srv.result(c) == 2007


def test_undirected_gate_still_canonicalizes_by_default(small_index):
    srv = WCSDServer(small_index, max_batch=64)
    assert srv.undirected
    r1 = srv.submit(11, 3, 1)
    srv.flush()
    r2 = srv.submit(3, 11, 1)
    assert srv.stats.memo_hits == 1
    assert srv.result(r1) == srv.result(r2)


# ------------------------------------------------------------ correctness
@pytest.mark.parametrize("layout", ["padded", "csr"])
def test_query_many_matches_oracle(small_index, layout):
    g_queries = random_queries_for(small_index, 300, seed=9)
    srv = WCSDServer(small_index, max_batch=64, layout=layout)
    s, t, wl = g_queries
    got = srv.query_many(s, t, wl)
    exp = small_index.query_batch(s, t, wl)
    assert np.array_equal(got, exp)
    assert srv.stats.requests == 300
    assert srv.stats.batches >= 1


def test_serve_from_packed_index_no_repack():
    """A PackedWCIndex from the device-resident builder is served as-is:
    the engine adopts the store object (no repack) and answers match the
    padded oracle."""
    from repro.core.generators import erdos_renyi
    from repro.core.wc_index_batched import build_wc_index_batched_packed

    g = erdos_renyi(90, 3.5, num_levels=4, seed=8)
    pidx, _ = build_wc_index_batched_packed(g, batch_size=16)
    srv = WCSDServer(pidx, max_batch=64, layout="csr")
    assert srv.engine.packed is pidx.labels   # same object, zero repack
    s, t, wl = random_queries_for(pidx, 200, seed=4)
    got = srv.query_many(s, t, wl)
    exp = pidx.to_index().query_batch(s, t, wl)
    assert np.array_equal(got, exp)


def random_queries_for(idx, n, seed):
    rng = np.random.default_rng(seed)
    s = rng.integers(0, idx.num_nodes, n).astype(np.int32)
    t = rng.integers(0, idx.num_nodes, n).astype(np.int32)
    wl = rng.integers(0, idx.num_levels, n).astype(np.int32)
    return s, t, wl


# ------------------------------------------------------- result eviction
def test_results_do_not_grow_across_epochs(small_index, serve_layout):
    """Regression for the unbounded-results leak: delivered rids are popped
    (read-once), so the dict stays empty after each query_many epoch
    instead of accumulating one entry per request forever."""
    srv = WCSDServer(small_index, max_batch=32, layout=serve_layout)
    s, t, wl = random_queries_for(small_index, 100, seed=1)
    for epoch in range(3):
        srv.query_many(s, t, wl)
        assert len(srv.results) == 0, epoch
    assert srv.stats.requests == 300


def test_result_is_read_once(small_index, serve_layout):
    srv = WCSDServer(small_index, max_batch=64, layout=serve_layout)
    rid = srv.submit(3, 9, 1)
    first = srv.result(rid)
    assert first is not None
    with pytest.raises(UnknownRequestError):   # delivered -> evicted
        srv.result(rid)
    # the memo still answers a re-submission without device work
    rid2 = srv.submit(3, 9, 1)
    assert srv.stats.memo_hits == 1 and srv.result(rid2) == first


# ----------------------------------------------------------- async flush
def test_auto_flush_is_async_and_double_buffered(small_index, serve_layout):
    """Hitting max_batch dispatches the batch (batches increments, pending
    clears) but does NOT materialize results; the host keeps queueing the
    next batch while one is in flight, and at most one is in flight."""
    srv = WCSDServer(small_index, max_batch=4, layout=serve_layout)
    rids = [srv.submit(i, i + 30, 0) for i in range(4)]
    assert srv.stats.batches == 1
    assert srv._inflight is not None       # dispatched, not drained
    assert len(srv.results) == 0           # nothing materialized yet
    more = [srv.submit(i + 10, i + 60, 0) for i in range(4)]  # batch k+1
    assert srv.stats.batches == 2          # launching k+1 drained k
    assert all(r in srv.results for r in rids)
    out = [srv.result(r) for r in rids + more]   # drains batch k+1
    assert all(o is not None for o in out)
    assert srv._inflight is None and len(srv.results) == 0


def test_duplicate_submitted_while_in_flight_hits_memo(small_index,
                                                       serve_layout):
    """A hot key re-submitted while its batch is still in flight must
    piggyback on the in-flight computation (a memo hit), not queue a
    second device batch — the heavy-tailed workload the memo exists for."""
    srv = WCSDServer(small_index, max_batch=2, layout=serve_layout)
    r1 = srv.submit(3, 9, 1)
    srv.submit(5, 11, 0)               # hits max_batch -> async dispatch
    assert srv._inflight is not None and srv.stats.batches == 1
    r3 = srv.submit(3, 9, 1)           # duplicate of in-flight r1
    assert srv.stats.memo_hits == 1
    assert srv.pending == []           # piggybacked, not re-queued
    got3 = srv.result(r3)              # drains the in-flight batch
    assert got3 is not None and got3 == srv.result(r1)
    assert srv.stats.batches == 1      # no second device batch


def test_async_results_match_sync(small_index, serve_layout):
    s, t, wl = random_queries_for(small_index, 200, seed=3)
    srv = WCSDServer(small_index, max_batch=16, layout=serve_layout)
    got = srv.query_many(s, t, wl)           # many async auto-flushes
    exp = small_index.query_batch(s, t, wl)
    assert np.array_equal(got, exp)


# ------------------------------------------------------- engine plumbing
def test_interpret_and_backend_plumbing(small_index):
    """Regression: serving must be able to reach the compiled kernel path —
    use_pallas / interpret / layout flow through to the engine instead of
    being hardwired."""
    srv = WCSDServer(small_index, layout="csr", use_pallas=True,
                     interpret=False)
    assert srv.engine.use_pallas and srv.engine.interpret is False
    assert srv.engine.layout == "csr"
    srv2 = WCSDServer(small_index, interpret=True)
    assert srv2.engine.interpret is True
    from repro.core.query import DeviceQueryEngine, ShardedQueryEngine
    from repro.launch.mesh import make_serving_mesh
    assert isinstance(srv.engine, DeviceQueryEngine)
    srv3 = WCSDServer(small_index, backend="sharded", layout="csr",
                      interpret=False, mesh=make_serving_mesh())
    assert isinstance(srv3.engine, ShardedQueryEngine)
    assert srv3.engine.interpret is False
    with pytest.raises(ValueError):
        WCSDServer(small_index, backend="nope")


def test_prebuilt_engine_injection(small_index):
    from repro.core.query import DeviceQueryEngine
    eng = DeviceQueryEngine(small_index, layout="csr")
    srv = WCSDServer(engine=eng, max_batch=32)
    assert srv.engine is eng
    s, t, wl = random_queries_for(small_index, 50, seed=6)
    assert np.array_equal(srv.query_many(s, t, wl),
                          small_index.query_batch(s, t, wl))


# ------------------------------------------------------------ edge cases
def test_empty_batch_paths(small_index, serve_layout):
    """Empty pending through flush()/flush_async(), and an empty
    query_many, must be no-ops."""
    srv = WCSDServer(small_index, max_batch=8, layout=serve_layout)
    srv.flush()
    srv.flush_async()
    assert srv.stats.batches == 0
    out = srv.query_many(np.array([], np.int32), np.array([], np.int32),
                         np.array([], np.int32))
    assert out.shape == (0,) and srv.stats.batches == 0


def test_plan_query_batch_empty():
    from repro.core.query import plan_query_batch
    bucket_of = np.zeros(10, np.int32)
    assert plan_query_batch(bucket_of, np.array([], np.int32),
                            np.array([], np.int32)) == []


def test_single_bucket_store_serves(small_index):
    """A store whose every label row fits one bucket exercises the planner's
    single-sub-batch path end to end."""
    packed = small_index.packed()
    assert packed.num_buckets == 1   # 120-vertex index: all rows < 128
    srv = WCSDServer(small_index, max_batch=32, layout="csr")
    s, t, wl = random_queries_for(small_index, 80, seed=2)
    assert np.array_equal(srv.query_many(s, t, wl),
                          small_index.query_batch(s, t, wl))


def test_duplicate_keys_both_orientations_one_flush(small_index):
    """undirected=True: both orientations of (s, t) plus exact duplicates
    inside ONE flush canonicalize to a single memo entry — and, with
    pending-batch dedup, a single device slot — and all get the same
    (correct) answer."""
    srv = WCSDServer(small_index, max_batch=1024, undirected=True)
    exp = int(small_index.query_batch(np.array([7]), np.array([2]),
                                      np.array([0]))[0])
    rids = [srv.submit(7, 2, 0), srv.submit(2, 7, 0),
            srv.submit(7, 2, 0), srv.submit(2, 7, 0)]
    assert srv.stats.memo_hits == 3          # piggybacked on the queued slot
    assert len(srv.pending) == 1             # ONE device slot for the key
    srv.flush()                              # one batch answers all four
    assert srv.stats.batches == 1
    assert srv.stats.max_batch == 1          # the batch held one real row
    assert [srv.result(r) for r in rids] == [exp] * 4
    assert (2, 7, 0) in srv.memo and (7, 2, 0) not in srv.memo
    assert len([k for k in srv.memo if k[2] == 0]) == 1


# --------------------------------------------------- pending-batch dedup
def test_pending_dedup_single_device_slot(small_index, serve_layout):
    """Regression (pending dedup): duplicates of a key submitted BEFORE
    any flush must ride the queued request's batch slot, not occupy extra
    device rows — pre-fix, the batch held three rows and memo_hits stayed
    0 until the flush landed."""
    srv = WCSDServer(small_index, max_batch=1024, layout=serve_layout)
    seen = []
    inner = srv.engine.query_async   # bound class method, pre-stub
    srv.engine.query_async = None
    srv.engine.query = lambda s, t, w: (seen.append(len(np.asarray(s)))
                                        or inner(s, t, w).wait())
    exp = int(small_index.query_batch(np.array([7]), np.array([2]),
                                      np.array([0]))[0])
    rids = [srv.submit(7, 2, 0), srv.submit(2, 7, 0), srv.submit(7, 2, 0)]
    assert len(srv.pending) == 1           # one slot for the hot key
    assert srv.stats.memo_hits == 2        # piggybacks count as hits
    srv.flush()
    assert seen[-1] == 1                   # device saw ONE row, not three
    assert [srv.result(r) for r in rids] == [exp] * 3


def test_pending_dedup_profiles(small_index, serve_layout):
    """The profile queue dedups pending pairs the same way (both
    orientations canonicalize onto one queued staircase)."""
    srv = WCSDServer(small_index, max_batch=1024, layout=serve_layout)
    seen = []
    inner = srv.engine.query_profile_async
    srv.engine.query_profile_async = None
    srv.engine.query_profile = lambda s, t: (seen.append(len(np.asarray(s)))
                                             or inner(s, t).wait())
    r1 = srv.submit_profile(4, 9)
    r2 = srv.submit_profile(9, 4)          # canonicalizes onto the queued pair
    r3 = srv.submit_profile(4, 9)
    assert len(srv.pending_profiles) == 1
    assert srv.stats.memo_hits == 2
    srv.flush()
    assert seen[-1] == 1
    a, b, c = (srv.profile_result(r) for r in (r1, r2, r3))
    assert a is not None and np.array_equal(a, b) and np.array_equal(a, c)


# ------------------------------------------------------ dispatch failure
def test_transient_dispatch_failure_is_absorbed(small_index, serve_layout):
    """The flush watchdog (docs/resilience.md): a single engine raise at
    dispatch time is retried with backoff inside flush() — the caller
    never sees it, the requests are answered, and the retry is counted."""
    srv = WCSDServer(small_index, max_batch=1024, layout=serve_layout,
                     backoff_base_ms=0.01)
    inner = srv.engine.query_async
    calls = {"n": 0}

    def flaky(s, t, w):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient dispatch failure")
        return inner(s, t, w)

    srv.engine.query_async = flaky
    rids = [srv.submit(i, i + 40, 0) for i in range(5)]
    srv.flush()                             # the raise is absorbed
    assert srv.stats.error_retries == 1
    assert srv.stats.demotions == 0 and srv.mode == "primary"
    assert srv.pending == []
    got = np.array([srv.result(r) for r in rids])
    s = np.arange(5, dtype=np.int32)
    exp = small_index.query_batch(s, s + 40, np.zeros(5, np.int32))
    assert np.array_equal(got, exp)
    assert calls["n"] == 2


def test_dispatch_failure_keeps_requests(small_index, serve_layout):
    """Regression (flush-path request loss): a terminally-failing dispatch
    — the retry budget exhausted on an engine= server, which has no
    fallback ladder to demote down — must leave every queued request
    pending (nothing dropped), and a later result() must still answer
    them once the engine recovers."""
    from repro.core.query import DeviceQueryEngine

    eng = DeviceQueryEngine(small_index, layout=serve_layout)
    calls = {"n": 0}

    class FlakyEngine:
        layout = serve_layout
        query_profile = eng.query_profile

        def query(self, s, t, w):
            calls["n"] += 1
            if calls["n"] <= 2:             # budget is 1 retry -> exhausted
                raise RuntimeError("dispatch failure")
            return eng.query(s, t, w)

    srv = WCSDServer(engine=FlakyEngine(), max_batch=1024,
                     max_retries=1, backoff_base_ms=0.01)
    assert srv.mode == "injected"           # no ladder to absorb the loss
    rids = [srv.submit(i, i + 40, 0) for i in range(5)]
    with pytest.raises(FlushRetryExhausted):
        srv.flush()
    assert srv.stats.error_retries == 1 and srv.stats.exhausted == 1
    assert len(srv.pending) == 5            # nothing dropped
    assert srv._pending_rids == set(rids)
    assert srv.stats.batches == 0           # the failed dispatch never landed
    got = np.array([srv.result(r) for r in rids])   # result() retries
    s = np.arange(5, dtype=np.int32)
    exp = small_index.query_batch(s, s + 40, np.zeros(5, np.int32))
    assert np.array_equal(got, exp)
    assert calls["n"] == 3


def test_profile_dispatch_failure_keeps_profiles(small_index, serve_layout):
    """Partial failure: the scalar half of a mixed flush dispatches, the
    profile dispatch raises until the budget is exhausted — the profile
    queue must survive intact and a retry must answer both halves."""
    srv = WCSDServer(small_index, max_batch=1024, layout=serve_layout,
                     backoff_base_ms=0.01)
    inner = srv.engine.query_profile_async
    calls = {"n": 0}

    def flaky(s, t):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("profile dispatch failure")
        return inner(s, t)

    srv.engine.query_profile_async = flaky
    rs = srv.submit(3, 9, 1)
    rp = srv.submit_profile(4, 11)
    srv.flush()                             # watchdog absorbs the raise
    assert srv.stats.error_retries == 1
    assert not srv.pending and not srv.pending_profiles
    prof = srv.profile_result(rp)
    assert prof is not None and len(prof) == small_index.num_levels + 1
    assert srv.result(rs) is not None
    assert calls["n"] == 2


# -------------------------------------------------------- latency stats
def test_flush_time_split_and_latency(small_index, serve_layout):
    """flush_time_s is the sum of its two new components (dispatch vs
    drain wait), and every request gets an enqueue->deliver latency
    sample — memo hits included."""
    srv = WCSDServer(small_index, max_batch=16, layout=serve_layout)
    s, t, wl = random_queries_for(small_index, 64, seed=12)
    srv.query_many(s, t, wl)
    st = srv.stats
    assert st.dispatch_time_s > 0.0 and st.drain_wait_s > 0.0
    assert st.flush_time_s == pytest.approx(st.dispatch_time_s
                                            + st.drain_wait_s)
    lat = srv.latency_summary()
    assert lat["count"] == 64               # all delivered -> all sampled
    assert lat["p99_us"] >= lat["p50_us"] >= 0.0
    assert not srv._enqueue_t               # no stamp leaks


# ---------------------------------------------------- continuous batching
class _Gate:
    """Controllable readiness probe injected into PendingResult deps, so
    tests decide when the 'device' looks done without real async work."""

    def __init__(self):
        self.ready = False

    def is_ready(self):
        return self.ready


def _gate_engine(srv):
    """Wrap engine.query_async so every dispatched handle reports ready()
    only once the returned gate is opened (wait() still works)."""
    from repro.core.query import PendingResult
    gate = _Gate()
    inner = srv.engine.query_async
    srv.engine.query_async = lambda s, t, w: PendingResult(
        inner(s, t, w).wait, deps=(gate,))
    return gate


def test_opportunistic_flush_below_max_batch(small_index, serve_layout):
    """With a deadline configured and the in-flight slot free, min_batch
    queued requests dispatch immediately — no waiting for max_batch."""
    srv = WCSDServer(small_index, max_batch=1024, layout=serve_layout,
                     max_wait_us=10_000_000.0, min_batch=3)
    rids = [srv.submit(i, i + 30, 0) for i in range(3)]
    assert srv.stats.batches == 1          # fired at min_batch, not 1024
    assert srv.stats.opportunistic_flushes == 1
    assert srv.stats.deadline_flushes == 0
    assert srv._inflight is not None and srv.pending == []
    assert all(srv.result(r) is not None for r in rids)


def test_below_min_batch_never_early_flushes(small_index, serve_layout):
    """min_batch is an admission floor: under it, even an expired deadline
    does not fire (max_batch remains the only trigger)."""
    srv = WCSDServer(small_index, max_batch=1024, layout=serve_layout,
                     max_wait_us=0.0, min_batch=4)
    for i in range(3):
        srv.submit(i, i + 30, 0)
    assert srv.stats.batches == 0 and len(srv.pending) == 3


def test_deadline_flush_with_busy_slot(small_index, serve_layout):
    """While a batch is in flight and its device work unfinished, newly
    queued requests flush on the max_wait_us deadline instead of waiting
    for the slot (or for max_batch)."""
    srv = WCSDServer(small_index, max_batch=1024, layout=serve_layout,
                     max_wait_us=0.0, min_batch=2)
    gate = _gate_engine(srv)
    first = [srv.submit(i, i + 50, 0) for i in range(2)]
    assert srv.stats.opportunistic_flushes == 1 and srv.stats.batches == 1
    assert not gate.ready                  # device "still computing"
    r5 = srv.submit(40, 90, 1)
    assert srv.stats.batches == 1          # below min_batch: still queued
    r6 = srv.submit(41, 91, 1)             # min_batch hit, slot busy, 0µs
    assert srv.stats.batches == 2
    assert srv.stats.deadline_flushes == 1
    gate.ready = True
    assert all(srv.result(r) is not None for r in first + [r5, r6])


def test_poll_harvests_and_flushes(small_index, serve_layout):
    """poll(): a finished in-flight batch is drained without blocking and
    the queued requests dispatch opportunistically into the freed slot."""
    srv = WCSDServer(small_index, max_batch=1024, layout=serve_layout,
                     max_wait_us=1e9, min_batch=1)
    gate = _gate_engine(srv)
    r1 = srv.submit(3, 9, 1)       # min_batch=1, slot free -> dispatches
    assert srv.stats.opportunistic_flushes == 1
    r2 = srv.submit(5, 11, 0)      # slot busy, huge deadline -> queued
    assert srv.stats.batches == 1 and len(srv.pending) == 1
    srv.poll()                     # busy slot: nothing happens
    assert srv.stats.batches == 1 and r1 not in srv.results
    gate.ready = True
    srv.poll()                     # harvests batch 1, dispatches batch 2
    assert r1 in srv.results       # delivered without result() blocking
    assert srv.stats.batches == 2
    assert srv.stats.opportunistic_flushes == 2
    assert srv.result(r1) is not None and srv.result(r2) is not None


def test_mixed_flush_single_slot_continuous(small_index, serve_layout):
    """An early flush carries the scalar AND profile queues together as
    the single in-flight slot (stats.batches counts the pair once)."""
    srv = WCSDServer(small_index, max_batch=1024, layout=serve_layout,
                     max_wait_us=0.0, min_batch=2)
    rs = srv.submit(3, 9, 1)
    rp = srv.submit_profile(4, 11)         # npend=2 -> early flush
    assert srv.stats.batches == 1
    assert srv._inflight is not None and srv._inflight_prof is not None
    assert srv.result(rs) is not None
    prof = srv.profile_result(rp)
    assert prof is not None and len(prof) == small_index.num_levels + 1


# ------------------------------------------- continuous-traffic harness
def _random_mutation(rng, g):
    """1-2 random inserts/deletes over ``g`` (valid levels only)."""
    inserts, deletes = [], []
    for _ in range(int(rng.integers(1, 3))):
        half = np.flatnonzero(g.edges_src < g.edges_dst)
        if rng.random() < 0.45 and len(half):
            e = int(rng.choice(half))
            deletes.append((int(g.edges_src[e]), int(g.edges_dst[e])))
        else:
            u, v = (int(x) for x in rng.choice(g.num_nodes, 2,
                                               replace=False))
            inserts.append((u, v, float(rng.choice(g.levels))))
    return inserts, deletes


@pytest.mark.parametrize("mode", ["device", "sharded", "dynamic"])
def test_continuous_traffic_differential(mode):
    """Randomized interleaved traffic — submit / submit_profile / result /
    poll (/ apply_updates in dynamic mode) — under deadline flushes,
    differentially checked against the BFS oracle grid, then the bulk
    query_many path over the same stream."""
    from repro.core.baselines import constrained_distance_grid
    from repro.core.generators import erdos_renyi

    g = erdos_renyi(40, 3.0, num_levels=3, seed=21)
    idx = build_wc_index(g, ordering="degree")
    kw = dict(max_batch=32, max_wait_us=0.0, min_batch=4, layout="csr",
              use_pallas=True, interpret=True)
    if mode == "sharded":
        from repro.launch.mesh import make_serving_mesh
        srv = WCSDServer(idx, backend="sharded", mesh=make_serving_mesh(),
                         **kw)
    elif mode == "dynamic":
        srv = WCSDServer(idx, graph=g, compact_threshold=None, **kw)
    else:
        srv = WCSDServer(idx, **kw)

    rng = np.random.default_rng(77)
    grid = constrained_distance_grid(g)
    V, W = g.num_nodes, g.num_levels
    exp_scalar, exp_prof = {}, {}   # rid -> expectation at submit time
    out_scalar = {}                 # rid -> value read mid-stream
    unread = []                     # scalar rids not yet result()-ed
    submitted = []

    for step in range(160):
        op = rng.random()
        if op < 0.55:
            s, t = int(rng.integers(V)), int(rng.integers(V))
            wl = int(rng.integers(W))
            rid = srv.submit(s, t, wl)
            exp_scalar[rid] = int(grid[s, t, wl])
            unread.append(rid)
            submitted.append((s, t, wl))
        elif op < 0.72:
            s, t = int(rng.integers(V)), int(rng.integers(V))
            rid = srv.submit_profile(s, t)
            exp_prof[rid] = grid[s, t, :].copy()
        elif op < 0.84 and unread:
            rid = unread.pop(int(rng.integers(len(unread))))
            out_scalar[rid] = srv.result(rid)   # may force a flush
        elif op < 0.90:
            srv.poll()
        elif mode == "dynamic" and op < 0.93:
            ins, dels = _random_mutation(rng, srv.index.graph)
            srv.apply_updates(inserts=ins, deletes=dels)
            grid = constrained_distance_grid(srv.index.graph)
        # else: idle tick

    srv.flush()
    for rid in unread:
        out_scalar[rid] = srv.result(rid)
    for rid, exp in exp_scalar.items():
        assert out_scalar[rid] == exp, rid
    for rid, exp in exp_prof.items():
        got = srv.profile_result(rid)
        assert got is not None and np.array_equal(got, exp), rid

    # continuous batching actually fired below the hard cap
    assert srv.stats.opportunistic_flushes + srv.stats.deadline_flushes > 0
    assert srv.stats.max_batch < kw["max_batch"]
    lat = srv.latency_summary()
    assert lat["count"] == srv.stats.requests + srv.stats.profile_requests

    # the epoch-flush bulk path over the same scalar stream agrees with
    # the (final) oracle grid
    if submitted:
        s, t, wl = (np.array(x, np.int32) for x in zip(*submitted))
        assert np.array_equal(srv.query_many(s, t, wl), grid[s, t, wl])

"""ShardedQueryEngine + async WCSDServer on multi-device meshes.

The bit-for-bit acceptance test runs in a subprocess with 8 virtual host
devices (the device count must be fixed before jax initializes; the main
pytest process keeps its default single device) by invoking the same
`launch.dryrun --serve` entry point CI runs, so the test and the CI step
cannot drift apart. In-process tests cover the engine's code paths on a
1-device mesh and the row-gather collective math that the vertex-sharded
fallback rests on.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.generators import scale_free
from repro.core.query import DeviceQueryEngine, ShardedQueryEngine
from repro.core.serve import WCSDServer
from repro.core.wc_index import build_wc_index
from repro.launch.mesh import make_serving_mesh

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def small_index():
    return build_wc_index(scale_free(150, 3, num_levels=4, seed=12),
                          ordering="degree")


def _queries(idx, n, seed):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, idx.num_nodes, n).astype(np.int32),
            rng.integers(0, idx.num_nodes, n).astype(np.int32),
            rng.integers(0, idx.num_levels, n).astype(np.int32))


# --------------------------------------------------- in-process (1 device)
@pytest.mark.parametrize("layout", ["padded", "csr"])
@pytest.mark.parametrize("budget", [None, 1])
def test_sharded_engine_single_device_mesh(small_index, layout, budget):
    """Both placements (replicated / sharded_labels) degenerate gracefully
    to a 1-device mesh and agree with the single-device engine exactly."""
    mesh = make_serving_mesh()
    eng = ShardedQueryEngine(small_index, mesh=mesh, layout=layout,
                             device_budget_bytes=budget)
    assert eng.mode == ("replicated" if budget is None else "sharded_labels")
    s, t, wl = _queries(small_index, 300, seed=3)
    exp = np.asarray(DeviceQueryEngine(small_index,
                                       layout=layout).query(s, t, wl))
    got = np.asarray(eng.query(s, t, wl))
    assert np.array_equal(got, exp)


def test_sharded_engine_rejects_bad_args(small_index):
    with pytest.raises(ValueError):
        ShardedQueryEngine(small_index, mesh=make_serving_mesh(),
                           layout="nope")
    with pytest.raises(ValueError):
        ShardedQueryEngine(small_index, mesh=make_serving_mesh(),
                           layout="csr", cap=4)


def test_sharded_server_single_device_mesh(small_index):
    srv = WCSDServer(small_index, max_batch=32, backend="sharded",
                     layout="csr", mesh=make_serving_mesh())
    s, t, wl = _queries(small_index, 150, seed=5)
    got = srv.query_many(s, t, wl)
    assert np.array_equal(got, small_index.query_batch(s, t, wl))
    assert len(srv.results) == 0      # read-once delivery drained


# ------------------------------------------------- subprocess (8 devices)
def test_dryrun_serve_eight_virtual_devices():
    """Acceptance: the CI dryrun — ShardedQueryEngine (replicated AND
    vertex-sharded, single- and multi-pod meshes) + async WCSDServer on 8
    virtual host devices, bit-for-bit against the single-device engine on
    differential-harness instances."""
    env = {**os.environ, "PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu"}
    env.pop("XLA_FLAGS", None)   # dryrun sets the device count itself
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--serve", "--quick"],
        capture_output=True, text=True, env=env, timeout=420)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "serve dryrun PASS on 8 virtual devices" in r.stdout
    assert r.stdout.count("bit-identical") >= 8  # 2 instances x 4 modes
    # the profile path is part of the same acceptance sweep
    assert r.stdout.count("queries + profiles bit-identical") >= 8
    assert "(+profiles)" in r.stdout             # async server epoch


def test_row_gather_collectives_eight_devices():
    """row_gather_psum / row_gather_psum_scatter: exact gather from a
    block-row-sharded array, replicated and scattered forms."""
    prog = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.query import shard_map_compat
from repro.distributed.collectives import (row_gather_psum,
                                           row_gather_psum_scatter)
from repro.launch.mesh import make_serving_mesh
mesh = make_serving_mesh()
V, W, B = 64, 16, 32
rng = np.random.default_rng(0)
store = rng.integers(-5, 100, (V, W)).astype(np.int32)
rows = rng.integers(0, V, B).astype(np.int32)
per = V // 8
f = jax.jit(shard_map_compat(
    lambda sh, rr: row_gather_psum(sh, rr, ("data",), per),
    mesh, (P("data", None), P(None)), P(None)))
np.testing.assert_array_equal(np.asarray(f(store, rows)), store[rows])
g = jax.jit(shard_map_compat(
    lambda sh, rr: row_gather_psum_scatter(sh, rr, ("data",), per),
    mesh, (P("data", None), P(None)), P("data")))
np.testing.assert_array_equal(np.asarray(g(store, rows)), store[rows])
print("OK row gather")

# fused multi-array gather (ONE reduce-scatter for hub/dist/wlev + a
# count column) == per-array gathers, exactly
from repro.distributed.collectives import multi_row_gather_psum_scatter
store2 = rng.integers(0, 7, (V, 3)).astype(np.int32)
col = rng.integers(1, 50, (V, 1)).astype(np.int32)
m = jax.jit(shard_map_compat(
    lambda a, b, c, rr: multi_row_gather_psum_scatter(
        (a, b, c), rr, ("data",), per),
    mesh, (P("data", None),) * 3 + (P(None),), (P("data"),) * 3))
ga, gb, gc = (np.asarray(x) for x in m(store, store2, col, rows))
np.testing.assert_array_equal(ga, store[rows])
np.testing.assert_array_equal(gb, store2[rows])
np.testing.assert_array_equal(gc, col[rows])
print("OK fused multi row gather")

# ServeConfig.multi_pod reaches the engine's mesh (regression: the flag
# used to be dropped by server_kwargs)
from repro.configs.wcsd_serve import ServeConfig
from repro.core.serve import WCSDServer
from repro.core.generators import scale_free
from repro.core.wc_index import build_wc_index
idx = build_wc_index(scale_free(60, 3, num_levels=3, seed=1))
srv = WCSDServer(idx, **ServeConfig(multi_pod=True, max_batch=32).server_kwargs())
assert srv.engine.mesh.axis_names == ("pod", "data"), srv.engine.mesh
s = np.arange(30, dtype=np.int32)
assert np.array_equal(srv.query_many(s, s, np.zeros(30, np.int32)),
                      np.zeros(30, np.int32))
print("OK multi_pod config plumb")
"""
    env = {**os.environ, "PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu"}
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=env, timeout=300)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "OK row gather" in r.stdout
    assert "OK fused multi row gather" in r.stdout
    assert "OK multi_pod config plumb" in r.stdout

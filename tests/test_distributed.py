"""Distributed behavior on 8 virtual CPU devices. Each test runs in a
subprocess because the device count must be fixed before jax initializes
(the main test process keeps the default single device)."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

# these tests drive the explicit-mesh API surface (jax.sharding.AxisType,
# jax.set_mesh, jax.shard_map); on older jax the APIs do not exist at all,
# so gate instead of failing on an AttributeError in the subprocess
pytestmark = pytest.mark.skipif(
    not (hasattr(jax.sharding, "AxisType") and hasattr(jax, "set_mesh")
         and hasattr(jax, "shard_map")),
    reason="needs jax explicit-mesh APIs (AxisType/set_mesh/shard_map)")

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str):
    prog = textwrap.dedent(body)
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": SRC}
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=env, timeout=420)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_lm_train_step_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_arch
        from repro.models import transformer as T
        from repro.train import optim as O
        from repro.train.loop import make_train_step
        cfg = get_arch('llama3-8b').smoke_config()
        params = T.init_params(cfg, jax.random.key(0))
        ocfg = O.OptimizerConfig(lr=1e-3)
        opt = O.init_opt_state(ocfg, params)
        toks = np.random.default_rng(0).integers(0, cfg.vocab, (8, 32)).astype(np.int32)
        batch = {'tokens': jnp.asarray(toks), 'labels': jnp.asarray(toks)}
        step = make_train_step(lambda p, b: T.loss_fn(p, cfg, b), ocfg)
        # single device
        p1, o1, m1 = jax.jit(step)(params, opt, batch)
        # 4x2 mesh, batch sharded over data
        mesh = jax.make_mesh((4, 2), ('data', 'model'),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        bspec = {'tokens': P('data', None), 'labels': P('data', None)}
        with jax.set_mesh(mesh):
            p2, o2, m2 = jax.jit(step, in_shardings=(None, None, bspec))(
                params, opt, batch)
        assert np.allclose(float(m1['loss']), float(m2['loss']), rtol=1e-4), \
            (float(m1['loss']), float(m2['loss']))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-3, atol=3e-5)
        print('OK sharded == single')
    """)
    assert "OK sharded == single" in out


def test_compressed_psum_shard_map():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.train.grad_compress import compressed_psum
        mesh = jax.make_mesh((8,), ('data',),
                             axis_types=(jax.sharding.AxisType.Auto,))
        x = np.random.default_rng(0).standard_normal((8, 64)).astype(np.float32)
        f = jax.shard_map(lambda v: compressed_psum(v[0], 'data'),
                          mesh=mesh, in_specs=P('data', None),
                          out_specs=P(None), check_vma=False)
        got = np.asarray(f(jnp.asarray(x)))
        exp = x.sum(0)
        rel = np.abs(got - exp).max() / np.abs(exp).max()
        assert rel < 0.02, rel
        print('OK compressed psum rel', rel)
    """)
    assert "OK compressed psum" in out


def test_pipeline_stage_permute():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.pipeline import gpipe_forward
        mesh = jax.make_mesh((4, 2), ('pod', 'data'),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        # 4 pipeline stages, each a linear layer
        rng = np.random.default_rng(0)
        ws = jnp.asarray(rng.standard_normal((4, 16, 16)).astype(np.float32) * 0.3)
        x = jnp.asarray(rng.standard_normal((8, 16, 16)).astype(np.float32))
        y = gpipe_forward(mesh, ws, x, n_microbatches=8)
        # reference: sequential application
        ref = x
        for i in range(4):
            ref = jnp.tanh(ref @ ws[i])
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=2e-4, atol=2e-5)
        print('OK pipeline')
    """)
    assert "OK pipeline" in out


def test_wcsd_query_engine_sharded_batch():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core.generators import scale_free, random_queries
        from repro.core.wc_index import build_wc_index
        from repro.core.query import query_batch_jnp
        g = scale_free(100, 3, num_levels=3, seed=1)
        idx = build_wc_index(g)
        h, d, w, c = idx.padded_device_arrays()
        s, t, wl = random_queries(g, 64, seed=2)
        mesh = jax.make_mesh((8,), ('data',),
                             axis_types=(jax.sharding.AxisType.Auto,))
        with jax.set_mesh(mesh):
            f = jax.jit(query_batch_jnp,
                        in_shardings=(None, None, None, None,
                                      P('data'), P('data'), P('data')))
            got = np.asarray(f(jnp.asarray(h), jnp.asarray(d), jnp.asarray(w),
                               jnp.asarray(c), jnp.asarray(s), jnp.asarray(t),
                               jnp.asarray(wl)))
        exp = idx.query_batch(s, t, wl)
        assert np.array_equal(got, exp)
        print('OK sharded queries')
    """)
    assert "OK sharded queries" in out

"""CSR-packed label store + segmented query path: round-trip fidelity,
bucket-tiling invariants, planner coverage, and end-to-end agreement with
the numpy oracle (`WCIndex.query_batch`) across all quality levels."""
import numpy as np
import pytest

from repro.core.generators import random_queries, road_grid, scale_free
from repro.core.graph import INF_DIST
from repro.core.query import DeviceQueryEngine, plan_query_batch
from repro.core.wc_index import PackedLabels, build_wc_index, round_to_lane


def _indices():
    road = build_wc_index(road_grid(12, 12, num_levels=4, seed=2))
    social = build_wc_index(scale_free(300, 3, num_levels=5, seed=1),
                            ordering="degree")
    return {"road": road, "social": social}


@pytest.fixture(scope="module")
def indices():
    return _indices()


# ----------------------------------------------------------------- packing
def test_packed_round_trip(indices):
    for idx in indices.values():
        packed = idx.packed()
        assert packed.size_entries() == idx.size_entries()
        hub, dist, wlev, count = packed.to_padded(cap=idx.label_capacity)
        h2, d2, w2, c2 = idx.padded_device_arrays(cap=idx.label_capacity)
        assert np.array_equal(count, c2)
        col = np.arange(hub.shape[1])
        m = col[None, :] < count[:, None]
        for a, b in [(hub, h2), (dist, d2), (wlev, w2)]:
            assert np.array_equal(a[m], b[m])
            # pad cells carry the same sentinel contract on both paths
            assert np.array_equal(a[~m], b[~m])


def test_to_padded_trim_matches_padded_device_arrays(indices):
    """Regression: the trim rule (first cap-1 entries + the trailing self
    entry, count clamped) is identical on both padding paths, for caps
    small enough to actually drop entries."""
    for idx in indices.values():
        packed = idx.packed()
        for cap in (1, 2, 3):
            got = packed.to_padded(cap=cap)
            exp = idx.padded_device_arrays(cap)
            for a, b, name in zip(got, exp, ("hub", "dist", "wlev", "count")):
                assert np.array_equal(a, b), (cap, name)
            hub, dist, wlev, count = got
            v = np.arange(idx.num_nodes)
            last = np.maximum(count - 1, 0)
            assert np.array_equal(hub[v, last], idx.rank), cap
            assert np.all(dist[v, last] == 0), cap


def test_packed_rows_match_labels(indices):
    idx = indices["social"]
    packed = idx.packed()
    for v in range(0, idx.num_nodes, 17):
        c = int(idx.count[v])
        h, d, w = packed.row(v)
        assert np.array_equal(h, idx.hub_rank[v, :c])
        assert np.array_equal(d, idx.dist[v, :c])
        assert np.array_equal(w, idx.wlev[v, :c])


def test_bucket_invariants(indices):
    for idx in indices.values():
        packed = idx.packed()
        # widths are ascending multiples of 128
        assert np.all(packed.bucket_widths % 128 == 0)
        assert np.all(np.diff(packed.bucket_widths) > 0)
        # every vertex lands in exactly one bucket, in the smallest width
        # that fits its label row
        seen = np.zeros(packed.num_nodes, dtype=int)
        lens = packed.offsets[1:] - packed.offsets[:-1]
        for b, members in enumerate(packed.bucket_vertices):
            seen[members] += 1
            W = int(packed.bucket_widths[b])
            assert np.all(lens[members] <= W)
            if W > 128:
                assert np.all(lens[members] > W // 2), \
                    "vertex placed in a wider bucket than needed"
            # slot_of inverts bucket_vertices
            assert np.array_equal(packed.slot_of[members],
                                  np.arange(len(members)))
        assert np.all(seen == 1)


def test_bucket_tiles_pad_contract(indices):
    idx = indices["social"]
    packed = idx.packed()
    lens = packed.offsets[1:] - packed.offsets[:-1]
    for b in range(packed.num_buckets):
        hub, dist, wlev = packed.bucket_tiles(b)
        members = packed.bucket_vertices[b]
        assert hub.shape == (len(members), int(packed.bucket_widths[b]))
        col = np.arange(hub.shape[1])
        pad = col[None, :] >= lens[members][:, None]
        assert np.all(hub[pad] == -1)
        assert np.all(wlev[pad] == -1)
        assert np.all(dist[pad] == INF_DIST)
        for v in members[:: max(1, len(members) // 8)]:
            h, d, w = packed.row(int(v))
            slot = int(packed.slot_of[v])
            assert np.array_equal(hub[slot, :len(h)], h)
            assert np.array_equal(dist[slot, :len(d)], d)
            assert np.array_equal(wlev[slot, :len(w)], w)


def test_packed_memory_never_exceeds_padded(indices):
    for idx in indices.values():
        packed = idx.packed()
        padded_bytes = idx.num_nodes * idx.label_capacity * 12
        assert packed.memory_bytes() <= padded_bytes + packed.offsets.nbytes
        # tiles never exceed what the 128-aligned dense engine would ship
        cap128 = round_to_lane(idx.label_capacity)
        assert packed.tile_memory_bytes() <= idx.num_nodes * cap128 * 12


# ----------------------------------------------------------------- planner
def test_planner_partitions_batch(indices):
    idx = indices["social"]
    packed = idx.packed()
    rng = np.random.default_rng(0)
    s = rng.integers(0, idx.num_nodes, 200).astype(np.int32)
    t = rng.integers(0, idx.num_nodes, 200).astype(np.int32)
    plan = plan_query_batch(packed.bucket_of, s, t)
    allpos = np.concatenate([p.positions for p in plan])
    assert np.array_equal(np.sort(allpos), np.arange(200))
    for p in plan:
        assert np.all(packed.bucket_of[s[p.positions]] == p.bucket_s)
        assert np.all(packed.bucket_of[t[p.positions]] == p.bucket_t)


# ------------------------------------------------------------- end to end
@pytest.mark.parametrize("use_pallas", [False, True])
def test_segmented_matches_oracle_all_levels(indices, use_pallas):
    """Acceptance: segmented CSR path == numpy oracle on road-grid and
    scale-free graphs, across every w level (including the infeasible
    level == num_levels)."""
    for name, idx in indices.items():
        eng = DeviceQueryEngine(idx, layout="csr", use_pallas=use_pallas)
        rng = np.random.default_rng(7)
        n = 40
        s = rng.integers(0, idx.num_nodes, n).astype(np.int32)
        t = rng.integers(0, idx.num_nodes, n).astype(np.int32)
        for level in range(idx.num_levels + 1):
            wl = np.full(n, level, dtype=np.int32)
            got = np.asarray(eng.query(s, t, wl))
            exp = idx.query_batch(s, t, wl)
            assert np.array_equal(got, exp), (name, level)


def test_segmented_multi_bucket_cross_pairs():
    """A hub-heavy scale-free graph splits into >= 2 buckets; cross-bucket
    sub-batches must agree with the oracle too."""
    g = scale_free(1200, 4, num_levels=9, seed=42)
    idx = build_wc_index(g, ordering="degree")
    packed = idx.packed()
    assert packed.num_buckets >= 2, "config no longer exercises bucketing"
    # force queries that hit every bucket pair
    reps = [int(m[0]) for m in packed.bucket_vertices]
    s, t = [], []
    for a in reps:
        for b in reps:
            s.append(a), t.append(b)
    extra_s, extra_t, extra_w = random_queries(g, 100, seed=3)
    s = np.concatenate([np.array(s, np.int32), extra_s])
    t = np.concatenate([np.array(t, np.int32), extra_t])
    wl = np.concatenate([np.zeros(len(reps) ** 2, np.int32), extra_w])
    plan = plan_query_batch(packed.bucket_of, s, t)
    assert len(plan) >= packed.num_buckets ** 2
    eng = DeviceQueryEngine(idx, layout="csr", use_pallas=True)
    assert np.array_equal(np.asarray(eng.query(s, t, wl)),
                          idx.query_batch(s, t, wl))


def test_from_flat_round_trips_from_index(indices):
    """`from_flat` (the builder's emission entry point) and `from_index`
    (pack-after-build) agree on every derived table."""
    for idx in indices.values():
        a = idx.packed()
        b = PackedLabels.from_flat(a.hub_rank, a.dist, a.wlev, a.offsets)
        for field in ("hub_rank", "dist", "wlev", "offsets", "bucket_widths",
                      "bucket_of", "slot_of"):
            assert np.array_equal(getattr(a, field), getattr(b, field))
        for ma, mb in zip(a.bucket_vertices, b.bucket_vertices):
            assert np.array_equal(ma, mb)


def test_builder_append_finalize_matches_pack_after_build(indices):
    """Feeding a WCIndex's non-self entries hub-by-hub-batch through
    `PackedLabelsBuilder` reproduces `.packed()` exactly."""
    from repro.core.wc_index import PackedLabelsBuilder

    idx = indices["road"]
    V = idx.num_nodes
    c = idx.count
    rows = np.repeat(np.arange(V), c)
    cols = np.concatenate([np.arange(k) for k in c])
    h = idx.hub_rank[rows, cols]
    d = idx.dist[rows, cols]
    w = idx.wlev[rows, cols]
    not_self = h != idx.rank[rows]          # builder appends self entries
    rows, h, d, w = rows[not_self], h[not_self], d[not_self], w[not_self]
    builder = PackedLabelsBuilder(V)
    for lo in range(0, V, 32):              # ascending hub-rank slices
        m = (h >= lo) & (h < lo + 32)
        o = np.lexsort((d[m], h[m], rows[m]))
        builder.append_batch(rows[m][o], h[m][o], d[m][o], w[m][o])
    store, removed = builder.finalize(rank=idx.rank,
                                      num_levels=idx.num_levels)
    assert removed == 0                     # sequential index is minimal
    ref = idx.packed()
    for field in ("hub_rank", "dist", "wlev", "offsets"):
        assert np.array_equal(getattr(store, field), getattr(ref, field))


def test_segmented_kernel_vs_ref_op():
    """ops.wcsd_query_segmented kernel vs jnp ref on synthetic tiles with
    different side widths."""
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(11)
    Ns, Nt, Ws, Wt, B = 12, 20, 256, 128, 33
    hs = np.sort(rng.integers(-1, 40, size=(Ns, Ws)), 1).astype(np.int32)
    ht = np.sort(rng.integers(-1, 40, size=(Nt, Wt)), 1).astype(np.int32)
    ds = rng.integers(0, 100, size=(Ns, Ws)).astype(np.int32)
    dt = rng.integers(0, 100, size=(Nt, Wt)).astype(np.int32)
    ws = rng.integers(-1, 5, size=(Ns, Ws)).astype(np.int32)
    wt = rng.integers(-1, 5, size=(Nt, Wt)).astype(np.int32)
    srow = rng.integers(0, Ns, B).astype(np.int32)
    trow = rng.integers(0, Nt, B).astype(np.int32)
    wq = rng.integers(0, 6, B).astype(np.int32)
    args = tuple(jnp.asarray(a) for a in (hs, ds, ws, ht, dt, wt,
                                          srow, trow, wq))
    got = np.asarray(ops.wcsd_query_segmented(*args))
    exp = np.asarray(ops.wcsd_query_segmented(*args, use_kernel=False))
    np.testing.assert_array_equal(got, exp)


@pytest.mark.parametrize("Ws,Wt", [(192, 192), (96, 48), (48, 192)])
def test_segmented_kernel_non_multiple_widths(Ws, Wt):
    """Regression: tile widths that are NOT multiples of the 128 t-block
    (reachable via the engines' ``lane`` knob, e.g. lane=48) must not drop
    tail columns — a hub meeting only in the tile's last block was
    silently missed before the block width was fitted to divide Wt."""
    import jax.numpy as jnp

    from repro.kernels import ops

    hs = np.full((2, Ws), -1, np.int32)
    ht = np.full((2, Wt), -1, np.int32)
    ds = np.full((2, Ws), 7, np.int32)
    dt = np.full((2, Wt), 7, np.int32)
    ws = np.full((2, Ws), 3, np.int32)
    wt = np.full((2, Wt), 3, np.int32)
    hs[0, 0] = 5
    ht[0, Wt - 1] = 5          # the meet lives in the LAST t-column
    args = tuple(jnp.asarray(a) for a in (
        hs, ds, ws, ht, dt, wt, np.zeros(4, np.int32),
        np.zeros(4, np.int32), np.zeros(4, np.int32)))
    got = np.asarray(ops.wcsd_query_segmented(*args))
    exp = np.asarray(ops.wcsd_query_segmented(*args, use_kernel=False))
    np.testing.assert_array_equal(got, exp)
    assert got[0] == 14
    prof_args = args[:8]
    gp = np.asarray(ops.wcsd_profile_segmented(*prof_args, num_levels=3))
    ep = np.asarray(ops.wcsd_profile_segmented(*prof_args, num_levels=3,
                                               use_kernel=False))
    np.testing.assert_array_equal(gp, ep)
    assert gp[0, 3] == 14

"""Schema gate for the benchmark JSON artifacts (BENCH_*.json).

CI archives ``benchmarks/run.py --json`` output as the repo's perf
trajectory; these tests hold the same `validate_rows` gate the harness
applies before writing, against (a) a real tiny serving-suite run — so
the profile-vs-loop rows physically exist, not just pass review — and
(b) synthetic malformed rows, so the gate itself cannot rot.

Run from the repo root (CI and the tier-1 command both do), where the
``benchmarks`` namespace package is importable.
"""
import numpy as np
import pytest

from benchmarks import bench_wcsd
from benchmarks.run import REQUIRED_ALGOS, ROW_KEYS, validate_rows


@pytest.fixture(scope="module")
def serving_rows():
    # tiny config: the schema (which rows exist), not the numbers, is
    # what is under test here
    return bench_wcsd.bench_serving(batch=64, n_nodes=200)


def test_serving_suite_conforms_and_carries_profile_rows(serving_rows):
    validate_rows("serving", serving_rows)
    algos = {r["algo"] for r in serving_rows}
    assert {"profile_us_per_query", "profile_loop_us_per_query",
            "profile_speedup", "profile_levels"} <= algos
    by_algo = {r["algo"]: r["value"] for r in serving_rows}
    # the acceptance trend is asserted on the real bench graphs in CI;
    # here only sanity: L >= 4 levels and strictly positive timings
    assert by_algo["profile_levels"] >= 4
    assert by_algo["profile_us_per_query"] > 0
    assert by_algo["profile_loop_us_per_query"] > 0
    assert by_algo["profile_speedup"] == pytest.approx(
        by_algo["profile_loop_us_per_query"]
        / by_algo["profile_us_per_query"], rel=1e-6)


def test_row_keys_are_the_csv_header():
    assert ROW_KEYS == ("table", "dataset", "algo", "value")


def test_validate_rows_rejects_drift():
    good = [dict(table="serving", dataset="X", algo=a, value=1.0)
            for a in REQUIRED_ALGOS["serving"]]
    validate_rows("serving", good)                      # passes
    with pytest.raises(ValueError, match="non-empty row list"):
        validate_rows("serving", [])
    with pytest.raises(ValueError, match="missing"):
        validate_rows("x", [dict(table="t", dataset="d", algo="a")])
    with pytest.raises(ValueError, match="must be a number"):
        validate_rows("x", [dict(table="t", dataset="d", algo="a",
                                 value="fast")])
    with pytest.raises(ValueError, match="must be a number"):
        validate_rows("x", [dict(table="t", dataset="d", algo="a",
                                 value=True)])
    with pytest.raises(ValueError, match="non-empty string"):
        validate_rows("x", [dict(table="", dataset="d", algo="a",
                                 value=0.5)])
    # dropping a tracked serving metric is a schema break
    with pytest.raises(ValueError, match="dropped tracked"):
        validate_rows("serving", good[:-1] if good[-1]["algo"] != "qps"
                      else good[1:])
    # numpy scalars (what _time / len arithmetic can produce) are numbers
    validate_rows("x", [dict(table="t", dataset="d", algo="a",
                             value=float(np.float64(1.5)))])

"""Schema gate for the benchmark JSON artifacts (BENCH_*.json).

CI archives ``benchmarks/run.py --json`` output as the repo's perf
trajectory; these tests hold the same `validate_rows` gate the harness
applies before writing, against (a) a real tiny serving-suite run — so
the profile-vs-loop rows physically exist, not just pass review — and
(b) synthetic malformed rows, so the gate itself cannot rot.

Run from the repo root (CI and the tier-1 command both do), where the
``benchmarks`` namespace package is importable.
"""
import numpy as np
import pytest

from benchmarks import bench_wcsd
from benchmarks.run import (BASELINE_FILES, CHECK_CEILINGS, CHECK_FLOORS,
                            CHECK_GATES, REQUIRED_ALGOS, ROW_KEYS,
                            check_against_baseline, validate_rows)


@pytest.fixture(scope="module")
def serving_rows():
    # tiny config: the schema (which rows exist), not the numbers, is
    # what is under test here
    return bench_wcsd.bench_serving(batch=64, n_nodes=200)


def test_serving_suite_conforms_and_carries_profile_rows(serving_rows):
    validate_rows("serving", serving_rows)
    algos = {r["algo"] for r in serving_rows}
    assert {"profile_us_per_query", "profile_loop_us_per_query",
            "profile_speedup", "profile_levels"} <= algos
    by_algo = {r["algo"]: r["value"] for r in serving_rows}
    # the acceptance trend is asserted on the real bench graphs in CI;
    # here only sanity: L >= 4 levels and strictly positive timings
    assert by_algo["profile_levels"] >= 4
    assert by_algo["profile_us_per_query"] > 0
    assert by_algo["profile_loop_us_per_query"] > 0
    assert by_algo["profile_speedup"] == pytest.approx(
        by_algo["profile_loop_us_per_query"]
        / by_algo["profile_us_per_query"], rel=1e-6)
    # the row-sharded ragged + compressed-arena rows exist and are sane;
    # the >= 2x / >= 1.8x acceptance floors are enforced on the real
    # bench config by run.py --check (bytes ratio is machine-independent,
    # so it is asserted here too)
    assert {"rowsharded_ragged_us_per_query",
            "rowsharded_bucket_pair_us_per_query",
            "rowsharded_ragged_speedup", "compressed_bytes_ratio"} <= algos
    assert by_algo["rowsharded_ragged_us_per_query"] > 0
    assert by_algo["rowsharded_ragged_speedup"] > 0
    assert by_algo["compressed_bytes_ratio"] >= 1.8
    # the dynamic-index rows exist and are sane; the <= 1.15x overhead
    # ceiling is enforced on the real bench config by run.py --check
    assert {"update_apply_us", "compact_us",
            "delta_query_overhead"} <= algos
    assert by_algo["update_apply_us"] > 0
    assert by_algo["compact_us"] > 0
    assert by_algo["delta_query_overhead"] > 0
    # the continuous-batching latency rows and the DMA-ring overlap row
    # exist and are sane; the p99 ceiling / overlap floor are enforced on
    # the real bench config by run.py --check
    assert {"serve_p50_us", "serve_p99_us",
            "dma_overlap_speedup"} <= algos
    assert 0 < by_algo["serve_p50_us"] <= by_algo["serve_p99_us"]
    assert by_algo["dma_overlap_speedup"] > 0
    assert by_algo["dma_worklist_entries"] > 0
    # the resilience rows exist and are sane; the overhead/append
    # ceilings are enforced on the real bench config by run.py --check
    assert {"degraded_mode_overhead", "wal_append_us"} <= algos
    assert by_algo["degraded_mode_overhead"] > 0
    assert by_algo["wal_append_us"] > 0


def test_row_keys_are_the_csv_header():
    assert ROW_KEYS == ("table", "dataset", "algo", "value")


def test_validate_rows_rejects_drift():
    good = [dict(table="serving", dataset="X", algo=a, value=1.0)
            for a in REQUIRED_ALGOS["serving"]]
    validate_rows("serving", good)                      # passes
    with pytest.raises(ValueError, match="non-empty row list"):
        validate_rows("serving", [])
    with pytest.raises(ValueError, match="missing"):
        validate_rows("x", [dict(table="t", dataset="d", algo="a")])
    with pytest.raises(ValueError, match="must be a number"):
        validate_rows("x", [dict(table="t", dataset="d", algo="a",
                                 value="fast")])
    with pytest.raises(ValueError, match="must be a number"):
        validate_rows("x", [dict(table="t", dataset="d", algo="a",
                                 value=True)])
    with pytest.raises(ValueError, match="non-empty string"):
        validate_rows("x", [dict(table="", dataset="d", algo="a",
                                 value=0.5)])
    # dropping a tracked serving metric is a schema break
    with pytest.raises(ValueError, match="dropped tracked"):
        validate_rows("serving", good[:-1] if good[-1]["algo"] != "qps"
                      else good[1:])
    # numpy scalars (what _time / len arithmetic can produce) are numbers
    validate_rows("x", [dict(table="t", dataset="d", algo="a",
                             value=float(np.float64(1.5)))])


# ------------------------------------------------- --check regression gate
def _row(algo, value, table="serving", dataset="S"):
    return dict(table=table, dataset=dataset, algo=algo, value=value)


def test_check_against_baseline_passes_within_tolerance():
    kb = [_row("cmp_ratio", 10.0, table="kernel_segmented"),
          _row("hbm_ratio", 5.0, table="kernel_segmented"),
          _row("seg_us_per_query", 50.0, table="kernel_segmented")]
    fresh = [_row("cmp_ratio", 8.0, table="kernel_segmented"),   # 1.25x ok
             _row("hbm_ratio", 5.0, table="kernel_segmented"),
             _row("seg_us_per_query", 500.0, table="kernel_segmented")]
    assert check_against_baseline("kernel_segmented", fresh, kb) == []
    # wall-clock serving metrics are archived but NOT relatively gated
    # (cross-machine); only the same-run speedup floors apply
    fresh_srv = [_row("us_per_query", 1e9), _row("ragged_speedup", 5.0),
                 _row("ragged_buckets", 8.0)]
    assert check_against_baseline(
        "serving", fresh_srv, [_row("us_per_query", 100.0)]) == []


def test_check_against_baseline_fails_on_regression():
    # higher-is-better direction: the kernel traffic ratio collapsing
    kb = [_row("traffic_ratio", 50.0, table="kernel_wcsd_query")]
    fails = check_against_baseline(
        "kernel_query", [_row("traffic_ratio", 30.0,
                              table="kernel_wcsd_query")], kb)
    assert len(fails) == 1 and "worse than baseline" in fails[0]


def test_check_against_baseline_enforces_floors_and_presence():
    # the >= 2x ragged acceptance floor holds independent of the baseline
    fails = check_against_baseline(
        "serving", [_row("ragged_speedup", 1.5)], [])
    assert len(fails) == 1 and "absolute floor" in fails[0]
    # a gated baseline metric missing from the fresh run is a failure
    fails = check_against_baseline(
        "kernel_cin", [], [_row("ratio", 16.0, table="kernel_cin")])
    assert len(fails) == 1 and "missing" in fails[0]


def test_check_against_baseline_enforces_ceilings():
    # the <= 1.15x delta serving tax holds independent of the baseline
    fails = check_against_baseline(
        "serving", [_row("delta_query_overhead", 1.4)], [])
    assert len(fails) == 1 and "absolute ceiling" in fails[0]
    assert check_against_baseline(
        "serving", [_row("delta_query_overhead", 1.02)], []) == []


def test_gate_tables_are_wired():
    """Every gated/floored suite maps to a committed baseline artifact,
    and the ragged acceptance metrics are actually gated."""
    for suite in set(CHECK_GATES) | set(CHECK_FLOORS) | set(CHECK_CEILINGS):
        assert suite in BASELINE_FILES, suite
    assert CHECK_FLOORS["serving"]["ragged_speedup"] >= 2.0
    assert CHECK_FLOORS["serving"]["ragged_buckets"] >= 8.0
    # row-sharded ragged acceptance: >= 2x over the bucket-pair loop on
    # the SAME row-sharded placement, and the compressed arena's >= 1.8x
    # rows-per-byte claim — both hard floors, not baseline-relative
    assert CHECK_FLOORS["serving"]["rowsharded_ragged_speedup"] >= 2.0
    assert CHECK_FLOORS["serving"]["compressed_bytes_ratio"] >= 1.8
    assert {"ragged_speedup", "ragged_us_per_query",
            "bucket_pair_us_per_query",
            "ragged_buckets"} <= REQUIRED_ALGOS["serving"]
    assert {"rowsharded_ragged_speedup", "rowsharded_ragged_us_per_query",
            "rowsharded_bucket_pair_us_per_query",
            "compressed_bytes_ratio"} <= REQUIRED_ALGOS["serving"]
    # dynamic-index serving: the delta overhead ceiling is wired and the
    # update/compact cost rows are tracked in the artifact
    assert CHECK_CEILINGS["serving"]["delta_query_overhead"] <= 1.15
    assert {"update_apply_us", "compact_us",
            "delta_query_overhead"} <= REQUIRED_ALGOS["serving"]
    # continuous batching: the p99 SLO ceiling and the DMA-ring overlap
    # floor are wired, and the latency/overlap rows are tracked
    assert CHECK_CEILINGS["serving"]["serve_p99_us"] > 0
    assert 0 < CHECK_FLOORS["serving"]["dma_overlap_speedup"] <= 1.0
    assert {"serve_p50_us", "serve_p99_us",
            "dma_overlap_speedup"} <= REQUIRED_ALGOS["serving"]
    # resilience (docs/resilience.md): the degraded-rung overhead and
    # WAL-append ceilings are wired, and the rows are tracked
    assert CHECK_CEILINGS["serving"]["degraded_mode_overhead"] > 1.0
    assert CHECK_CEILINGS["serving"]["wal_append_us"] > 0
    assert {"degraded_mode_overhead",
            "wal_append_us"} <= REQUIRED_ALGOS["serving"]

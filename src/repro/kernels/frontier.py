"""Pallas TPU kernels for the constrained-BFS rounds of WC-INDEX
construction (Algorithm 3 lines 11-17).

Single-root kernel (`frontier_relax_gathered`) — one relaxation round over a
padded adjacency. Per destination vertex v:
    cand[v] = max_{u in N(v)} min(Fw[u], level(u, v))     (-1 == inactive)
    newF[v] = cand[v] if cand[v] > R[v] else -1
    newR[v] = max(R[v], cand[v])
ops.py pre-gathers Fw over the padded neighbor table ([V, D] = `Fw[nbr]`,
XLA row gather; on a real TPU deployment this becomes a scalar-prefetch DMA
— noted in DESIGN.md). The kernel fuses the min/max/compare chain so the
[V, D] intermediate never round-trips to HBM, and tiles V so the working set
(3 × [bV, D] int32) sits in VMEM.

Rank-batched kernels (`wc_prune_emit_batched`, `wc_relax_batched`) — the two
fused stages of one synchronized round for a batch of B roots (the
device-resident builder in `core/wc_index_batched.py`):

  prune+emit  per (root b, vertex v): query the partial index as of the
              batch start — q = min_i dist[v,i] + T[b, hub[v,i], F[b,v]]
              over quality-feasible label entries — and emit F[b,v] iff the
              frontier distance d improves on q. The [B, V, cap] gather /
              mask / add intermediates that the jnp formulation materializes
              in HBM stay per-tile in VMEM here; the per-root hub table
              T[b] rides along as one [V, W+1] block per grid row.
  relax       per (root b, vertex v): the batched form of the single-root
              kernel, with the root-rank mask (rank[v] > rank(b)) fused in.
              The emitted frontier row of root b is kept whole in VMEM and
              gathered by the neighbor table in-kernel (scalar-prefetch
              carries the per-root rank; the row gather is the same pattern
              as `wcsd_query_segmented`'s in-kernel label-row gather).

Both batched kernels take the current round / root ranks through
`PrefetchScalarGridSpec` so the grid's block index maps and the kernel body
share one scalar upload per call instead of a retrace per round.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEV_INF = 1 << 29  # python int: safe to close over in pallas kernels
INF_DIST = 1 << 30


def _frontier_kernel(fw_nbr_ref, lvl_ref, r_ref, newf_ref, newr_ref):
    fw = fw_nbr_ref[...]          # [bV, D] frontier level at each neighbor
    lvl = lvl_ref[...]            # [bV, D] edge level (-1 = padding)
    r = r_ref[...]                # [bV, 1]
    wprime = jnp.minimum(fw, lvl)             # -1 edges / inactive stay -1
    cand = wprime.max(axis=1, keepdims=True)  # [bV, 1]
    improved = cand > r
    newf_ref[...] = jnp.where(improved, cand, -1)
    newr_ref[...] = jnp.maximum(r, cand)


@functools.partial(jax.jit, static_argnames=("block_v", "interpret"))
def frontier_relax_gathered(fw_nbr, lvl_pad, R, *, block_v: int = 256,
                            interpret: bool = True):
    """fw_nbr/lvl_pad: [V, D] int32, R: [V] int32 -> (newF [V], newR [V])."""
    V, D = fw_nbr.shape
    grid = (V // block_v,)
    newf, newr = pl.pallas_call(
        _frontier_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_v, D), lambda i: (i, 0)),
            pl.BlockSpec((block_v, D), lambda i: (i, 0)),
            pl.BlockSpec((block_v, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_v, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_v, 1), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((V, 1), jnp.int32),
                   jax.ShapeDtypeStruct((V, 1), jnp.int32)],
        interpret=interpret,
    )(fw_nbr, lvl_pad, R[:, None])
    return newf[:, 0], newr[:, 0]


# ------------------------------------------------------------ rank-batched
def _prune_emit_kernel(d_ref, f_ref, t_ref, hub_ref, dist_ref, wlev_ref,
                       emit_ref):
    d = d_ref[0]
    f = f_ref[0, :]                     # [bV] frontier level (-1 inactive)
    tb = t_ref[0]                       # [V, W+1] hub table of root b
    hub = hub_ref[...]                  # [bV, cap] partial-index labels
    dist = dist_ref[...]
    wlev = wlev_ref[...]
    fw = jnp.clip(f, 0, tb.shape[1] - 1)
    # gather the root's table at (hub rank, query level); clamp before the
    # add so INF + INF cannot overflow int32
    tv = tb[jnp.clip(hub, 0, tb.shape[0] - 1), fw[:, None]]     # [bV, cap]
    feas = (hub >= 0) & (wlev >= fw[:, None])
    cand = jnp.where(feas, jnp.minimum(dist, DEV_INF)
                     + jnp.minimum(tv, DEV_INF), INF_DIST)
    q = cand.min(axis=1)                # partial-index answer per vertex
    survive = (f >= 0) & (q > d)
    emit_ref[0, :] = jnp.where(survive, f, -1)


@functools.partial(jax.jit, static_argnames=("block_v", "interpret"))
def wc_prune_emit_batched(F, T, hub, dist, wlev, d, *, block_v: int = 256,
                          interpret: bool = True):
    """Fused partial-index prune + label emission for a root batch.

    F: [B, V] frontier levels (-1 inactive); T: [B, V, W+1] per-root hub
    tables indexed by hub *rank*; hub/dist/wlev: [V, cap] padded partial
    index (pad: hub = -1, dist = INF_DIST, wlev = -1); d: [1] current round.
    Returns emit_w [B, V]: the quality level to emit per (root, vertex), -1
    where the frontier is pruned/inactive. V % block_v == 0 (ops.py pads).
    """
    B, V = F.shape
    W1 = T.shape[2]
    cap = hub.shape[1]
    grid = (B, V // block_v)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_v), lambda b, i, d: (b, i)),       # F
            pl.BlockSpec((1, T.shape[1], W1), lambda b, i, d: (b, 0, 0)),
            pl.BlockSpec((block_v, cap), lambda b, i, d: (i, 0)),     # hub
            pl.BlockSpec((block_v, cap), lambda b, i, d: (i, 0)),     # dist
            pl.BlockSpec((block_v, cap), lambda b, i, d: (i, 0)),     # wlev
        ],
        out_specs=pl.BlockSpec((1, block_v), lambda b, i, d: (b, i)),
    )
    return pl.pallas_call(
        _prune_emit_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, V), jnp.int32),
        interpret=interpret,
    )(d, F, T, hub, dist, wlev)


def _relax_batched_kernel(rr_ref, ew_ref, nbr_ref, lvl_ref, rank_ref, r_ref,
                          newf_ref, newr_ref):
    rr = rr_ref[pl.program_id(0)]       # rank of root b
    ew = ew_ref[0, :]                   # [V] emitted frontier row of root b
    nbr = nbr_ref[...]                  # [bV, D] padded adjacency (-1 pad)
    lvl = lvl_ref[...]                  # [bV, D] edge level (-1 pad)
    rank = rank_ref[0, :]               # [bV]
    r = r_ref[0, :]                     # [bV] best bottleneck level so far
    fwn = ew[jnp.clip(nbr, 0, ew.shape[0] - 1)]                 # [bV, D]
    wp = jnp.minimum(jnp.where(nbr >= 0, fwn, -1), lvl)
    cand = wp.max(axis=1)
    cand = jnp.where(rank > rr, cand, -1)   # only label higher-ranked nodes
    improved = cand > r
    newf_ref[0, :] = jnp.where(improved, cand, -1)
    newr_ref[0, :] = jnp.maximum(r, cand)


@functools.partial(jax.jit, static_argnames=("block_v", "interpret"))
def wc_relax_batched(emit_w, nbr_pad, lvl_pad, rank, root_ranks, R, *,
                     block_v: int = 256, interpret: bool = True):
    """One batched relaxation: emit_w [B, V] surviving frontier, nbr_pad/
    lvl_pad [V, D] padded adjacency, rank [1, V], root_ranks [B] (scalar
    prefetch), R [B, V]. Returns (newF [B, V], newR [B, V])."""
    B, V = emit_w.shape
    D = nbr_pad.shape[1]
    grid = (B, V // block_v)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, V), lambda b, i, rr: (b, 0)),            # ew
            pl.BlockSpec((block_v, D), lambda b, i, rr: (i, 0)),      # nbr
            pl.BlockSpec((block_v, D), lambda b, i, rr: (i, 0)),      # lvl
            pl.BlockSpec((1, block_v), lambda b, i, rr: (0, i)),      # rank
            pl.BlockSpec((1, block_v), lambda b, i, rr: (b, i)),      # R
        ],
        out_specs=[
            pl.BlockSpec((1, block_v), lambda b, i, rr: (b, i)),
            pl.BlockSpec((1, block_v), lambda b, i, rr: (b, i)),
        ],
    )
    newf, newr = pl.pallas_call(
        _relax_batched_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, V), jnp.int32),
                   jax.ShapeDtypeStruct((B, V), jnp.int32)],
        interpret=interpret,
    )(root_ranks, emit_w, nbr_pad, lvl_pad, rank, R)
    return newf, newr

"""Pallas TPU kernel for one constrained-BFS relaxation round over a padded
adjacency (the inner loop of WC-INDEX construction, Algorithm 3 lines 13-17).

Per destination vertex v:
    cand[v] = max_{u in N(v)} min(Fw[u], level(u, v))     (-1 == inactive)
    newF[v] = cand[v] if cand[v] > R[v] else -1
    newR[v] = max(R[v], cand[v])

ops.py pre-gathers Fw over the padded neighbor table ([V, D] = `Fw[nbr]`,
XLA row gather; on a real TPU deployment this becomes a scalar-prefetch DMA
— noted in DESIGN.md). The kernel fuses the min/max/compare chain so the
[V, D] intermediate never round-trips to HBM, and tiles V so the working set
(3 × [bV, D] int32) sits in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _frontier_kernel(fw_nbr_ref, lvl_ref, r_ref, newf_ref, newr_ref):
    fw = fw_nbr_ref[...]          # [bV, D] frontier level at each neighbor
    lvl = lvl_ref[...]            # [bV, D] edge level (-1 = padding)
    r = r_ref[...]                # [bV, 1]
    wprime = jnp.minimum(fw, lvl)             # -1 edges / inactive stay -1
    cand = wprime.max(axis=1, keepdims=True)  # [bV, 1]
    improved = cand > r
    newf_ref[...] = jnp.where(improved, cand, -1)
    newr_ref[...] = jnp.maximum(r, cand)


@functools.partial(jax.jit, static_argnames=("block_v", "interpret"))
def frontier_relax_gathered(fw_nbr, lvl_pad, R, *, block_v: int = 256,
                            interpret: bool = True):
    """fw_nbr/lvl_pad: [V, D] int32, R: [V] int32 -> (newF [V], newR [V])."""
    V, D = fw_nbr.shape
    grid = (V // block_v,)
    newf, newr = pl.pallas_call(
        _frontier_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_v, D), lambda i: (i, 0)),
            pl.BlockSpec((block_v, D), lambda i: (i, 0)),
            pl.BlockSpec((block_v, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_v, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_v, 1), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((V, 1), jnp.int32),
                   jax.ShapeDtypeStruct((V, 1), jnp.int32)],
        interpret=interpret,
    )(fw_nbr, lvl_pad, R[:, None])
    return newf[:, 0], newr[:, 0]

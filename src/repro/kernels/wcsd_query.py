"""Pallas TPU kernel for batched WCSD 2-hop label intersection (the paper's
Algorithm 5 hot path, restructured for the MXU/VPU).

CPU Alg. 5 is a pointer sort-merge — hostile to SIMD. On TPU we compute, per
query, a masked outer join over the two padded label rows:

    best = min_{i,j} [ hub_s[i] == hub_t[j] ] * (d_s[i] + d_t[j])
           subject to w_s[i] >= w, w_t[j] >= w

The [B, L, L] compare volume never touches HBM: the kernel tiles the t-side
label axis, keeps the s-side row resident in VMEM, and accumulates the
min-plus reduction in a [bB, 1] output block. XLA on the same computation
materializes the [B, L, L] intermediate (see benchmarks/bench_kernels.py).

Feasibility masking (w >= threshold, entry in-bounds) is pre-applied by
ops.py by overwriting infeasible distances with DEV_INF, so the kernel body
is a pure equality-gated min-plus — one VPU compare + add + min per cell.

Layout contract (from core.query.DeviceQueryEngine / WCIndex):
  label rows are hub-sorted, L padded to a multiple of 128 with hub = -1,
  dist = DEV_INF; pad cells can never win the min because DEV_INF + DEV_INF
  < int32 max yet > any real distance sum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEV_INF = 1 << 29  # python int: safe to close over in pallas kernels


def _query_kernel(hs_ref, ds_ref, ht_ref, dt_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, DEV_INF)

    hs = hs_ref[...]            # [bB, L]   (s-side: full label row)
    ds = ds_ref[...]
    ht = ht_ref[...]            # [bB, bLt] (t-side tile)
    dt = dt_ref[...]
    eq = hs[:, :, None] == ht[:, None, :]            # [bB, L, bLt]
    dsum = ds[:, :, None] + dt[:, None, :]
    best = jnp.where(eq, dsum, DEV_INF).min(axis=(1, 2))
    out_ref[...] = jnp.minimum(out_ref[...], best[:, None])


@functools.partial(jax.jit,
                   static_argnames=("block_b", "block_lt", "interpret"))
def wcsd_query_gathered(hs, ds, ht, dt, *, block_b: int = 8,
                        block_lt: int = 128, interpret: bool = True):
    """Masked-distance form: [B, L] gathered label rows -> [B] best sum.

    ds/dt must already hold DEV_INF at infeasible entries.
    B % block_b == 0, L % block_lt == 0 (ops.py pads).
    """
    B, L = hs.shape
    grid = (B // block_b, L // block_lt)
    out = pl.pallas_call(
        _query_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, L), lambda i, j: (i, 0)),    # hs
            pl.BlockSpec((block_b, L), lambda i, j: (i, 0)),    # ds
            pl.BlockSpec((block_b, block_lt), lambda i, j: (i, j)),  # ht
            pl.BlockSpec((block_b, block_lt), lambda i, j: (i, j)),  # dt
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.int32),
        interpret=interpret,
    )(hs, ds, ht, dt)
    return out[:, 0]


def _fit_block(block_lt: int, Wt: int) -> int:
    """Largest t-tile block width <= ``block_lt`` that DIVIDES ``Wt`` —
    the grid is ``Wt // block_lt`` steps, so a non-divisor block would
    silently drop Wt's tail columns (non-128-multiple widths are reachable
    through the engines' ``lane`` knob: lane=48 gives Wt = 48, 96, 192...).
    Halving always terminates at a divisor (worst case 1)."""
    if Wt <= block_lt:
        return Wt
    while Wt % block_lt:
        block_lt //= 2
    return block_lt


# --------------------------------------------------------------- segmented
def _segmented_kernel(srow_ref, trow_ref, wq_ref,
                      hs_ref, ds_ref, ws_ref, ht_ref, dt_ref, wt_ref,
                      out_ref):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, DEV_INF)

    wq = wq_ref[i]
    # feasibility mask applied in-kernel: store pads carry wlev = -1 and
    # real entries wlev >= 0, so one compare covers both in-bounds and
    # quality-threshold masking (no count array on device).
    hs = hs_ref[...]                                        # [1, Ws]
    ds = jnp.where(ws_ref[...] >= wq,
                   jnp.minimum(ds_ref[...], DEV_INF), DEV_INF)
    ht = ht_ref[...]                                        # [1, bLt]
    dt = jnp.where(wt_ref[...] >= wq,
                   jnp.minimum(dt_ref[...], DEV_INF), DEV_INF)
    eq = hs[0, :, None] == ht[0, None, :]                   # [Ws, bLt]
    best = jnp.where(eq, ds[0, :, None] + dt[0, None, :], DEV_INF).min()
    out_ref[0, 0] = jnp.minimum(out_ref[0, 0], best)


@functools.partial(jax.jit, static_argnames=("block_lt", "interpret"))
def wcsd_query_segmented(hub_s, dist_s, wlev_s, hub_t, dist_t, wlev_t,
                         srow, trow, w_level, *, block_lt: int = 128,
                         interpret: bool = True):
    """Bucket-pair query path: gathers CSR label rows in-kernel.

    Unlike `wcsd_query_gathered`, whose caller materializes [B, L] gathered
    + masked copies in HBM, this kernel reads label rows straight out of the
    bucket-tiled store: the query's row ids arrive as scalar-prefetch
    arguments (`PrefetchScalarGridSpec`) and each BlockSpec index_map picks
    block ``(srow[i], 0)`` / ``(trow[i], j)`` of the store, so the gather is
    the DMA itself. Feasibility masking (wlev >= w) moves in-kernel, which
    lets both query sides share one store — per query the HBM traffic is
    3·(Ws + Wt) int32 instead of 4·2·L after host-side gather/mask.

    hub_s/dist_s/wlev_s: [Ns, Ws] s-side bucket tiles (pad: hub -1,
    wlev -1); hub_t/...: [Nt, Wt] t-side tiles. srow/trow/w_level: [B]
    int32. Ws and Wt may differ (that is the point: a (128, 128) bucket
    pair does 1/64th the compares of a 1024-padded dense row pair).
    Returns [B] int32 best sums (>= DEV_INF means infeasible).
    """
    B = srow.shape[0]
    Ws, Wt = hub_s.shape[1], hub_t.shape[1]
    block_lt = _fit_block(block_lt, Wt)
    grid = (B, Wt // block_lt)

    def s_spec():
        return pl.BlockSpec((1, Ws), lambda i, j, srow, trow, wq: (srow[i], 0))

    def t_spec():
        return pl.BlockSpec((1, block_lt),
                            lambda i, j, srow, trow, wq: (trow[i], j))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[s_spec(), s_spec(), s_spec(),
                  t_spec(), t_spec(), t_spec()],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, srow, trow, wq: (i, 0)),
    )
    out = pl.pallas_call(
        _segmented_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.int32),
        interpret=interpret,
    )(srow, trow, w_level, hub_s, dist_s, wlev_s, hub_t, dist_t, wlev_t)
    return out[:, 0]


# ------------------------------------------------------------------ ragged
#
# The ragged kernels fetch their arena tiles MANUALLY: the arena stays in
# HBM (`memory_space=ANY`) and each work item's six (1, lane) tiles are
# DMA'd into a quad-buffered VMEM scratch ring (`_RAGGED_NBUF` slots x six
# buffers, one DMA semaphore per copy). The automatic BlockSpec pipeline
# only double-buffers and serializes its prefetch one grid step ahead;
# with the explicit ring the copy for worklist entry k + 4 is issued the
# moment slot k % 4 frees, so on skewed stores the O(lane^2) join of entry
# k overlaps the HBM latency of the next THREE entries — deep enough to
# hide a full tile fetch behind one join (the ROADMAP quad-buffering
# item). Worklist scalars and tile spans still ride scalar prefetch; the
# output side keeps its (qidx[k], 0) BlockSpec, so revisit-pipelining of
# consecutive work items of one query is unchanged — and the whole flush
# is still exactly ONE `pallas_call`.
_RAGGED_NBUF = 4


def _fetch_ring(stile_ref, ttile_ref, srcs, bufs, sems):
    """DMA-descriptor factory for one worklist entry: six async copies
    (s-side and t-side hub/dist/wlev tiles) into ring slot ``slot``.

    Start/wait calls must balance per (slot, copy) semaphore: every entry
    k is started exactly once (warmup for k < NBUF, else the prefetch at
    step k - NBUF) and waited exactly once (step k)."""
    def copies(slot, entry):
        s = stile_ref[entry]
        t = ttile_ref[entry]
        idxs = (s, s, s, t, t, t)
        return [pltpu.make_async_copy(src.at[pl.ds(ix, 1)],
                                      buf.at[slot], sems.at[slot, j])
                for j, (src, ix, buf) in enumerate(zip(srcs, idxs, bufs))]
    return copies


def _fetch_wait(k, WL, copies, nbuf=_RAGGED_NBUF):
    """Warmup (step 0 issues the first ``nbuf`` entries), then block on
    this entry's slot. Returns the slot index owning entry ``k``'s
    tiles."""
    @pl.when(k == 0)
    def _warmup():
        for i in range(min(nbuf, WL)):
            for c in copies(i, i):
                c.start()

    slot = jax.lax.rem(k, nbuf)
    for c in copies(slot, k):
        c.wait()
    return slot


def _fetch_next(k, WL, slot, copies, nbuf=_RAGGED_NBUF):
    """Reuse the slot just consumed for entry ``k + nbuf`` (clamped read:
    the guard keeps the copy from running, the clamp keeps the scalar
    load in bounds)."""
    if WL > nbuf:
        @pl.when(k + nbuf < WL)
        def _prefetch():
            nxt = jnp.minimum(k + nbuf, WL - 1)
            for c in copies(slot, nxt):
                c.start()


def _ragged_scratch(lane, dtypes, nbuf=_RAGGED_NBUF):
    """Six (nbuf, 1, lane) VMEM ring buffers + the (nbuf, 6) DMA
    semaphore array; ``dtypes`` is the (hub, dist, wlev) dtype triple
    (int32 x3 uncompressed, int16/float/int8 compressed)."""
    return ([pltpu.VMEM((nbuf, 1, lane), dt)
             for dt in (*dtypes, *dtypes)]
            + [pltpu.SemaphoreType.DMA((nbuf, 6))])


def _ragged_kernel(WL, nbuf=_RAGGED_NBUF):
    def kernel(qidx_ref, stile_ref, ttile_ref, first_ref, wq_ref,
               lo_ref, hi_ref, hub_ref, dist_ref, wlev_ref, out_ref,
               hs_buf, ds_buf, ws_buf, ht_buf, dt_buf, wt_buf, sems):
        k = pl.program_id(0)
        copies = _fetch_ring(stile_ref, ttile_ref,
                             (hub_ref, dist_ref, wlev_ref) * 2,
                             (hs_buf, ds_buf, ws_buf, ht_buf, dt_buf,
                              wt_buf), sems)
        slot = _fetch_wait(k, WL, copies, nbuf)

        @pl.when(first_ref[k] == 1)
        def _init():
            out_ref[...] = jnp.full_like(out_ref, DEV_INF)

        s_tile = stile_ref[k]
        t_tile = ttile_ref[k]
        # Thm.-3 rows are hub-sorted, so each arena tile covers one
        # hub-rank interval [lo, hi]; disjoint intervals cannot meet ->
        # skip the O(lane^2) join for this work item (the DMA already
        # happened, the saving is compute — and on skewed stores most
        # cross-tile pairs of a long x long query are disjoint).
        meet = (lo_ref[s_tile] <= hi_ref[t_tile]) & \
            (lo_ref[t_tile] <= hi_ref[s_tile])

        @pl.when(meet)
        def _join():
            wq = wq_ref[qidx_ref[k]]
            hs = hs_buf[slot]                               # [1, lane]
            ds = jnp.where(ws_buf[slot] >= wq,
                           jnp.minimum(ds_buf[slot], DEV_INF), DEV_INF)
            ht = ht_buf[slot]                               # [1, lane]
            dt = jnp.where(wt_buf[slot] >= wq,
                           jnp.minimum(dt_buf[slot], DEV_INF), DEV_INF)
            eq = hs[0, :, None] == ht[0, None, :]           # [lane, lane]
            best = jnp.where(eq, ds[0, :, None] + dt[0, None, :],
                             DEV_INF).min()
            out_ref[0, 0] = jnp.minimum(out_ref[0, 0], best)

        _fetch_next(k, WL, slot, copies, nbuf)
    return kernel


@functools.partial(jax.jit, static_argnames=("interpret", "nbuf"))
def wcsd_query_ragged(hub, dist, wlev, tile_lo, tile_hi,
                      qidx, stile, ttile, first, wq, *,
                      interpret: bool = True, nbuf: int = _RAGGED_NBUF):
    """Single-launch ragged query path over the lane-tiled label arena.

    Collapses the whole bucket-pair dispatch loop into ONE `pallas_call`:
    the grid is a flat worklist of ``(query, s_tile, t_tile)`` work items
    (one per tile pair of a query's two label rows, query-major — see
    `core.query.emit_ragged_worklist`). The arena stays HBM-resident and
    each entry's tiles are fetched through the quad-buffered DMA ring
    (see the section comment), so a batch mixing every bucket length runs
    in a single launch with zero wasted lanes and the tile DMA of entry
    k + 4 overlapping the join of entry k.

    hub/dist/wlev: [T, lane] arena tiles (pad contract hub -1, wlev -1);
    tile_lo/tile_hi: [T] per-tile hub-rank spans (Thm.-3 early-out);
    qidx/stile/ttile/first: [WL] int32 worklist — ``qidx`` is
    non-decreasing (output rows are revisited only consecutively) and
    ``first`` marks each query's first work item (DEV_INF init);
    wq: [Q] per-output-row query levels (worklist pads must point at a
    trash row whose level is infeasible). Returns [Q] int32 best sums
    (>= DEV_INF means infeasible).

    ``nbuf`` sizes the DMA ring (default quad-buffered); ``nbuf=1`` is
    the no-overlap baseline the serving bench's ``dma_overlap_speedup``
    row compares against.
    """
    WL = qidx.shape[0]
    Q = wq.shape[0]
    lane = hub.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=(WL,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] * 3,
        out_specs=pl.BlockSpec(
            (1, 1), lambda k, qidx, stile, ttile, first, wq, lo, hi:
            (qidx[k], 0)),
        scratch_shapes=_ragged_scratch(lane, (jnp.int32,) * 3, nbuf),
    )
    out = pl.pallas_call(
        _ragged_kernel(WL, nbuf),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Q, 1), jnp.int32),
        interpret=interpret,
    )(qidx, stile, ttile, first, wq, tile_lo, tile_hi, hub, dist, wlev)
    return out[:, 0]


def _profile_ragged_kernel(WL, nbuf=_RAGGED_NBUF):
    def kernel(qidx_ref, stile_ref, ttile_ref, first_ref, lo_ref, hi_ref,
               hub_ref, dist_ref, wlev_ref, out_ref,
               hs_buf, ds_buf, ws_buf, ht_buf, dt_buf, wt_buf, sems):
        k = pl.program_id(0)
        copies = _fetch_ring(stile_ref, ttile_ref,
                             (hub_ref, dist_ref, wlev_ref) * 2,
                             (hs_buf, ds_buf, ws_buf, ht_buf, dt_buf,
                              wt_buf), sems)
        slot = _fetch_wait(k, WL, copies, nbuf)

        @pl.when(first_ref[k] == 1)
        def _init():
            out_ref[...] = jnp.full_like(out_ref, DEV_INF)

        s_tile = stile_ref[k]
        t_tile = ttile_ref[k]
        meet = (lo_ref[s_tile] <= hi_ref[t_tile]) & \
            (lo_ref[t_tile] <= hi_ref[s_tile])

        @pl.when(meet)
        def _join():
            hs = hs_buf[slot]                               # [1, lane]
            ds = jnp.minimum(ds_buf[slot], DEV_INF)
            ht = ht_buf[slot]
            dt = jnp.minimum(dt_buf[slot], DEV_INF)
            eq = hs[0, :, None] == ht[0, None, :]           # [lane, lane]
            dsum = jnp.where(eq, ds[0, :, None] + dt[0, None, :], DEV_INF)
            mw = jnp.minimum(ws_buf[slot][0, :, None],
                             wt_buf[slot][0, None, :])
            for lev in range(out_ref.shape[1]):  # static: W + 1 is tiny
                best = jnp.where(mw == lev, dsum, DEV_INF).min()
                out_ref[0, lev] = jnp.minimum(out_ref[0, lev], best)

        _fetch_next(k, WL, slot, copies, nbuf)
    return kernel


@functools.partial(jax.jit, static_argnames=("num_rows", "num_levels",
                                             "interpret", "nbuf"))
def wcsd_profile_ragged(hub, dist, wlev, tile_lo, tile_hi,
                        qidx, stile, ttile, first, *, num_rows: int,
                        num_levels: int, interpret: bool = True,
                        nbuf: int = _RAGGED_NBUF):
    """Single-launch ragged PROFILE path: same arena/worklist contract
    (and quad-buffered tile fetch) as `wcsd_query_ragged`, no per-query
    level — each work item bins its hub meets' distance sums by pair
    level ``min(wlev_s, wlev_t)`` into the query's [num_levels + 1]
    bucket row (the staircase is the suffix min-scan, applied in ops).
    Returns [num_rows, num_levels + 1] int32 bucket minima; worklist pads
    must point at trash row num_rows - 1."""
    WL = qidx.shape[0]
    lane = hub.shape[1]
    Lp = int(num_levels) + 1
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(WL,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] * 3,
        out_specs=pl.BlockSpec(
            (1, Lp), lambda k, qidx, stile, ttile, first, lo, hi:
            (qidx[k], 0)),
        scratch_shapes=_ragged_scratch(lane, (jnp.int32,) * 3, nbuf),
    )
    return pl.pallas_call(
        _profile_ragged_kernel(WL, nbuf),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_rows, Lp), jnp.int32),
        interpret=interpret,
    )(qidx, stile, ttile, first, tile_lo, tile_hi, hub, dist, wlev)


# ------------------------------------------------- ragged, compressed arena
def _decode_cells(hd, d, w, lo):
    """In-register decode of one compressed arena tile (CompressedArena,
    docs/index-format.md §6): int16 hub deltas rebuilt against the tile's
    lo rank (the sign is the pad flag, so -1 sentinels survive), float
    distances clamped at DEV_INF — the +inf pad encoding saturates there,
    so no isfinite test is needed — and rounded back to int32 (+0.5 then
    truncate; exact for every in-range integer the float format holds),
    int8 quality levels widened."""
    hub = jnp.where(hd >= 0, lo + hd.astype(jnp.int32), -1)
    dist = (jnp.minimum(d.astype(jnp.float32), float(DEV_INF))
            + 0.5).astype(jnp.int32)
    return hub, dist, w.astype(jnp.int32)


def _ragged_kernel_c(WL, nbuf=_RAGGED_NBUF):
    def kernel(qidx_ref, stile_ref, ttile_ref, first_ref, wq_ref,
               lo_ref, hi_ref, hub_ref, dist_ref, wlev_ref, out_ref,
               hs_buf, ds_buf, ws_buf, ht_buf, dt_buf, wt_buf, sems):
        k = pl.program_id(0)
        copies = _fetch_ring(stile_ref, ttile_ref,
                             (hub_ref, dist_ref, wlev_ref) * 2,
                             (hs_buf, ds_buf, ws_buf, ht_buf, dt_buf,
                              wt_buf), sems)
        slot = _fetch_wait(k, WL, copies, nbuf)

        @pl.when(first_ref[k] == 1)
        def _init():
            out_ref[...] = jnp.full_like(out_ref, DEV_INF)

        s_tile = stile_ref[k]
        t_tile = ttile_ref[k]
        meet = (lo_ref[s_tile] <= hi_ref[t_tile]) & \
            (lo_ref[t_tile] <= hi_ref[s_tile])

        @pl.when(meet)
        def _join():
            wq = wq_ref[qidx_ref[k]]
            hs, ds0, ws = _decode_cells(hs_buf[slot], ds_buf[slot],
                                        ws_buf[slot], lo_ref[s_tile])
            ht, dt0, wt = _decode_cells(ht_buf[slot], dt_buf[slot],
                                        wt_buf[slot], lo_ref[t_tile])
            ds = jnp.where(ws >= wq, ds0, DEV_INF)
            dt = jnp.where(wt >= wq, dt0, DEV_INF)
            eq = hs[0, :, None] == ht[0, None, :]
            best = jnp.where(eq, ds[0, :, None] + dt[0, None, :],
                             DEV_INF).min()
            out_ref[0, 0] = jnp.minimum(out_ref[0, 0], best)

        _fetch_next(k, WL, slot, copies, nbuf)
    return kernel


@functools.partial(jax.jit, static_argnames=("interpret", "nbuf"))
def wcsd_query_ragged_compressed(hub_delta, dist, wlev, tile_lo, tile_hi,
                                 qidx, stile, ttile, first, wq, *,
                                 interpret: bool = True,
                                 nbuf: int = _RAGGED_NBUF):
    """`wcsd_query_ragged` over the COMPRESSED arena: identical worklist
    and output contract, but the tiles arrive as int16 hub deltas /
    bf16-or-fp16 distances / int8 levels — the quad-buffered ring scratch
    holds the narrow dtypes, so the DMA per work item shrinks with the
    store — and are decoded in-register (`_decode_cells`). Callers must
    not pass overflowed stores (CompressedArena.overflow) — the engines
    fall back to the uncompressed arena for those."""
    WL = qidx.shape[0]
    Q = wq.shape[0]
    lane = hub_delta.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=(WL,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] * 3,
        out_specs=pl.BlockSpec(
            (1, 1), lambda k, qidx, stile, ttile, first, wq, lo, hi:
            (qidx[k], 0)),
        scratch_shapes=_ragged_scratch(
            lane, (hub_delta.dtype, dist.dtype, wlev.dtype), nbuf),
    )
    out = pl.pallas_call(
        _ragged_kernel_c(WL, nbuf),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Q, 1), jnp.int32),
        interpret=interpret,
    )(qidx, stile, ttile, first, wq, tile_lo, tile_hi,
      hub_delta, dist, wlev)
    return out[:, 0]


def _profile_ragged_kernel_c(WL, nbuf=_RAGGED_NBUF):
    def kernel(qidx_ref, stile_ref, ttile_ref, first_ref, lo_ref, hi_ref,
               hub_ref, dist_ref, wlev_ref, out_ref,
               hs_buf, ds_buf, ws_buf, ht_buf, dt_buf, wt_buf, sems):
        k = pl.program_id(0)
        copies = _fetch_ring(stile_ref, ttile_ref,
                             (hub_ref, dist_ref, wlev_ref) * 2,
                             (hs_buf, ds_buf, ws_buf, ht_buf, dt_buf,
                              wt_buf), sems)
        slot = _fetch_wait(k, WL, copies, nbuf)

        @pl.when(first_ref[k] == 1)
        def _init():
            out_ref[...] = jnp.full_like(out_ref, DEV_INF)

        s_tile = stile_ref[k]
        t_tile = ttile_ref[k]
        meet = (lo_ref[s_tile] <= hi_ref[t_tile]) & \
            (lo_ref[t_tile] <= hi_ref[s_tile])

        @pl.when(meet)
        def _join():
            hs, ds, ws = _decode_cells(hs_buf[slot], ds_buf[slot],
                                       ws_buf[slot], lo_ref[s_tile])
            ht, dt, wt = _decode_cells(ht_buf[slot], dt_buf[slot],
                                       wt_buf[slot], lo_ref[t_tile])
            eq = hs[0, :, None] == ht[0, None, :]
            dsum = jnp.where(eq, ds[0, :, None] + dt[0, None, :], DEV_INF)
            mw = jnp.minimum(ws[0, :, None], wt[0, None, :])
            for lev in range(out_ref.shape[1]):  # static: W + 1 is tiny
                best = jnp.where(mw == lev, dsum, DEV_INF).min()
                out_ref[0, lev] = jnp.minimum(out_ref[0, lev], best)

        _fetch_next(k, WL, slot, copies, nbuf)
    return kernel


@functools.partial(jax.jit, static_argnames=("num_rows", "num_levels",
                                             "interpret", "nbuf"))
def wcsd_profile_ragged_compressed(hub_delta, dist, wlev, tile_lo, tile_hi,
                                   qidx, stile, ttile, first, *,
                                   num_rows: int, num_levels: int,
                                   interpret: bool = True,
                                   nbuf: int = _RAGGED_NBUF):
    """`wcsd_profile_ragged` over the COMPRESSED arena (see
    `wcsd_query_ragged_compressed` for the decode contract)."""
    WL = qidx.shape[0]
    lane = hub_delta.shape[1]
    Lp = int(num_levels) + 1
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(WL,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] * 3,
        out_specs=pl.BlockSpec(
            (1, Lp), lambda k, qidx, stile, ttile, first, lo, hi:
            (qidx[k], 0)),
        scratch_shapes=_ragged_scratch(
            lane, (hub_delta.dtype, dist.dtype, wlev.dtype), nbuf),
    )
    return pl.pallas_call(
        _profile_ragged_kernel_c(WL, nbuf),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_rows, Lp), jnp.int32),
        interpret=interpret,
    )(qidx, stile, ttile, first, tile_lo, tile_hi,
      hub_delta, dist, wlev)


# ----------------------------------------------------------------- profile
def _profile_kernel(srow_ref, trow_ref,
                    hs_ref, ds_ref, ws_ref, ht_ref, dt_ref, wt_ref,
                    out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, DEV_INF)

    # one gather of each side per query, every level answered from it: a
    # meeting pair (i, j) is feasible at every level <= min(ws[i], wt[j]),
    # so the pair contributes its distance sum to exactly one wlev BUCKET
    # (its pair level); the suffix min-scan over buckets -> staircase runs
    # in the wrapper, after all t-tiles have accumulated. Store pads carry
    # wlev = -1, below every bucket, so they never contribute.
    hs = hs_ref[...]                                        # [1, Ws]
    ds = jnp.minimum(ds_ref[...], DEV_INF)
    ht = ht_ref[...]                                        # [1, bLt]
    dt = jnp.minimum(dt_ref[...], DEV_INF)
    eq = hs[0, :, None] == ht[0, None, :]                   # [Ws, bLt]
    dsum = jnp.where(eq, ds[0, :, None] + dt[0, None, :], DEV_INF)
    mw = jnp.minimum(ws_ref[...][0, :, None], wt_ref[...][0, None, :])
    for lev in range(out_ref.shape[1]):   # static unroll: W + 1 is tiny
        best = jnp.where(mw == lev, dsum, DEV_INF).min()
        out_ref[0, lev] = jnp.minimum(out_ref[0, lev], best)


@functools.partial(jax.jit, static_argnames=("num_levels", "block_lt",
                                             "interpret"))
def wcsd_profile_segmented(hub_s, dist_s, wlev_s, hub_t, dist_t, wlev_t,
                           srow, trow, *, num_levels: int,
                           block_lt: int = 128, interpret: bool = True):
    """One-pass profile queries: per-(vertex-pair) wlev-bucket minima.

    Same store layout and scalar-prefetch gather as `wcsd_query_segmented`,
    but no per-query level: each query reads its two label rows ONCE and
    bins every hub meet's distance sum by its pair level
    ``min(wlev_s, wlev_t)``. Returns [B, num_levels + 1] int32 bucket
    minima — ``out[b, l]`` is the best sum among pairs whose pair level
    (the tightest constraint they satisfy) is exactly ``l``
    (>= DEV_INF: none). The full
    staircase ``dist(s, t, w)`` for every ``w`` is the suffix min-scan over
    the level axis (`ops.wcsd_profile_segmented` applies it), making the
    L-level workload one label sweep instead of L.

    The [B, num_levels + 1] output block is narrow (not lane-aligned);
    that is fine — it is DEV_INF-initialized per query and scalar-
    accumulated, exactly like the [B, 1] block of the single-level kernel.
    """
    B = srow.shape[0]
    Ws, Wt = hub_s.shape[1], hub_t.shape[1]
    Lp = int(num_levels) + 1
    block_lt = _fit_block(block_lt, Wt)
    grid = (B, Wt // block_lt)

    def s_spec():
        return pl.BlockSpec((1, Ws), lambda i, j, srow, trow: (srow[i], 0))

    def t_spec():
        return pl.BlockSpec((1, block_lt),
                            lambda i, j, srow, trow: (trow[i], j))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[s_spec(), s_spec(), s_spec(),
                  t_spec(), t_spec(), t_spec()],
        out_specs=pl.BlockSpec((1, Lp), lambda i, j, srow, trow: (i, 0)),
    )
    return pl.pallas_call(
        _profile_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Lp), jnp.int32),
        interpret=interpret,
    )(srow, trow, hub_s, dist_s, wlev_s, hub_t, dist_t, wlev_t)

"""Pure-jnp oracles for every Pallas kernel in this package. Tests sweep
shapes/dtypes and assert_allclose kernel-vs-oracle (exact for the int32
kernels)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

DEV_INF = 1 << 29  # python int: safe to close over in pallas kernels


def wcsd_query_gathered_ref(hs, ds, ht, dt):
    """[B, L] masked label rows -> [B] min-plus over equal hubs."""
    eq = hs[:, :, None] == ht[:, None, :]
    dsum = ds[:, :, None] + dt[:, None, :]
    return jnp.where(eq, dsum, DEV_INF).min(axis=(1, 2))


def frontier_relax_gathered_ref(fw_nbr, lvl_pad, R):
    wprime = jnp.minimum(fw_nbr, lvl_pad)
    cand = wprime.max(axis=1)
    newf = jnp.where(cand > R, cand, -1)
    newr = jnp.maximum(R, cand)
    return newf, newr


def cin_layer_ref(x1, x0, w):
    """out[b,k,d] = sum_{h,m} w[k,h,m] x1[b,h,d] x0[b,m,d] (fp32 accum)."""
    return jnp.einsum("bhd,bmd,khm->bkd", x1.astype(jnp.float32),
                      x0.astype(jnp.float32), w.astype(jnp.float32))


def flash_attention_ref(q, k, v, *, causal: bool = True, scale=None):
    """Plain softmax attention oracle, GQA-aware.

    q: [B, Hq, Tq, Dh], k/v: [B, Hkv, Tk, Dh]; Hq % Hkv == 0."""
    B, Hq, Tq, Dh = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = (Dh ** -0.5) if scale is None else scale
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Tq, Dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * scale
    if causal:
        Tk = k.shape[2]
        mask = jnp.arange(Tq)[:, None] + (Tk - Tq) >= jnp.arange(Tk)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return out.reshape(B, Hq, Tq, Dh).astype(q.dtype)

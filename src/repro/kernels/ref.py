"""Pure-jnp oracles for every Pallas kernel in this package. Tests sweep
shapes/dtypes and assert_allclose kernel-vs-oracle (exact for the int32
kernels).

The oracles model only the kernels' input/output contract. Execution
strategy knobs that cannot change results — in particular the ragged
kernels' multi-buffered DMA ring depth (``nbuf``), which only reorders
when arena tiles are fetched — have no counterpart here: every ``nbuf``
must match the same oracle bit-for-bit."""
from __future__ import annotations

import jax
import jax.numpy as jnp

DEV_INF = 1 << 29  # python int: safe to close over in pallas kernels


def wcsd_query_gathered_ref(hs, ds, ht, dt):
    """[B, L] masked label rows -> [B] min-plus over equal hubs."""
    eq = hs[:, :, None] == ht[:, None, :]
    dsum = ds[:, :, None] + dt[:, None, :]
    return jnp.where(eq, dsum, DEV_INF).min(axis=(1, 2))


def wcsd_query_segmented_ref(hub_s, dist_s, wlev_s, hub_t, dist_t, wlev_t,
                             srow, trow, w_level):
    """Gather + mask + min-plus in plain jnp (segmented-path oracle).

    Store tiles [Ns, Ws] / [Nt, Wt] (widths may differ), query rows and
    levels [B]. Pad cells carry wlev = -1 so the feasibility mask covers
    in-bounds masking too."""
    def side(store_h, store_d, store_w, rows):
        h = store_h[rows]
        m = store_w[rows] >= w_level[:, None]
        d = jnp.where(m, jnp.minimum(store_d[rows], DEV_INF), DEV_INF)
        return h, d

    hs, ds = side(hub_s, dist_s, wlev_s, srow)
    ht, dt = side(hub_t, dist_t, wlev_t, trow)
    eq = hs[:, :, None] == ht[:, None, :]
    return jnp.where(eq, ds[:, :, None] + dt[:, None, :], DEV_INF).min(
        axis=(1, 2))


def wcsd_query_ragged_ref(hub, dist, wlev, qidx, stile, ttile, wq):
    """Ragged-megakernel oracle: gather each work item's two arena tiles,
    join, scatter-min into the output row.

    hub/dist/wlev: [T, lane] arena tiles; qidx/stile/ttile: [WL] worklist;
    wq: [Q] per-output-row levels. Pads (arena cells with wlev = -1, and
    worklist pads routed to an infeasible trash row) contribute only
    DEV_INF. The tile_lo/tile_hi early-out is a kernel optimization, not
    semantics — the oracle joins every work item."""
    wqe = wq[qidx]                                          # [WL]
    hs, ws = hub[stile], wlev[stile]                        # [WL, lane]
    ht, wt = hub[ttile], wlev[ttile]
    ds = jnp.where(ws >= wqe[:, None],
                   jnp.minimum(dist[stile], DEV_INF), DEV_INF)
    dt = jnp.where(wt >= wqe[:, None],
                   jnp.minimum(dist[ttile], DEV_INF), DEV_INF)
    eq = hs[:, :, None] == ht[:, None, :]
    best = jnp.where(eq, ds[:, :, None] + dt[:, None, :], DEV_INF).min(
        axis=(1, 2))
    out = jnp.full((wq.shape[0],), DEV_INF, dtype=jnp.int32)
    return out.at[qidx].min(best)


def wcsd_profile_ragged_ref(hub, dist, wlev, qidx, stile, ttile,
                            num_rows: int, num_levels: int):
    """Ragged profile oracle: per work item, bin hub meets by pair level
    ``min(wlev_s, wlev_t)`` and scatter-min the [num_levels + 1] bucket
    rows into the output (suffix min-scan into the staircase happens in
    ops). Returns [num_rows, num_levels + 1]."""
    hs, ws = hub[stile], wlev[stile]
    ht, wt = hub[ttile], wlev[ttile]
    ds = jnp.minimum(dist[stile], DEV_INF)
    dt = jnp.minimum(dist[ttile], DEV_INF)
    eq = hs[:, :, None] == ht[:, None, :]
    dsum = jnp.where(eq, ds[:, :, None] + dt[:, None, :], DEV_INF)
    mw = jnp.minimum(ws[:, :, None], wt[:, None, :])
    bucket = jnp.stack([jnp.where(mw == lev, dsum, DEV_INF).min(axis=(1, 2))
                        for lev in range(num_levels + 1)], axis=1)
    out = jnp.full((num_rows, num_levels + 1), DEV_INF, dtype=jnp.int32)
    return out.at[qidx].min(bucket)


def _decode_tiles_ref(hub_delta, dist, wlev, tile_lo, tiles):
    """Oracle twin of the in-kernel compressed-tile decode
    (`wcsd_query._decode_cells`): gather [len(tiles), lane] tiles and
    widen — hub = tile_lo + delta (sign is the pad flag), float dist
    clamped at DEV_INF and rounded to int32, int8 wlev widened."""
    hd = hub_delta[tiles].astype(jnp.int32)
    h = jnp.where(hd >= 0, tile_lo[tiles][:, None] + hd, -1)
    d = (jnp.minimum(dist[tiles].astype(jnp.float32), float(DEV_INF))
         + 0.5).astype(jnp.int32)
    w = wlev[tiles].astype(jnp.int32)
    return h, d, w


def wcsd_query_ragged_compressed_ref(hub_delta, dist, wlev, tile_lo,
                                     qidx, stile, ttile, wq):
    """`wcsd_query_ragged_ref` over the compressed arena: decode the
    gathered tiles, then the identical join + scatter-min."""
    wqe = wq[qidx]
    hs, ds0, ws = _decode_tiles_ref(hub_delta, dist, wlev, tile_lo, stile)
    ht, dt0, wt = _decode_tiles_ref(hub_delta, dist, wlev, tile_lo, ttile)
    ds = jnp.where(ws >= wqe[:, None], ds0, DEV_INF)
    dt = jnp.where(wt >= wqe[:, None], dt0, DEV_INF)
    eq = hs[:, :, None] == ht[:, None, :]
    best = jnp.where(eq, ds[:, :, None] + dt[:, None, :], DEV_INF).min(
        axis=(1, 2))
    out = jnp.full((wq.shape[0],), DEV_INF, dtype=jnp.int32)
    return out.at[qidx].min(best)


def wcsd_profile_ragged_compressed_ref(hub_delta, dist, wlev, tile_lo,
                                       qidx, stile, ttile,
                                       num_rows: int, num_levels: int):
    """`wcsd_profile_ragged_ref` over the compressed arena."""
    hs, ds, ws = _decode_tiles_ref(hub_delta, dist, wlev, tile_lo, stile)
    ht, dt, wt = _decode_tiles_ref(hub_delta, dist, wlev, tile_lo, ttile)
    eq = hs[:, :, None] == ht[:, None, :]
    dsum = jnp.where(eq, ds[:, :, None] + dt[:, None, :], DEV_INF)
    mw = jnp.minimum(ws[:, :, None], wt[:, None, :])
    bucket = jnp.stack([jnp.where(mw == lev, dsum, DEV_INF).min(axis=(1, 2))
                        for lev in range(num_levels + 1)], axis=1)
    out = jnp.full((num_rows, num_levels + 1), DEV_INF, dtype=jnp.int32)
    return out.at[qidx].min(bucket)


def wcsd_profile_segmented_ref(hub_s, dist_s, wlev_s, hub_t, dist_t, wlev_t,
                               srow, trow, num_levels: int):
    """Profile-path oracle, mirroring the kernel's bucket-minima contract:
    gather both rows once, bin each hub meet's distance sum by its pair
    level ``min(wlev_s, wlev_t)``, return [B, num_levels + 1] bucket
    minima (suffix min-scan into the staircase happens in ops). Pad cells
    carry wlev = -1 and fall below every bucket."""
    hs, ds, ws = hub_s[srow], jnp.minimum(dist_s[srow], DEV_INF), wlev_s[srow]
    ht, dt, wt = hub_t[trow], jnp.minimum(dist_t[trow], DEV_INF), wlev_t[trow]
    eq = hs[:, :, None] == ht[:, None, :]
    dsum = jnp.where(eq, ds[:, :, None] + dt[:, None, :], DEV_INF)
    mw = jnp.minimum(ws[:, :, None], wt[:, None, :])
    return jnp.stack([jnp.where(mw == lev, dsum, DEV_INF).min(axis=(1, 2))
                      for lev in range(num_levels + 1)], axis=1)


def wc_prune_emit_batched_ref(F, T, hub, dist, wlev, d):
    """Batched prune+emit oracle (the `_batched_round` jnp gather soup):
    F [B, V], T [B, V, W+1], hub/dist/wlev [V, cap], d scalar round."""
    INF = 1 << 30
    B, V = F.shape
    fw = jnp.clip(F, 0, T.shape[2] - 1)
    tv = T[jnp.arange(B)[:, None, None],
           jnp.clip(hub, 0, V - 1)[None, :, :],
           fw[:, :, None]]                                      # [B, V, cap]
    feas = (hub >= 0)[None] & (wlev[None] >= fw[:, :, None])
    cand = jnp.where(feas, jnp.minimum(dist, DEV_INF)[None]
                     + jnp.minimum(tv, DEV_INF), INF)
    q = cand.min(axis=2)
    survive = (F >= 0) & (q > d)
    return jnp.where(survive, F, -1)


def wc_relax_batched_ref(emit_w, nbr_pad, lvl_pad, rank, root_ranks, R):
    """Batched relaxation oracle: emit_w [B, V], nbr_pad/lvl_pad [V, D],
    rank [1, V], root_ranks [B], R [B, V] -> (newF, newR)."""
    fwn = emit_w[:, jnp.clip(nbr_pad, 0, emit_w.shape[1] - 1)]  # [B, V, D]
    fwn = jnp.where(nbr_pad[None] >= 0, fwn, -1)
    wp = jnp.minimum(fwn, lvl_pad[None])
    cand = wp.max(axis=2)
    cand = jnp.where(rank[0][None, :] > root_ranks[:, None], cand, -1)
    improved = cand > R
    return jnp.where(improved, cand, -1), jnp.maximum(R, cand)


def frontier_relax_gathered_ref(fw_nbr, lvl_pad, R):
    wprime = jnp.minimum(fw_nbr, lvl_pad)
    cand = wprime.max(axis=1)
    newf = jnp.where(cand > R, cand, -1)
    newr = jnp.maximum(R, cand)
    return newf, newr


def cin_layer_ref(x1, x0, w):
    """out[b,k,d] = sum_{h,m} w[k,h,m] x1[b,h,d] x0[b,m,d] (fp32 accum)."""
    return jnp.einsum("bhd,bmd,khm->bkd", x1.astype(jnp.float32),
                      x0.astype(jnp.float32), w.astype(jnp.float32))


def flash_attention_ref(q, k, v, *, causal: bool = True, scale=None):
    """Plain softmax attention oracle, GQA-aware.

    q: [B, Hq, Tq, Dh], k/v: [B, Hkv, Tk, Dh]; Hq % Hkv == 0."""
    B, Hq, Tq, Dh = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = (Dh ** -0.5) if scale is None else scale
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Tq, Dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * scale
    if causal:
        Tk = k.shape[2]
        mask = jnp.arange(Tq)[:, None] + (Tk - Tq) >= jnp.arange(Tk)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return out.reshape(B, Hq, Tq, Dh).astype(q.dtype)

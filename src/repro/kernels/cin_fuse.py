"""Pallas TPU kernel: fused xDeepFM CIN layer (Compressed Interaction
Network, arXiv:1803.05170 — the `interaction=cin` core of the assigned
`xdeepfm` architecture).

    out[b, k, d] = sum_{h, m} W[k, h, m] * x1[b, h, d] * x0[b, m, d]

Naive XLA materializes the outer product z[b, h, m, d] — at the assigned
train_batch (65536) that is B*H*M*D = 65536*200*39*10 floats (~2 TB/step
across layers). The kernel tiles B, forms z only inside VMEM, and contracts
against W with one MXU dot per (batch-tile): reshape z to [bB*D, H*M] and
W to [K, H*M] — an ordinary [bB*D, HM] x [HM, K] matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cin_kernel(x1_ref, x0_ref, w_ref, out_ref):
    x1 = x1_ref[...]            # [bB, H, D]
    x0 = x0_ref[...]            # [bB, M, D]
    w = w_ref[...]              # [K, H, M]
    bB, H, D = x1.shape
    M = x0.shape[1]
    K = w.shape[0]
    z = x1[:, :, None, :] * x0[:, None, :, :]          # [bB, H, M, D] in VMEM
    z2 = z.reshape(bB, H * M, D).transpose(0, 2, 1)    # [bB, D, HM]
    z2 = z2.reshape(bB * D, H * M)
    out = jax.lax.dot_general(z2, w.reshape(K, H * M),
                              (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [bB*D, K]
    out_ref[...] = out.reshape(bB, D, K).transpose(0, 2, 1)        # [bB, K, D]


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def cin_layer(x1, x0, w, *, block_b: int = 8, interpret: bool = True):
    """x1: [B, H, D], x0: [B, M, D], w: [K, H, M] -> [B, K, D] float32."""
    B, H, D = x1.shape
    M = x0.shape[1]
    K = w.shape[0]
    grid = (B // block_b,)
    return pl.pallas_call(
        _cin_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, H, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, M, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((K, H, M), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, K, D), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, D), jnp.float32),
        interpret=interpret,
    )(x1, x0, w)

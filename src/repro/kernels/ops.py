"""Public jit'd wrappers for the Pallas kernels: shape padding, feasibility
masking, and dispatch (kernel vs jnp fallback). Everything here is safe to
call from traced code."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import cin_fuse as _cin
from . import frontier as _frontier
from . import ref as _ref
from . import wcsd_query as _wq

DEV_INF = 1 << 29  # python int: safe to close over in pallas kernels
INF_DIST = 1 << 30


def _ceil_to(x: int, m: int) -> int:
    return int(-(-x // m) * m)


def resolve_interpret(interpret: bool | None) -> bool:
    """THE resolution point for the Pallas ``interpret`` flag.

    Every engine takes ``interpret=None`` by default and resolves it here,
    so ``use_pallas=True`` engines reach the COMPILED kernels whenever the
    backend can lower them — interpret mode is for explicit requests and
    backends without Mosaic support, not a silent production default.

    Only the TPU backend resolves to compiled: every kernel in this
    package is TPU Pallas (`pltpu.PrefetchScalarGridSpec` scalar
    prefetch), which neither CPU nor GPU can lower — those backends
    emulate.

    Resolution table (locked by tests/test_ragged.py):

        interpret arg | backend      | resolved
        --------------+--------------+---------
        True          | any          | True
        False         | any          | False
        None          | tpu          | False  (compiled Mosaic kernels)
        None          | cpu/gpu/...  | True   (no Mosaic: emulate)
    """
    if interpret is not None:
        return bool(interpret)
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel"))
def wcsd_query(hub, dist, wlev, count, s, t, w_level, *,
               interpret: bool = True, use_kernel: bool = True):
    """Batched WCSD queries against padded device labels.

    hub/dist/wlev: [V, L] int32, count: [V], queries s/t/w_level: [B].
    Returns [B] int32 distances (INF_DIST when no feasible path)."""
    B = s.shape[0]
    L = hub.shape[1]
    col = jnp.arange(L)

    def side(v):
        m = (col[None, :] < count[v, None]) & (wlev[v] >= w_level[:, None])
        d = jnp.where(m, jnp.minimum(dist[v], DEV_INF), DEV_INF)
        return hub[v], d

    hs, ds = side(s)
    ht, dt = side(t)
    if use_kernel:
        Bp = _ceil_to(max(B, 1), 8)
        Lp = _ceil_to(L, 128)
        pad_b, pad_l = Bp - B, Lp - L
        # hub pad: -1 on s side, -2 on t side -> never equal
        hs = jnp.pad(hs, ((0, pad_b), (0, pad_l)), constant_values=-1)
        ht = jnp.pad(ht, ((0, pad_b), (0, pad_l)), constant_values=-2)
        ds = jnp.pad(ds, ((0, pad_b), (0, pad_l)), constant_values=DEV_INF)
        dt = jnp.pad(dt, ((0, pad_b), (0, pad_l)), constant_values=DEV_INF)
        best = _wq.wcsd_query_gathered(hs, ds, ht, dt,
                                       interpret=interpret)[:B]
    else:
        best = _ref.wcsd_query_gathered_ref(hs, ds, ht, dt)
    return jnp.where(best >= DEV_INF, INF_DIST, best).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel"))
def wcsd_query_segmented(hub_s, dist_s, wlev_s, hub_t, dist_t, wlev_t,
                         srow, trow, w_level, *, interpret: bool = True,
                         use_kernel: bool = True):
    """One bucket-pair sub-batch of the segmented CSR query path.

    hub_s/dist_s/wlev_s: [Ns, Ws] s-side bucket tiles, hub_t/...: [Nt, Wt]
    t-side tiles (Ws, Wt multiples of 128; pad contract hub = -1,
    wlev = -1). srow/trow: [B] row ids into the tiles, w_level: [B].
    Returns [B] int32 distances (INF_DIST when no feasible path)."""
    if use_kernel:
        best = _wq.wcsd_query_segmented(hub_s, dist_s, wlev_s,
                                        hub_t, dist_t, wlev_t,
                                        srow, trow, w_level,
                                        interpret=interpret)
    else:
        best = _ref.wcsd_query_segmented_ref(hub_s, dist_s, wlev_s,
                                             hub_t, dist_t, wlev_t,
                                             srow, trow, w_level)
    return jnp.where(best >= DEV_INF, INF_DIST, best).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel"))
def wcsd_query_segmented_staged(hub_s, dist_s, wlev_s, hub_t, dist_t, wlev_t,
                                stq, *, interpret: bool = True,
                                use_kernel: bool = True):
    """`wcsd_query_segmented` fed by ONE fused staging array: ``stq`` is
    [3, B] int32 carrying (srow, trow, w_level) stacked, so a planned
    sub-batch pays a single H2D transfer instead of three — the unpack
    happens on device, inside this jit."""
    return wcsd_query_segmented(hub_s, dist_s, wlev_s, hub_t, dist_t, wlev_t,
                                stq[0], stq[1], stq[2], interpret=interpret,
                                use_kernel=use_kernel)


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel"))
def wcsd_query_ragged(hub, dist, wlev, tile_lo, tile_hi,
                      qidx, stile, ttile, first, wq, *,
                      interpret: bool = True, use_kernel: bool = True):
    """One ragged sub-batch — which is the WHOLE batch: every bucket mix in
    a single launch over the lane-tiled arena (see `kernels.wcsd_query.
    wcsd_query_ragged` for the worklist contract). Returns [Q] int32
    distances (INF_DIST when no feasible path)."""
    if use_kernel:
        best = _wq.wcsd_query_ragged(hub, dist, wlev, tile_lo, tile_hi,
                                     qidx, stile, ttile, first, wq,
                                     interpret=interpret)
    else:
        best = _ref.wcsd_query_ragged_ref(hub, dist, wlev, qidx, stile,
                                          ttile, wq)
    return jnp.where(best >= DEV_INF, INF_DIST, best).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_rows", "num_levels",
                                             "interpret", "use_kernel"))
def wcsd_profile_ragged(hub, dist, wlev, tile_lo, tile_hi,
                        qidx, stile, ttile, first, *, num_rows: int,
                        num_levels: int, interpret: bool = True,
                        use_kernel: bool = True):
    """Ragged PROFILE batch: same worklist contract as `wcsd_query_ragged`,
    every constraint level answered from the one sweep. The kernel (or its
    jnp oracle) emits per-pair-level bucket minima; the suffix min-scan
    applied here turns them into staircases. Returns
    [num_rows, num_levels + 1] int32 (INF_DIST where infeasible)."""
    if use_kernel:
        bucket = _wq.wcsd_profile_ragged(hub, dist, wlev, tile_lo, tile_hi,
                                         qidx, stile, ttile, first,
                                         num_rows=num_rows,
                                         num_levels=num_levels,
                                         interpret=interpret)
    else:
        bucket = _ref.wcsd_profile_ragged_ref(hub, dist, wlev, qidx, stile,
                                              ttile, num_rows, num_levels)
    prof = jax.lax.cummin(bucket, axis=1, reverse=True)
    return jnp.where(prof >= DEV_INF, INF_DIST, prof).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel"))
def wcsd_query_ragged_compressed(hub_delta, dist, wlev, tile_lo, tile_hi,
                                 qidx, stile, ttile, first, wq, *,
                                 interpret: bool = True,
                                 use_kernel: bool = True):
    """`wcsd_query_ragged` over the COMPRESSED arena (CompressedArena
    fields; decode happens in-kernel / in the oracle). Same worklist and
    output contract; callers must route overflowed stores to the
    uncompressed path."""
    if use_kernel:
        best = _wq.wcsd_query_ragged_compressed(
            hub_delta, dist, wlev, tile_lo, tile_hi,
            qidx, stile, ttile, first, wq, interpret=interpret)
    else:
        best = _ref.wcsd_query_ragged_compressed_ref(
            hub_delta, dist, wlev, tile_lo, qidx, stile, ttile, wq)
    return jnp.where(best >= DEV_INF, INF_DIST, best).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_rows", "num_levels",
                                             "interpret", "use_kernel"))
def wcsd_profile_ragged_compressed(hub_delta, dist, wlev, tile_lo, tile_hi,
                                   qidx, stile, ttile, first, *,
                                   num_rows: int, num_levels: int,
                                   interpret: bool = True,
                                   use_kernel: bool = True):
    """`wcsd_profile_ragged` over the COMPRESSED arena."""
    if use_kernel:
        bucket = _wq.wcsd_profile_ragged_compressed(
            hub_delta, dist, wlev, tile_lo, tile_hi,
            qidx, stile, ttile, first, num_rows=num_rows,
            num_levels=num_levels, interpret=interpret)
    else:
        bucket = _ref.wcsd_profile_ragged_compressed_ref(
            hub_delta, dist, wlev, tile_lo, qidx, stile, ttile,
            num_rows, num_levels)
    prof = jax.lax.cummin(bucket, axis=1, reverse=True)
    return jnp.where(prof >= DEV_INF, INF_DIST, prof).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_levels", "interpret",
                                             "use_kernel"))
def wcsd_profile_segmented(hub_s, dist_s, wlev_s, hub_t, dist_t, wlev_t,
                           srow, trow, *, num_levels: int,
                           interpret: bool = True, use_kernel: bool = True):
    """One bucket-pair sub-batch of the one-pass PROFILE query path.

    Same tile/row-id contract as `wcsd_query_segmented`, minus the
    per-query level: both label rows are gathered once and every
    constraint level is answered from that single sweep. The kernel (or
    its jnp oracle) emits per-pair-level bucket minima; the suffix
    min-scan over the level axis applied here turns them into the
    staircase. Returns [B, num_levels + 1] int32 distances —
    ``out[b, w] == wcsd_query_segmented(..., w)[b]`` pointwise, with
    INF_DIST where no feasible path exists."""
    if use_kernel:
        bucket = _wq.wcsd_profile_segmented(hub_s, dist_s, wlev_s,
                                            hub_t, dist_t, wlev_t,
                                            srow, trow,
                                            num_levels=num_levels,
                                            interpret=interpret)
    else:
        bucket = _ref.wcsd_profile_segmented_ref(hub_s, dist_s, wlev_s,
                                                 hub_t, dist_t, wlev_t,
                                                 srow, trow, num_levels)
    prof = jax.lax.cummin(bucket, axis=1, reverse=True)
    return jnp.where(prof >= DEV_INF, INF_DIST, prof).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_levels", "interpret",
                                             "use_kernel"))
def wcsd_profile_segmented_staged(hub_s, dist_s, wlev_s,
                                  hub_t, dist_t, wlev_t, stq, *,
                                  num_levels: int, interpret: bool = True,
                                  use_kernel: bool = True):
    """`wcsd_profile_segmented` fed by one fused [2, B] (srow, trow)
    staging array — single H2D per planned sub-batch, unpacked in-jit."""
    return wcsd_profile_segmented(hub_s, dist_s, wlev_s,
                                  hub_t, dist_t, wlev_t, stq[0], stq[1],
                                  num_levels=num_levels, interpret=interpret,
                                  use_kernel=use_kernel)


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel"))
def frontier_relax(nbr_pad, lvl_pad, Fw, R, *, interpret: bool = True,
                   use_kernel: bool = True):
    """One constrained-relaxation round over a padded adjacency.

    nbr_pad/lvl_pad: [V, D] (pad: nbr=-1, lvl=-1); Fw/R: [V] int32.
    Returns (newF, newR), both [V]."""
    fw_nbr = Fw[jnp.clip(nbr_pad, 0, Fw.shape[0] - 1)]
    fw_nbr = jnp.where(nbr_pad >= 0, fw_nbr, -1)
    if not use_kernel:
        return _ref.frontier_relax_gathered_ref(fw_nbr, lvl_pad, R)
    V, D = fw_nbr.shape
    bV = 256 if V % 256 == 0 else (64 if V % 64 == 0 else 8)
    Vp = _ceil_to(V, bV)
    if Vp != V:
        fw_nbr = jnp.pad(fw_nbr, ((0, Vp - V), (0, 0)), constant_values=-1)
        lvl_pad = jnp.pad(lvl_pad, ((0, Vp - V), (0, 0)), constant_values=-1)
        R = jnp.pad(R, (0, Vp - V), constant_values=jnp.int32(1 << 20))
    newf, newr = _frontier.frontier_relax_gathered(
        fw_nbr, lvl_pad, R, block_v=bV, interpret=interpret)
    return newf[:V], newr[:V]


def _pick_block_v(V: int) -> int:
    return 256 if V % 256 == 0 else (64 if V % 64 == 0 else 8)


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel",
                                             "do_prune"))
def wc_prune_emit(F, T, hub, dist, wlev, d, *, do_prune: bool = True,
                  interpret: bool = True, use_kernel: bool = True):
    """Fused partial-index prune + emission for a batch of roots.

    F: [B, V] frontier levels (-1 inactive); T: [B, V, W+1] per-root hub
    tables (indexed by hub rank); hub/dist/wlev: [V, cap] padded partial
    index; d: scalar current round. Returns emit_w [B, V] (-1 = no emit).
    With do_prune=False (round 0) the whole active frontier emits."""
    if not do_prune:
        return F
    if not use_kernel:
        return _ref.wc_prune_emit_batched_ref(F, T, hub, dist, wlev, d)
    B, V = F.shape
    bV = _pick_block_v(V)
    Vp = _ceil_to(V, bV)
    if Vp != V:
        F = jnp.pad(F, ((0, 0), (0, Vp - V)), constant_values=-1)
        hub = jnp.pad(hub, ((0, Vp - V), (0, 0)), constant_values=-1)
        dist = jnp.pad(dist, ((0, Vp - V), (0, 0)), constant_values=INF_DIST)
        wlev = jnp.pad(wlev, ((0, Vp - V), (0, 0)), constant_values=-1)
    emit = _frontier.wc_prune_emit_batched(
        F, T, hub, dist, wlev, jnp.asarray(d, jnp.int32).reshape(1),
        block_v=bV, interpret=interpret)
    return emit[:, :V]


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel"))
def wc_relax_batched(emit_w, nbr_pad, lvl_pad, rank, root_ranks, R, *,
                     interpret: bool = True, use_kernel: bool = True):
    """One batched constrained-relaxation round.

    emit_w/R: [B, V]; nbr_pad/lvl_pad: [V, D] (pad: nbr = -1, lvl = -1);
    rank: [V] vertex -> rank; root_ranks: [B]. Returns (newF, newR)."""
    B, V = emit_w.shape
    rank2 = rank[None, :]
    if not use_kernel:
        return _ref.wc_relax_batched_ref(emit_w, nbr_pad, lvl_pad, rank2,
                                         root_ranks, R)
    bV = _pick_block_v(V)
    Vp = _ceil_to(V, bV)
    if Vp != V:
        emit_w = jnp.pad(emit_w, ((0, 0), (0, Vp - V)), constant_values=-1)
        nbr_pad = jnp.pad(nbr_pad, ((0, Vp - V), (0, 0)), constant_values=-1)
        lvl_pad = jnp.pad(lvl_pad, ((0, Vp - V), (0, 0)), constant_values=-1)
        rank2 = jnp.pad(rank2, ((0, 0), (0, Vp - V)), constant_values=-1)
        R = jnp.pad(R, ((0, 0), (0, Vp - V)),
                    constant_values=jnp.int32(1 << 20))
    newf, newr = _frontier.wc_relax_batched(
        emit_w, nbr_pad, lvl_pad, rank2, root_ranks, R,
        block_v=bV, interpret=interpret)
    return newf[:, :V], newr[:, :V]


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel",
                                             "block_b"))
def cin_layer(x1, x0, w, *, interpret: bool = True, use_kernel: bool = True,
              block_b: int = 8):
    """Fused CIN layer; pads batch to the block size."""
    if not use_kernel:
        return _ref.cin_layer_ref(x1, x0, w)
    B = x1.shape[0]
    Bp = _ceil_to(max(B, 1), block_b)
    if Bp != B:
        x1 = jnp.pad(x1, ((0, Bp - B), (0, 0), (0, 0)))
        x0 = jnp.pad(x0, ((0, Bp - B), (0, 0), (0, 0)))
    out = _cin.cin_layer(x1, x0, w, block_b=block_b, interpret=interpret)
    return out[:B]

"""Training loop: jitted train_step factory (loss -> grad -> clip -> update)
with optional gradient accumulation (microbatch scan) and int8 gradient
compression, plus a host-side Trainer that drives steps, tracks step-time
EMA (straggler signal) and checkpoints."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from . import optim as O
from .grad_compress import compress_decompress


def make_train_step(loss_fn: Callable, opt_cfg: O.OptimizerConfig,
                    accum_steps: int = 1, compress_grads: bool = False):
    """loss_fn(params, batch) -> scalar. Returns
    train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    accum_steps > 1: the batch's leading axis is split into microbatches and
    grads are accumulated with a lax.scan (constant memory in microbatches).
    compress_grads: int8-quantize gradients (with error feedback folded into
    the next step via the returned residual) before the optimizer — the
    cross-replica all-reduce then moves 4x fewer bytes.
    """
    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = grad_fn(params, batch)
        else:
            def micro(b):
                return jax.tree.map(
                    lambda x: x.reshape((accum_steps, -1) + x.shape[1:]), b)

            def body(acc, mb):
                l, g = grad_fn(params, mb)
                return (acc[0] + l,
                        jax.tree.map(jnp.add, acc[1], g)), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zero),
                                            micro(batch))
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
        if compress_grads:
            grads = jax.tree.map(lambda g: compress_decompress(g)[0], grads)
        params, opt_state, m = O.apply_updates(opt_cfg, params, grads,
                                               opt_state)
        m["loss"] = loss
        return params, opt_state, m

    return train_step


@dataclasses.dataclass
class StepTimeMonitor:
    """EMA-based straggler detector: flags steps whose duration exceeds
    mean + z * std of the running estimate (the large-scale runtime would
    feed per-host step times in here)."""
    alpha: float = 0.1
    z: float = 3.0
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    stragglers: int = 0

    def observe(self, dt: float) -> bool:
        self.n += 1
        if self.n == 1:
            self.mean = dt
            return False
        is_straggler = dt > self.mean + self.z * (self.var ** 0.5 + 1e-9) \
            and self.n > 5
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        if is_straggler:
            self.stragglers += 1
        return is_straggler


class Trainer:
    """Host driver: runs steps, records metrics, periodic checkpoints."""

    def __init__(self, train_step, params, opt_state, *,
                 checkpoint_manager=None, ckpt_every: int = 0):
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.ckpt = checkpoint_manager
        self.ckpt_every = ckpt_every
        self.monitor = StepTimeMonitor()
        self.history: list[dict] = []
        self.step = 0

    def run(self, batches, max_steps: Optional[int] = None):
        for batch in batches:
            t0 = time.perf_counter()
            self.params, self.opt_state, m = self.train_step(
                self.params, self.opt_state, batch)
            jax.block_until_ready(m["loss"])
            dt = time.perf_counter() - t0
            straggler = self.monitor.observe(dt)
            rec = {k: float(v) for k, v in m.items()}
            rec.update(step=self.step, time_s=dt, straggler=straggler)
            self.history.append(rec)
            self.step += 1
            if self.ckpt and self.ckpt_every and \
                    self.step % self.ckpt_every == 0:
                self.ckpt.save(self.step, {"params": self.params,
                                           "opt_state": self.opt_state})
            if max_steps and self.step >= max_steps:
                break
        return self.history

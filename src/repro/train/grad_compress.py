"""Int8 gradient compression with error feedback (1-bit-Adam-family trick,
adapted to int8 for TPU all-reduce friendliness).

quantize: g -> (int8 q, fp32 scale) with per-tensor absmax scaling.
The communication story on a real mesh: psum over int8 payloads moves 4x
fewer bytes over ICI/DCI; error feedback keeps SGD/Adam convergence
(residual = g - dequant(q) is added to the next step's gradient). The pure
functions below are used both inside train_step (simulation: quantize ->
dequantize) and by distributed/collectives.compressed_psum (shard_map)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(g):
    a = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jnp.maximum(a, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_decompress(g, residual=None):
    """Returns (g_hat, new_residual). Error feedback: compress (g + r)."""
    if residual is not None:
        g = g.astype(jnp.float32) + residual
    q, s = quantize_int8(g)
    g_hat = dequantize_int8(q, s)
    return g_hat, g - g_hat


def compressed_psum(g, axis_name: str):
    """shard_map collective: int8 all-reduce with fp32 scale exchange.
    Scales are max-reduced first so every shard quantizes onto the same
    grid; payload psum then runs on int8 (4x fewer bytes on the wire)."""
    a = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jax.lax.pmax(jnp.maximum(a, 1e-12), axis_name) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    # accumulate in int32 to avoid overflow across shards
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale

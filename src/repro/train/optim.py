"""Hand-rolled optimizers (no optax in this environment): AdamW and SGD with
momentum, global-norm clipping, and warmup-cosine schedules. Optimizer
states mirror the parameter pytree, so they inherit the parameter
PartitionSpecs (fully sharded optimizer == ZeRO-1 under FSDP specs)."""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


class SGDState(NamedTuple):
    step: jax.Array
    mom: dict


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def warmup_cosine(cfg: OptimizerConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def clip_by_global_norm(grads, max_norm):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def init_opt_state(cfg: OptimizerConfig, params):
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    if cfg.name == "adamw":
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(), v=zeros())
    if cfg.name == "sgd":
        return SGDState(step=jnp.zeros((), jnp.int32), mom=zeros())
    raise ValueError(cfg.name)


def abstract_opt_state(cfg: OptimizerConfig, abstract_params):
    like = lambda: jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), abstract_params)
    if cfg.name == "adamw":
        return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                          m=like(), v=like())
    if cfg.name == "sgd":
        return SGDState(step=jax.ShapeDtypeStruct((), jnp.int32), mom=like())
    raise ValueError(cfg.name)


def opt_state_shardings(cfg: OptimizerConfig, param_specs):
    from jax.sharding import PartitionSpec as P
    if cfg.name == "adamw":
        return AdamWState(step=P(), m=param_specs, v=param_specs)
    if cfg.name == "sgd":
        return SGDState(step=P(), mom=param_specs)
    raise ValueError(cfg.name)


def apply_updates(cfg: OptimizerConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    lr = warmup_cosine(cfg, state.step)
    if cfg.name == "adamw":
        b1, b2 = cfg.betas
        step = state.step + 1
        t = step.astype(jnp.float32)
        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            mhat = m2 / (1 - b1 ** t)
            vhat = v2 / (1 - b2 ** t)
            step_p = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
            return p - lr * step_p, m2, v2
        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state.m)
        flat_v = jax.tree.leaves(state.v)
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
        return new_p, AdamWState(step, new_m, new_v), {
            "grad_norm": gnorm, "lr": lr}
    if cfg.name == "sgd":
        step = state.step + 1
        def upd(p, g, mom):
            mom2 = 0.9 * mom + g.astype(jnp.float32)
            return p - lr * (mom2 + cfg.weight_decay * p), mom2
        flat_p, tdef = jax.tree.flatten(params)
        out = [upd(p, g, m) for p, g, m in
               zip(flat_p, jax.tree.leaves(grads),
                   jax.tree.leaves(state.mom))]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_mom = jax.tree.unflatten(tdef, [o[1] for o in out])
        return new_p, SGDState(step, new_mom), {"grad_norm": gnorm, "lr": lr}
    raise ValueError(cfg.name)

"""xDeepFM (arXiv:1803.05170): sparse embeddings + CIN + DNN + linear.

JAX has no nn.EmbeddingBag and no CSR sparse — the embedding substrate here
is built from jnp.take + jax.ops.segment_sum (`embedding_bag`), per the
assignment. The CIN interaction uses a D-sliced contraction that never
materializes the [B, H, M, D] outer product (the Pallas kernel
kernels/cin_fuse.py is the fused TPU form; the model path below is its
XLA-lowerable equivalent used by the dry-run).

Distribution: embedding tables are row(vocab)-sharded over "model" (classic
recsys model parallelism — per-step traffic is the gathered [B, F, D]
activations, not the tables); batch shards over ("pod", "data").
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import trunc_normal


@dataclasses.dataclass(frozen=True)
class XDeepFMConfig:
    name: str
    n_sparse: int = 39
    embed_dim: int = 10
    cin_layers: tuple = (200, 200, 200)
    mlp_layers: tuple = (400, 400)
    # criteo-like skewed vocabulary: a few huge fields + many small ones
    big_fields: int = 8
    big_vocab: int = 1_000_000
    small_vocab: int = 1_000
    compute_dtype: str = "float32"

    @property
    def field_vocabs(self) -> tuple:
        return tuple([self.big_vocab] * self.big_fields +
                     [self.small_vocab] * (self.n_sparse - self.big_fields))

    @property
    def total_rows(self) -> int:
        # padded to 512 so row-sharding divides any mesh axis; pad rows are
        # never indexed (ids are generated within per-field vocabs)
        raw = sum(self.field_vocabs)
        return -(-raw // 512) * 512

    @property
    def field_offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.field_vocabs)[:-1]])


# ------------------------------------------------------------ embedding bag
def embedding_bag(table, ids, bag_ids, num_bags, mode: str = "sum",
                  weights=None):
    """EmbeddingBag from first principles: gather + segment reduce.

    table: [R, D]; ids: [K] row indices; bag_ids: [K] which bag each id
    belongs to; num_bags: static. mode: sum | mean."""
    rows = jnp.take(table, ids, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    out = jax.ops.segment_sum(rows, bag_ids, num_segments=num_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(ids, jnp.float32), bag_ids,
                                  num_segments=num_bags)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


# --------------------------------------------------------------- param defs
def param_defs(cfg: XDeepFMConfig) -> dict:
    D = cfg.embed_dim
    R = cfg.total_rows
    defs = {
        "embed": ((R, D), P("model", None)),       # row-sharded tables
        "linear": ((R, 1), P("model", None)),
        "bias": ((1,), P(None)),
    }
    h_prev = cfg.n_sparse
    for i, k in enumerate(cfg.cin_layers):
        defs[f"cin.w{i}"] = ((k, h_prev, cfg.n_sparse), P(None, None, None))
        h_prev = k
    defs["cin.out_w"] = ((sum(cfg.cin_layers), 1), P(None, None))
    d_in = cfg.n_sparse * D
    for i, width in enumerate(cfg.mlp_layers):
        defs[f"mlp.w{i}"] = ((d_in, width), P(None, "model"))
        defs[f"mlp.b{i}"] = ((width,), P("model"))
        d_in = width
    defs["mlp.out_w"] = ((d_in, 1), P(None, None))
    return defs


def _nest(flat: dict) -> dict:
    out: dict = {}
    for path, v in flat.items():
        parts = path.split(".")
        node = out
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return out


def init_params(cfg: XDeepFMConfig, key) -> dict:
    defs = param_defs(cfg)
    keys = jax.random.split(key, len(defs))
    flat = {}
    for (path, (shape, _)), k in zip(sorted(defs.items()), keys):
        if path.endswith(("bias",)) or ".b" in path:
            flat[path] = jnp.zeros(shape)
        elif path == "embed":
            flat[path] = 0.01 * jax.random.normal(k, shape)
        else:
            flat[path] = trunc_normal(k, shape)
    return _nest(flat)


def abstract_params(cfg: XDeepFMConfig) -> dict:
    return _nest({p: jax.ShapeDtypeStruct(s, jnp.float32)
                  for p, (s, _) in param_defs(cfg).items()})


def param_shardings(cfg: XDeepFMConfig) -> dict:
    return _nest({p: spec for p, (s, spec) in param_defs(cfg).items()})


# ------------------------------------------------------------------ forward
def _cin(x0, params, cfg: XDeepFMConfig):
    """Compressed Interaction Network, D-sliced (no [B,H,M,D] intermediate).

    x0: [B, M, D]. Returns [B, sum(cin_layers)] pooled features."""
    xk = x0
    pooled = []
    for i, _ in enumerate(cfg.cin_layers):
        w = params[f"w{i}"]                       # [K, H, M]
        # out[b,k,d] = sum_{h,m} w[k,h,m] xk[b,h,d] x0[b,m,d]
        # scan over D slices: only one [B, H, M] outer product lives at a
        # time (vmap would materialize all D at once — 10x the memory)
        wf = w.reshape(w.shape[0], -1)                  # [K, H*M]

        def per_d(_, xs):
            xk_d, x0_d = xs                             # [B, H], [B, M]
            z = (xk_d[:, :, None] * x0_d[:, None, :])   # [B, H, M]
            return None, z.reshape(z.shape[0], -1) @ wf.T

        _, out = jax.lax.scan(
            jax.checkpoint(per_d), None,
            (jnp.moveaxis(xk, 2, 0), jnp.moveaxis(x0, 2, 0)))
        out = jnp.moveaxis(out, 0, 2)                   # [B, K, D]
        pooled.append(out.sum(-1))                      # [B, K]
        xk = out
    return jnp.concatenate(pooled, axis=-1)


def forward(params, cfg: XDeepFMConfig, batch):
    """batch: ids [B, F] global row ids. Returns logits [B]."""
    ids = batch["ids"]
    B, F = ids.shape
    emb = jnp.take(params["embed"], ids.reshape(-1), axis=0)
    emb = emb.reshape(B, F, cfg.embed_dim)              # [B, F, D]
    lin = jnp.take(params["linear"], ids.reshape(-1), axis=0)
    lin = lin.reshape(B, F).sum(-1)
    cin_feat = _cin(emb, params["cin"], cfg)            # [B, sumK]
    cin_logit = (cin_feat @ params["cin"]["out_w"])[:, 0]
    h = emb.reshape(B, F * cfg.embed_dim)
    mp = params["mlp"]
    i = 0
    while f"w{i}" in mp:
        h = jax.nn.relu(h @ mp[f"w{i}"] + mp[f"b{i}"])
        i += 1
    dnn_logit = (h @ mp["out_w"])[:, 0]
    return lin + cin_logit + dnn_logit + params["bias"][0]


def loss_fn(params, cfg: XDeepFMConfig, batch):
    logits = forward(params, cfg, batch)
    y = batch["labels"].astype(jnp.float32)
    # numerically-stable BCE-with-logits
    loss = jnp.maximum(logits, 0) - logits * y + jnp.log1p(
        jnp.exp(-jnp.abs(logits)))
    return loss.mean()


def retrieval_scores(params, cfg: XDeepFMConfig, query_ids, cand_emb):
    """retrieval_cand shape: one query against [C, D'] candidate vectors.
    Query tower = mean of its field embeddings -> dot with candidates."""
    q = jnp.take(params["embed"], query_ids.reshape(-1), axis=0)
    q = q.reshape(-1, cfg.embed_dim).mean(0)
    scores = cand_emb @ q                                  # [C]
    top = jax.lax.top_k(scores, 100)
    return scores, top

"""GNN architectures over flat edge lists: GIN, PNA, GatedGCN.

Message passing is built on jax.ops.segment_sum / segment_max over an
edge-index (JAX has no CSR SpMM — the scatter/gather IS the system, per the
assignment). This is deliberately the same primitive as the WC-INDEX
constrained-BFS relaxation (core/wc_index_batched.py) — the paper's
technique and the GNN substrate share one sparse backend.

Input format (GraphBatch, a dict of arrays):
  feat        [N, F]  node features
  edges_src   [E]     source node ids (symmetrized)
  edges_dst   [E]     destination node ids
  edge_feat   [E, Fe] optional edge features (GatedGCN)
  labels      [N] (node tasks, -1 = unlabeled) or [G] (graph tasks)
  graph_id    [N]     for batched small graphs (molecule shape)
  n_graphs    static  number of graphs in the batch

Distribution: the edge axis shards over ("pod","data"); node states are
replicated, so per-shard partial aggregates meet in one all-reduce per
layer (see EXPERIMENTS.md §Roofline — these cells are collective-bound,
and §Perf shows the reduce-scatter variant).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import cross_entropy_loss, trunc_normal


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str                 # gin | pna | gatedgcn
    n_layers: int
    d_hidden: int
    d_feat: int
    n_classes: int
    graph_level: bool = False     # graph classification (molecule shape)
    d_edge: int = 0
    learnable_eps: bool = True    # GIN-eps
    compute_dtype: str = "float32"


# --------------------------------------------------------------- primitives
def segment_softmax(scores, seg, num_segments):
    m = jax.ops.segment_max(scores, seg, num_segments=num_segments)
    e = jnp.exp(scores - m[seg])
    z = jax.ops.segment_sum(e, seg, num_segments=num_segments)
    return e / (z[seg] + 1e-9)


def degree(edges_dst, num_nodes):
    return jax.ops.segment_sum(jnp.ones_like(edges_dst, jnp.float32),
                               edges_dst, num_segments=num_nodes)


# ------------------------------------------------------------------- layers
def gin_layer(h, lp, src, dst, N):
    agg = jax.ops.segment_sum(h[src], dst, num_segments=N)
    z = (1.0 + lp["eps"]) * h + agg
    z = jax.nn.relu(z @ lp["w1"] + lp["b1"])
    return z @ lp["w2"] + lp["b2"]


def pna_layer(h, lp, src, dst, N, deg_log_mean):
    msg = h[src] @ lp["w_msg"]
    d = degree(dst, N)
    s = jax.ops.segment_sum(msg, dst, num_segments=N)
    mean = s / jnp.maximum(d, 1.0)[:, None]
    mx = jax.ops.segment_max(msg, dst, num_segments=N)
    mx = jnp.where(d[:, None] > 0, mx, 0.0)
    mn = -jax.ops.segment_max(-msg, dst, num_segments=N)
    mn = jnp.where(d[:, None] > 0, mn, 0.0)
    sq = jax.ops.segment_sum(msg * msg, dst, num_segments=N)
    var = jnp.maximum(sq / jnp.maximum(d, 1.0)[:, None] - mean * mean, 0.0)
    std = jnp.sqrt(var + 1e-5)
    aggs = jnp.concatenate([mean, mx, mn, std], axis=-1)        # [N, 4d]
    logd = jnp.log1p(d)[:, None]
    amp = logd / deg_log_mean
    att = deg_log_mean / jnp.maximum(logd, 1e-5)
    scaled = jnp.concatenate([aggs, aggs * amp, aggs * att], -1)  # [N, 12d]
    return jax.nn.relu(jnp.concatenate([h, scaled], -1) @ lp["w_out"]
                       + lp["b_out"])


def gatedgcn_layer(h, e, lp, src, dst, N):
    hi, hj = h[dst], h[src]
    e_new = hi @ lp["A"] + hj @ lp["B"] + e @ lp["C"]
    eta = jax.nn.sigmoid(e_new)
    denom = jax.ops.segment_sum(eta, dst, num_segments=N) + 1e-6
    msg = eta * (hj @ lp["V"])
    agg = jax.ops.segment_sum(msg, dst, num_segments=N) / denom
    h_new = h @ lp["U"] + agg
    # residual + layernorm
    def ln(x, g, b):
        mu = x.mean(-1, keepdims=True)
        v = ((x - mu) ** 2).mean(-1, keepdims=True)
        return (x - mu) * jax.lax.rsqrt(v + 1e-5) * g + b
    h_out = h + jax.nn.relu(ln(h_new, lp["ln_h_g"], lp["ln_h_b"]))
    e_out = e + jax.nn.relu(ln(e_new, lp["ln_e_g"], lp["ln_e_b"]))
    return h_out, e_out


# --------------------------------------------------------------- param defs
def param_defs(cfg: GNNConfig) -> dict:
    L, d = cfg.n_layers, cfg.d_hidden
    defs = {
        "enc_w": ((cfg.d_feat, d), P(None, None)),
        "enc_b": ((d,), P(None)),
        "head_w": ((d, cfg.n_classes), P(None, None)),
        "head_b": ((cfg.n_classes,), P(None)),
    }
    if cfg.kind == "gin":
        defs.update({
            "layers.eps": ((L,), P(None)),
            "layers.w1": ((L, d, d), P(None, None, None)),
            "layers.b1": ((L, d), P(None, None)),
            "layers.w2": ((L, d, d), P(None, None, None)),
            "layers.b2": ((L, d), P(None, None)),
        })
    elif cfg.kind == "pna":
        defs.update({
            "layers.w_msg": ((L, d, d), P(None, None, None)),
            "layers.w_out": ((L, 13 * d, d), P(None, None, None)),
            "layers.b_out": ((L, d), P(None, None)),
        })
    elif cfg.kind == "gatedgcn":
        for m in ("A", "B", "C", "U", "V"):
            defs[f"layers.{m}"] = ((L, d, d), P(None, None, None))
        for m in ("ln_h_g", "ln_h_b", "ln_e_g", "ln_e_b"):
            defs[f"layers.{m}"] = ((L, d), P(None, None))
        defs["edge_enc_w"] = ((max(cfg.d_edge, 1), d), P(None, None))
        defs["edge_enc_b"] = ((d,), P(None))
    else:
        raise ValueError(cfg.kind)
    return defs


def _nest(flat: dict) -> dict:
    out: dict = {}
    for path, v in flat.items():
        parts = path.split(".")
        node = out
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return out


def init_params(cfg: GNNConfig, key) -> dict:
    defs = param_defs(cfg)
    keys = jax.random.split(key, len(defs))
    flat = {}
    for (path, (shape, _)), k in zip(sorted(defs.items()), keys):
        if path.endswith(("_b", ".eps", "b1", "b2", "b_out")) or "ln_" in path:
            base = jnp.ones(shape) if path.endswith("_g") else jnp.zeros(shape)
            flat[path] = base
        else:
            flat[path] = trunc_normal(k, shape)
    return _nest(flat)


def abstract_params(cfg: GNNConfig) -> dict:
    return _nest({p: jax.ShapeDtypeStruct(s, jnp.float32)
                  for p, (s, _) in param_defs(cfg).items()})


def param_shardings(cfg: GNNConfig) -> dict:
    return _nest({p: spec for p, (s, spec) in param_defs(cfg).items()})


# ------------------------------------------------------------------ forward
def forward(params, cfg: GNNConfig, batch, n_graphs: int | None = None):
    dt = jnp.dtype(cfg.compute_dtype)
    src, dst = batch["edges_src"], batch["edges_dst"]
    N = batch["feat"].shape[0]
    h = batch["feat"].astype(dt) @ params["enc_w"].astype(dt) \
        + params["enc_b"].astype(dt)
    if cfg.kind == "gatedgcn":
        ef = batch.get("edge_feat")
        if ef is None:
            ef = jnp.ones((src.shape[0], 1), dt)
        e = ef.astype(dt) @ params["edge_enc_w"].astype(dt) \
            + params["edge_enc_b"].astype(dt)
    else:
        e = None
    deg_log_mean = jnp.maximum(jnp.log1p(degree(dst, N)).mean(), 1e-2)

    def apply_layer(h, e, lp):
        lp = jax.tree.map(lambda a: a.astype(dt), lp)
        if cfg.kind == "gin":
            h2, e2 = gin_layer(h, lp, src, dst, N), e
        elif cfg.kind == "pna":
            # degree scalers are fp32; pin the carry dtype for the scan
            h2, e2 = pna_layer(h, lp, src, dst, N, deg_log_mean), e
        else:
            h2, e2 = gatedgcn_layer(h, e, lp, src, dst, N)
        return h2.astype(dt), (e2.astype(dt) if e2 is not None else e2)

    lp_stack = params["layers"]
    big = N > 500_000
    block = 4 if (big and cfg.n_layers % 4 == 0) else 1
    if big and block > 1:
        # sqrt-remat over layer blocks (§Perf H-gatedgcn): only block
        # boundaries are saved — at ogb_products scale each per-layer
        # (h, e) save costs ~2.9 GiB (e: [124M, d]); 16 saves -> 4.
        nb = cfg.n_layers // block
        lp_blocks = jax.tree.map(
            lambda a: a.reshape((nb, block) + a.shape[1:]), lp_stack)
        e0 = e if cfg.kind == "gatedgcn" else jnp.zeros((1, 1), dt)

        def block_body(carry, lp_blk):
            h, e = carry
            for i in range(block):
                lp = jax.tree.map(lambda a: a[i], lp_blk)
                h, e = apply_layer(h, e, lp)
            return (h, e), None

        (h, e), _ = jax.lax.scan(jax.checkpoint(block_body), (h, e0),
                                 lp_blocks)
    else:
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], lp_stack)
            h, e = apply_layer(h, e, lp)
    if cfg.graph_level:
        g = jax.ops.segment_sum(h, batch["graph_id"],
                                num_segments=n_graphs)
        return g @ params["head_w"].astype(dt) + params["head_b"].astype(dt)
    return h @ params["head_w"].astype(dt) + params["head_b"].astype(dt)


def loss_fn(params, cfg: GNNConfig, batch, n_graphs: int | None = None):
    logits = forward(params, cfg, batch, n_graphs=n_graphs)
    return cross_entropy_loss(logits, batch["labels"])

"""Decoder-only LM (dense + MoE): LLaMA/Qwen/DBRX-family architectures.

Design notes
  - Layers are *stacked* (leading n_layers axis) and executed with lax.scan:
    keeps HLO size O(1) in depth (critical for 40-cell dry-run compile times)
    and gives remat a natural per-layer boundary.
  - Params are stored fp32 (master) and cast to cfg.compute_dtype inside the
    forward; optimizer states are fp32 — MaxText-style mixed precision.
  - Every param is declared once in `param_defs` with its shape AND its
    PartitionSpec, so init / abstract (dry-run) / shardings can never drift.
  - GQA TP: q heads shard over "model" when divisible; otherwise the
    row-parallel fallback (d_model contracted over "model") keeps the mesh
    fully used except attention einsums (documented; see qwen2.5-14b).
    KV projections replicate over "model" (tp > kv_heads duplication).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .attention import gqa_attention
from .common import apply_rope, cross_entropy_loss, rms_norm, rope_angles, trunc_normal
from .moe import MoEConfig, moe_apply


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 128
    qkv_bias: bool = False
    rope_theta: float = 1e6
    moe: Optional[MoEConfig] = None
    window: Optional[int] = None          # sliding-window attention (opt-in)
    compute_dtype: str = "bfloat16"
    remat: str = "full"                   # none | full
    # tensor-parallel plan, resolved against the mesh at lowering time
    tp_size: int = 16

    @property
    def heads_shardable(self) -> bool:
        return self.n_heads % self.tp_size == 0

    def param_count(self) -> int:
        d, L = self.d_model, self.n_layers
        qkv = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
        o = self.n_heads * self.d_head * d
        if self.moe:
            m = self.moe
            ffn = 3 * d * m.d_ff_expert * m.num_experts
            if m.num_shared:
                ffn += 3 * d * m.d_ff_expert * m.num_shared
                if m.shared_gate:
                    ffn += d
            ffn += d * m.padded_experts  # router
        else:
            ffn = 3 * d * self.d_ff
        return L * (qkv + o + ffn + 2 * d) + 2 * self.vocab * d + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if not self.moe:
            return self.param_count()
        d, L, m = self.d_model, self.n_layers, self.moe
        qkv = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
        o = self.n_heads * self.d_head * d
        ffn = 3 * d * m.d_ff_expert * (m.top_k + m.num_shared)
        ffn += d * m.padded_experts
        return L * (qkv + o + ffn + 2 * d) + 2 * self.vocab * d + d


# --------------------------------------------------------------- param defs
def param_defs(cfg: LMConfig) -> dict:
    """{path: (shape, PartitionSpec)} — single source of truth."""
    L, d, V = cfg.n_layers, cfg.d_model, cfg.vocab
    hq = cfg.n_heads * cfg.d_head
    hkv = cfg.n_kv_heads * cfg.d_head
    col = cfg.heads_shardable  # column-parallel attention?
    defs = {
        "embed": ((V, d), P("model", "data")),
        "final_norm": ((d,), P(None)),
        "lm_head": ((d, V), P("data", "model")),
        "layers.ln1": ((L, d), P(None, None)),
        "layers.ln2": ((L, d), P(None, None)),
        # heads shardable -> Megatron column/row parallel attention.
        # Otherwise (e.g. 40 heads on a 16-way axis) the CONTEXT-PARALLEL
        # plan: attention weights are FSDP-only and the sequence axis of
        # the activations shards over "model" (set via act_spec) — K/V are
        # all-gathered per layer (small: Hkv*Dh per token), scores stay
        # q-block local. §Perf H-qwen25.
        "layers.wq": ((L, d, hq),
                      P(None, "data", "model") if col else
                      P(None, "data", None)),
        "layers.wk": ((L, d, hkv), P(None, "data", None)),
        "layers.wv": ((L, d, hkv), P(None, "data", None)),
        "layers.wo": ((L, hq, d),
                      P(None, "model", "data") if col else
                      P(None, None, "data")),
    }
    if cfg.qkv_bias:
        defs["layers.bq"] = ((L, hq), P(None, "model") if col
                             else P(None, None))
        defs["layers.bk"] = ((L, hkv), P(None, None))
        defs["layers.bv"] = ((L, hkv), P(None, None))
    if cfg.moe:
        m = cfg.moe
        E, F = m.padded_experts, m.d_ff_expert
        defs.update({
            "layers.router": ((L, d, E), P(None, "data", None)),
            "layers.w_gate": ((L, E, d, F), P(None, "model", "data", None)),
            "layers.w_up": ((L, E, d, F), P(None, "model", "data", None)),
            "layers.w_down": ((L, E, F, d), P(None, "model", None, "data")),
        })
        if m.num_shared:
            Fs = F * m.num_shared
            defs.update({
                "layers.shared_gate_w": ((L, d, Fs), P(None, "data", "model")),
                "layers.shared_up": ((L, d, Fs), P(None, "data", "model")),
                "layers.shared_down": ((L, Fs, d), P(None, "model", "data")),
            })
            if m.shared_gate:
                defs["layers.shared_out_gate"] = ((L, d, 1),
                                                  P(None, "data", None))
    else:
        defs.update({
            "layers.w_gate": ((L, d, cfg.d_ff), P(None, "data", "model")),
            "layers.w_up": ((L, d, cfg.d_ff), P(None, "data", "model")),
            "layers.w_down": ((L, cfg.d_ff, d), P(None, "model", "data")),
        })
    return defs


def _nest(flat: dict) -> dict:
    out: dict = {}
    for path, v in flat.items():
        parts = path.split(".")
        node = out
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return out


def init_params(cfg: LMConfig, key) -> dict:
    defs = param_defs(cfg)
    keys = jax.random.split(key, len(defs))
    flat = {}
    for (path, (shape, _)), k in zip(sorted(defs.items()), keys):
        if path.endswith(("ln1", "ln2", "final_norm")):
            flat[path] = jnp.ones(shape, jnp.float32)
        else:
            flat[path] = trunc_normal(k, shape, scale=1.0)
    return _nest(flat)


def abstract_params(cfg: LMConfig) -> dict:
    return _nest({p: jax.ShapeDtypeStruct(s, jnp.float32)
                  for p, (s, _) in param_defs(cfg).items()})


def param_shardings(cfg: LMConfig) -> dict:
    return _nest({p: spec for p, (s, spec) in param_defs(cfg).items()})


# ------------------------------------------------------------------ forward
def _layer(cfg: LMConfig, x, lp, sin, cos, cache=None, pos=None,
           kv_valid_len=None):
    """One decoder layer. x: [B, T, D]. cache: (k, v) [B, S, Hkv, Dh]."""
    B, T, d = x.shape
    dt = x.dtype
    h = rms_norm(x, lp["ln1"].astype(dt))
    q = h @ lp["wq"].astype(dt)
    k = h @ lp["wk"].astype(dt)
    v = h @ lp["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(dt)
        k = k + lp["bk"].astype(dt)
        v = v + lp["bv"].astype(dt)
    q = q.reshape(B, T, cfg.n_heads, cfg.d_head)
    k = k.reshape(B, T, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(B, T, cfg.n_kv_heads, cfg.d_head)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    new_cache = None
    if cache is not None:
        ck, cv = cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, pos, 0, 0))
        new_cache = (ck, cv)
        attn = gqa_attention(q, ck, cv, causal=False, q_offset=pos,
                             kv_valid_len=kv_valid_len, window=cfg.window)
    else:
        new_cache = (k, v)  # exposed for prefill cache collection
        attn = gqa_attention(q, k, v, causal=True, window=cfg.window)
    x = x + attn.reshape(B, T, -1) @ lp["wo"].astype(dt)

    h = rms_norm(x, lp["ln2"].astype(dt))
    if cfg.moe:
        wp = {k2: lp[k2] for k2 in
              ("router", "w_gate", "w_up", "w_down")}
        for k2 in ("shared_gate_w", "shared_up", "shared_down",
                   "shared_out_gate"):
            if k2 in lp:
                wp[k2] = lp[k2]
        y, aux = moe_apply(h.reshape(B * T, d), wp, cfg.moe)
        y = y.reshape(B, T, d)
    else:
        g = jax.nn.silu(h @ lp["w_gate"].astype(dt))
        y = (g * (h @ lp["w_up"].astype(dt))) @ lp["w_down"].astype(dt)
        aux = jnp.float32(0.0)
    return x + y, new_cache, aux


def forward(params, cfg: LMConfig, tokens, act_spec=None,
            collect_kv: bool = False, head_act_spec=None):
    """tokens: [B, T] -> logits [B, T, vocab] (compute_dtype activations).

    act_spec: optional PartitionSpec pinned onto the residual stream after
    every layer (e.g. P(("data",), None, "model")) — the Megatron
    sequence-parallel analogue: per-layer all-gather/reduce-scatter instead
    of a full replicated [B, T, D] carry in HBM."""
    dt = jnp.dtype(cfg.compute_dtype)
    B, T = tokens.shape
    x = params["embed"][tokens].astype(dt)
    sin, cos = rope_angles(jnp.arange(T), cfg.d_head, cfg.rope_theta, dt)
    constrain = (lambda z: jax.lax.with_sharding_constraint(z, act_spec)) \
        if act_spec is not None else (lambda z: z)
    x = constrain(x)
    # cast the stacked layer weights to compute dtype BEFORE the scan: the
    # per-layer FSDP all-gathers then move bf16, not fp32 master copies
    # (2x collective bytes; §Perf H-lm-1)
    layers_c = jax.tree.map(lambda a: a.astype(dt)
                            if a.dtype == jnp.float32 else a,
                            params["layers"])

    def body(carry, lp):
        x, aux = carry
        y, kv, a = _layer(cfg, x, lp, sin, cos)
        ys = kv if collect_kv else None
        return (constrain(y), aux + a), ys

    body_fn = body
    if cfg.remat == "full" and not collect_kv:
        body_fn = jax.checkpoint(body)
    (x, aux), kvs = jax.lax.scan(body_fn, (x, jnp.float32(0.0)),
                                 layers_c)
    x = rms_norm(x, params["final_norm"].astype(dt))
    if head_act_spec is not None:
        # context-parallel plans re-shard [B, S, D] from seq-sharded to
        # d-sharded here so the vocab-sharded head contracts locally
        x = jax.lax.with_sharding_constraint(x, head_act_spec)
    logits = x @ params["lm_head"].astype(dt)
    if collect_kv:
        return logits, aux / cfg.n_layers, kvs
    return logits, aux / cfg.n_layers


def loss_fn(params, cfg: LMConfig, batch, act_spec=None,
            head_act_spec=None):
    logits, aux = forward(params, cfg, batch["tokens"], act_spec=act_spec,
                          head_act_spec=head_act_spec)
    return cross_entropy_loss(logits, batch["labels"]) + aux


def prefill_step(params, cfg: LMConfig, tokens, act_spec=None):
    """Inference prefill: run the prompt, return (next_token, kv cache).

    The per-layer K/V tensors are collected as scan outputs -> cache layout
    [L, B, S, Hkv, Dh], identical to decode_step's expectation."""
    logits, _, (ks, vs) = forward(params, cfg, tokens, act_spec=act_spec,
                                  collect_kv=True)
    nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(tokens.dtype)
    return nxt, {"k": ks.astype(jnp.bfloat16), "v": vs.astype(jnp.bfloat16)}


# ------------------------------------------------------------------- decode
def init_cache_abstract(cfg: LMConfig, batch: int, max_len: int,
                        dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(params, cfg: LMConfig, cache, tokens, pos):
    """One serving step: tokens [B] at position `pos` (scalar int32).

    Returns (next_tokens [B], logits [B, vocab], updated cache)."""
    dt = jnp.dtype(cfg.compute_dtype)
    B = tokens.shape[0]
    x = params["embed"][tokens].astype(dt)[:, None, :]       # [B, 1, D]
    sin, cos = rope_angles(pos[None], cfg.d_head, cfg.rope_theta, dt)
    sin, cos = sin[None], cos[None]                          # [1, 1, Dh/2]

    def body(carry, xs):
        x, aux = carry
        lp, ck, cv = xs
        y, new_cache, a = _layer(cfg, x, lp, sin, cos, cache=(ck, cv),
                                 pos=pos, kv_valid_len=pos + 1)
        return (y, aux + a), new_cache

    (x, _), new_kv = jax.lax.scan(
        body, (x, jnp.float32(0.0)),
        (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"].astype(dt))
    logits = (x @ params["lm_head"].astype(dt))[:, 0, :]
    nxt = jnp.argmax(logits, axis=-1).astype(tokens.dtype)
    return nxt, logits, {"k": new_kv[0], "v": new_kv[1]}

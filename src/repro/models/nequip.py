"""NequIP-style E(3)-equivariant interatomic potential (arXiv:2101.03164),
l_max = 2, built from scratch (no e3nn):

  - real spherical harmonics l in {0,1,2} as explicit polynomials;
  - coupling tensors = *Gaunt coefficients* computed exactly with
    Gauss-Legendre x uniform-phi quadrature (the integrand is a polynomial of
    degree <= 6, so the quadrature is exact to float precision). Gaunt
    coefficients are proportional to real Clebsch-Gordan coefficients per
    (l1, l2, l3), hence an equally valid invariant coupling — equivariance is
    what the property tests assert (energy invariance under random rotations).
  - interaction layer: radial-Bessel-weighted tensor-product messages
    (h_j^{l1} (x) Y^{l2}(r_hat))_{l3}, segment-sum aggregation, per-l
    self-interaction, scalar-gated nonlinearity;
  - readout: per-atom scalar energy -> graph sum; forces available via
    jax.grad wrt positions.

Hardware note: the tensor-product contraction is einsum over tiny (2l+1)
dims fused with the [E, C] channel axis — on TPU this maps to VPU work with
MXU for the channel mixes; the edge gather/scatter shares the GNN segment
backend. Non-molecular shapes (citation graphs) carry synthetic 3D
coordinates — E(3) geometry is undefined there; see DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import trunc_normal

LS = (0, 1, 2)
DIM = {0: 1, 1: 3, 2: 5}


# ----------------------------------------------------- real SH + Gaunt setup
def _real_sh_np(vec: np.ndarray) -> dict[int, np.ndarray]:
    """Orthonormal real spherical harmonics on unit vectors [*, 3]."""
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    c0 = 0.5 / np.sqrt(np.pi)
    c1 = np.sqrt(3.0 / (4 * np.pi))
    out = {
        0: np.stack([np.full_like(x, c0)], -1),
        1: c1 * np.stack([x, y, z], -1),
        2: np.stack([
            0.5 * np.sqrt(15 / np.pi) * x * y,
            0.5 * np.sqrt(15 / np.pi) * y * z,
            0.25 * np.sqrt(5 / np.pi) * (3 * z * z - 1.0),
            0.5 * np.sqrt(15 / np.pi) * x * z,
            0.25 * np.sqrt(15 / np.pi) * (x * x - y * y),
        ], -1),
    }
    return out


@lru_cache(maxsize=None)
def _gaunt_tables() -> dict[tuple[int, int, int], np.ndarray]:
    """G[l1,l2,l3][m1,m2,m3] = Int Y_l1m1 Y_l2m2 Y_l3m3 dOmega, exactly."""
    nt, nphi = 16, 32  # exact for polynomial degree <= 2*16-1 in cos(theta)
    ct, wt = np.polynomial.legendre.leggauss(nt)
    phi = (np.arange(nphi) + 0.5) * (2 * np.pi / nphi)
    wphi = 2 * np.pi / nphi
    st = np.sqrt(1 - ct ** 2)
    grid = np.stack([
        (st[:, None] * np.cos(phi)[None, :]).ravel(),
        (st[:, None] * np.sin(phi)[None, :]).ravel(),
        np.broadcast_to(ct[:, None], (nt, nphi)).ravel(),
    ], -1)
    w = (wt[:, None] * wphi * np.ones(nphi)[None, :]).ravel()
    sh = _real_sh_np(grid)
    tables = {}
    for l1 in LS:
        for l2 in LS:
            for l3 in LS:
                g = np.einsum("g,ga,gb,gc->abc", w, sh[l1], sh[l2], sh[l3])
                g[np.abs(g) < 1e-12] = 0.0
                if np.abs(g).max() > 1e-12:
                    tables[(l1, l2, l3)] = g.astype(np.float32)
    return tables


def sph_harm(vec):
    """jnp real SH of unit vectors [E, 3] -> {l: [E, 2l+1]}."""
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    c0 = 0.5 / np.sqrt(np.pi)
    c1 = float(np.sqrt(3.0 / (4 * np.pi)))
    return {
        0: jnp.stack([jnp.full_like(x, c0)], -1),
        1: c1 * jnp.stack([x, y, z], -1),
        2: jnp.stack([
            0.5 * np.sqrt(15 / np.pi) * x * y,
            0.5 * np.sqrt(15 / np.pi) * y * z,
            0.25 * np.sqrt(5 / np.pi) * (3 * z * z - 1.0),
            0.5 * np.sqrt(15 / np.pi) * x * z,
            0.25 * np.sqrt(15 / np.pi) * (x * x - y * y),
        ], -1),
    }


def bessel_basis(r, n_rbf: int, cutoff: float):
    """Bessel radial basis with smooth polynomial cutoff envelope."""
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    rs = jnp.maximum(r, 1e-6)[:, None]
    b = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * np.pi * rs / cutoff) / rs
    u = r / cutoff
    env = 1 - 10 * u ** 3 + 15 * u ** 4 - 6 * u ** 5   # p=3 smooth cutoff
    env = jnp.where(u < 1.0, env, 0.0)
    return b * env[:, None]


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str
    n_layers: int = 5
    channels: int = 32          # multiplicity per l
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    d_feat: int = 16            # species / input feature width
    radial_hidden: int = 64


# --------------------------------------------------------------- param defs
def _paths():
    """All (l1, l2, l3) tensor-product paths with nonzero Gaunt coupling."""
    return sorted(_gaunt_tables().keys())


def param_defs(cfg: NequIPConfig) -> dict:
    L, C = cfg.n_layers, cfg.channels
    defs = {
        "embed_w": ((cfg.d_feat, C), P(None, None)),
        "readout_w1": ((C, C), P(None, None)),
        "readout_b1": ((C,), P(None)),
        "readout_w2": ((C, 1), P(None, None)),
    }
    n_paths = len(_paths())
    defs["layers.radial_w1"] = ((L, cfg.n_rbf, cfg.radial_hidden),
                                P(None, None, None))
    defs["layers.radial_b1"] = ((L, cfg.radial_hidden), P(None, None))
    defs["layers.radial_w2"] = ((L, cfg.radial_hidden, n_paths * C),
                                P(None, None, None))
    for l in LS:
        defs[f"layers.self_w{l}"] = ((L, C, C), P(None, None, None))
        if l > 0:
            defs[f"layers.gate_w{l}"] = ((L, C, C), P(None, None, None))
    return defs


def _nest(flat: dict) -> dict:
    out: dict = {}
    for path, v in flat.items():
        parts = path.split(".")
        node = out
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return out


def init_params(cfg: NequIPConfig, key) -> dict:
    defs = param_defs(cfg)
    keys = jax.random.split(key, len(defs))
    flat = {}
    for (path, (shape, _)), k in zip(sorted(defs.items()), keys):
        flat[path] = (jnp.zeros(shape) if path.endswith("_b1")
                      else trunc_normal(k, shape))
    return _nest(flat)


def abstract_params(cfg: NequIPConfig) -> dict:
    return _nest({p: jax.ShapeDtypeStruct(s, jnp.float32)
                  for p, (s, _) in param_defs(cfg).items()})


def param_shardings(cfg: NequIPConfig) -> dict:
    return _nest({p: spec for p, (s, spec) in param_defs(cfg).items()})


# ------------------------------------------------------------------ forward
def energy_fn(params, cfg: NequIPConfig, batch, n_graphs: int | None = None,
              edge_chunk: int | None = None):
    """batch: feat [N, d_feat], pos [N, 3], edges_src/dst [E], graph_id [N].
    Returns per-graph energies [G].

    edge_chunk: process edges in scan chunks of this size (E % chunk == 0),
    so the [E, C, 2l+1] message tensors never materialize at full E —
    required for the ogb_products cell (124M directed edges)."""
    src, dst = batch["edges_src"], batch["edges_dst"]
    N = batch["feat"].shape[0]
    C = cfg.channels
    pos = batch["pos"]
    gaunt = _gaunt_tables()  # numpy constants: jnp constants traced
    # into a custom_vjp body leak tracers under sharded lowering
    paths = _paths()

    # node irreps: {l: [N, C, 2l+1]}
    h = {0: (batch["feat"] @ params["embed_w"])[:, :, None],
         1: jnp.zeros((N, C, 3)),
         2: jnp.zeros((N, C, 5))}

    def edge_messages(h, lp, src_c, dst_c, pos_):
        """Messages + per-l segment aggregation for one edge chunk."""
        rel = pos_[src_c] - pos_[dst_c]
        r = jnp.linalg.norm(rel + 1e-12, axis=-1)
        unit = rel / jnp.maximum(r, 1e-6)[:, None]
        Y = sph_harm(unit)
        rbf = bessel_basis(r, cfg.n_rbf, cfg.cutoff)
        rad = jax.nn.silu(rbf @ lp["radial_w1"] + lp["radial_b1"])
        rad = rad @ lp["radial_w2"]                            # [e, P*C]
        # mask degenerate edges (r ~ 0, e.g. self loops): Y_l>=2 of the zero
        # vector is garbage that does not rotate -> breaks equivariance
        rad = rad * (r > 1e-6).astype(rad.dtype)[:, None]
        rad = rad.reshape(-1, len(paths), C)
        msg = {l: 0.0 for l in LS}
        for pi, (l1, l2, l3) in enumerate(paths):
            hj = h[l1][src_c]                                  # [e, C, 2l1+1]
            w = rad[:, pi, :]                                  # [e, C]
            m = jnp.einsum("ecm,en,mnp->ecp", hj, Y[l2], gaunt[(l1, l2, l3)])
            msg[l3] = msg[l3] + m * w[:, :, None]
        return {l: jax.ops.segment_sum(msg[l], dst_c, num_segments=N)
                for l in LS}

    @jax.custom_vjp
    def agg_chunked(h, lp, pos_, src2, dst2):
        """Linear-in-chunks aggregation with O(N + chunk) memory: the
        forward scan saves NOTHING per chunk (plain lax.scan under
        custom_vjp), and the backward recomputes each chunk's vjp from just
        (h, lp, pos). NOTE: no cotangent flows to pos through this path
        (energy-only training; the force objective uses the unchunked
        path — asserted in loss_fn)."""
        def body(acc, xs):
            a = edge_messages(h, lp, xs[0], xs[1], pos_)
            return {l: acc[l] + a[l] for l in LS}, None
        zero = {l: jnp.zeros((N, C, DIM[l])) for l in LS}
        agg, _ = jax.lax.scan(body, zero, (src2, dst2))
        return agg

    def agg_fwd(h, lp, pos_, src2, dst2):
        return agg_chunked(h, lp, pos_, src2, dst2), (h, lp, pos_, src2,
                                                      dst2)

    def agg_bwd(res, dagg):
        h, lp, pos_, src2, dst2 = res

        def body(acc, xs):
            dh_acc, dlp_acc = acc
            f = lambda hh, ll: edge_messages(hh, ll, xs[0], xs[1], pos_)
            _, vjp = jax.vjp(f, h, lp)
            dh_c, dlp_c = vjp(dagg)
            return (jax.tree.map(jnp.add, dh_acc, dh_c),
                    jax.tree.map(jnp.add, dlp_acc, dlp_c)), None

        zero = (jax.tree.map(jnp.zeros_like, h),
                jax.tree.map(jnp.zeros_like, lp))
        (dh, dlp), _ = jax.lax.scan(body, zero, (src2, dst2))
        return (dh, dlp, jnp.zeros_like(pos_),
                np.zeros(src2.shape, jax.dtypes.float0),
                np.zeros(dst2.shape, jax.dtypes.float0))

    agg_chunked.defvjp(agg_fwd, agg_bwd)

    def layer(h, lp, src_, dst_, pos_):
        E = src_.shape[0]
        if edge_chunk and E > edge_chunk and E % edge_chunk == 0:
            nc = E // edge_chunk
            agg = agg_chunked(h, lp, pos_, src_.reshape(nc, edge_chunk),
                              dst_.reshape(nc, edge_chunk))
        else:
            agg = edge_messages(h, lp, src_, dst_, pos_)
        # self-interaction (channel mix) + residual
        new_h = {}
        for l in LS:
            z = jnp.einsum("ncm,cd->ndm", agg[l], lp[f"self_w{l}"])
            new_h[l] = h[l] + z
        # gated nonlinearity: scalars -> silu; l>0 gated by scalar channels
        s = new_h[0][:, :, 0]
        out_h = {0: jax.nn.silu(s)[:, :, None]}
        for l in (1, 2):
            gate = jax.nn.sigmoid(s @ lp[f"gate_w{l}"])        # [N, C]
            out_h[l] = new_h[l] * gate[:, :, None]
        return out_h

    # scan over stacked layers (single while loop -> buffers reused across
    # layers) + per-layer remat: at ogb_products scale each saved
    # [N, C, 2l+1] costs 2.8 GiB. Loop-invariant arrays (edges, positions)
    # ride in the carry: jax.checkpoint of a body that CLOSES OVER tracers
    # breaks under jit when the body contains a custom_vjp call.
    big = batch["feat"].shape[0] > 500_000

    def scan_body(carry, lp):
        h, src_, dst_, pos_ = carry
        h2 = layer(h, lp, src_, dst_, pos_)
        return (h2, src_, dst_, pos_), None

    body_fn = jax.checkpoint(scan_body) if big else scan_body
    (h, _, _, _), _ = jax.lax.scan(body_fn, (h, src, dst, pos),
                                   params["layers"])

    e_atom = jax.nn.silu(h[0][:, :, 0] @ params["readout_w1"]
                         + params["readout_b1"]) @ params["readout_w2"]
    ng = n_graphs if n_graphs is not None else 1
    gid = batch.get("graph_id")
    if gid is None:
        gid = jnp.zeros(N, jnp.int32)
    return jax.ops.segment_sum(e_atom[:, 0], gid, num_segments=ng)


def loss_fn(params, cfg: NequIPConfig, batch, n_graphs: int | None = None,
            force_weight: float = 0.1):
    """Energy MSE + force MSE (forces = -dE/dpos), the NequIP objective."""
    def etot(pos):
        b = dict(batch)
        b["pos"] = pos
        return energy_fn(params, cfg, b, n_graphs=n_graphs).sum()

    e = energy_fn(params, cfg, batch, n_graphs=n_graphs)
    f = -jax.grad(etot)(batch["pos"])
    le = jnp.mean((e - batch["energy"]) ** 2)
    lf = jnp.mean((f - batch["forces"]) ** 2)
    return le + force_weight * lf

"""Shared model building blocks: init, norms, RoPE, MLPs.

Convention: models are pure-function + pytree-of-arrays (no flax). Each
model module exposes
    init_params(cfg, key)        -> params pytree (fp32 master copies)
    param_shardings(cfg, axes)   -> matching pytree of PartitionSpec
    abstract_params(cfg)         -> matching pytree of ShapeDtypeStruct
so the multi-pod dry-run can lower without allocating anything.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def trunc_normal(key, shape, scale=1.0, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 1 else 1
    std = scale / np.sqrt(max(fan_in, 1))
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def split_keys(key, names):
    ks = jax.random.split(key, len(names))
    return dict(zip(names, ks))


def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def rope_angles(positions, d_head, theta=10000.0, dtype=jnp.float32):
    """positions: [...,] int -> (sin, cos) of shape [..., d_head//2]."""
    half = d_head // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang).astype(dtype), jnp.cos(ang).astype(dtype)


def apply_rope(x, sin, cos):
    """x: [..., T, H, Dh]; sin/cos: [..., T, Dh//2] broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[..., None, :]
    cos = cos[..., None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1)


def swiglu(x, w_gate, w_up, w_down):
    """LLaMA-style gated MLP. x: [..., D]."""
    g = jax.nn.silu(x @ w_gate)
    return (g * (x @ w_up)) @ w_down


def mlp(params_prefix, x, ws, act=jax.nn.relu):
    """Plain MLP given list of (w, b)."""
    del params_prefix
    for i, (w, b) in enumerate(ws):
        x = x @ w + b
        if i + 1 < len(ws):
            act_x = act(x)
            x = act_x
    return x


def cross_entropy_loss(logits, labels, z_loss=0.0):
    """Token-mean CE; labels < 0 are masked.

    Sharding-friendly: the label log-prob is an iota-mask reduction (not
    take_along_axis), so a vocab-sharded logits tensor reduces locally per
    shard + one tiny cross-shard sum instead of an all-gather of [B, S, V].
    Reductions run in fp32 off bf16 logits (no fp32 [B, S, V] temp)."""
    mask = (labels >= 0).astype(jnp.float32)
    labels_c = jnp.maximum(labels, 0)
    V = logits.shape[-1]
    m = jax.lax.stop_gradient(logits.max(axis=-1)).astype(jnp.float32)
    ex = jnp.exp(logits.astype(jnp.float32) - m[..., None])
    lse = m + jnp.log(ex.sum(axis=-1))
    onehot = (jnp.arange(V)[None, None, :] == labels_c[..., None])
    ll = jnp.where(onehot, logits.astype(jnp.float32), 0.0).sum(-1)
    loss = (lse - ll) * mask
    if z_loss:
        loss = loss + z_loss * (lse * mask) ** 2
    return loss.sum() / jnp.maximum(mask.sum(), 1.0)


def count_params(tree) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))

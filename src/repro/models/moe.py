"""Mixture-of-Experts FFN: top-k routing with capacity-based dispatch
(GShard/Switch style), SwiGLU experts, optional DeepSeek/Qwen-style shared
experts, and the standard load-balance auxiliary loss.

Dispatch is permutation-free: per routing choice a one-hot cumsum assigns a
slot in the per-expert capacity buffer; overflow tokens are dropped (train)
— FLOPs therefore scale with top_k (not num_experts), which keeps the
roofline's MODEL_FLOPS/HLO_FLOPS ratio honest. Expert axis shards over
"model" (EP); under SPMD the scatter/gather becomes the canonical all-to-all
pair.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    shared_gate: bool = False
    capacity_factor: float = 1.25
    pad_experts_to: int | None = None  # EP divisibility padding
    aux_loss_coef: float = 0.01
    # scan the token stream through the experts in this many chunks: the
    # [E, cap, D] dispatch buffers shrink by the same factor (memory), at
    # identical FLOPs. Applied only when tokens/chunk stays >= 8192.
    token_chunks: int = 1
    # per-shard capacity dispatch (§Perf H-moe): slots are assigned by a
    # cumsum LOCAL to each data shard and the buffer grows a leading
    # data-shard dim, so every scatter write is shard-local — the SPMD
    # partitioner then avoids all-reducing the full [E, cap, D] buffer
    # across the data axis. dispatch_shards must divide the token count;
    # dispatch_axes names the mesh axes of the token shards.
    dispatch_shards: int = 1
    dispatch_axes: tuple = ("data",)
    ep_axis: str = "model"

    @property
    def padded_experts(self) -> int:
        return self.pad_experts_to or self.num_experts


def _maybe_constrain(x, spec):
    """with_sharding_constraint that no-ops outside a mesh context (tests
    and single-device smoke runs)."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (RuntimeError, ValueError):
        return x


def moe_ffn_replicated_ep(x, wp, cfg: MoEConfig):
    """Replicated-token expert parallelism via shard_map (§Perf H-moe-3).

    Observation: under DP x TP the token activations are *replicated over
    the model axis*, so every EP shard already holds every token. Each
    shard therefore (1) routes locally, (2) selects the tokens belonging to
    its local experts into a tiny local capacity buffer, (3) runs its
    experts, and (4) contributes a partial output; one psum over the model
    axis combines them. Dispatch traffic collapses from all-reducing
    [E, cap, D] buffers (7.3 TiB/step/chip measured on dbrx train_4k) to a
    single [N_local, D] bf16 all-reduce per call.

    Falls back to moe_ffn when no mesh is set (single-device smoke)."""
    mesh = jax.sharding.get_abstract_mesh()
    ep_ax = cfg.ep_axis
    if mesh is None or ep_ax not in getattr(mesh, "shape", {}):
        return moe_ffn(x, wp, cfg)
    from jax.sharding import PartitionSpec as P
    MP = mesh.shape[ep_ax]
    da = tuple(a for a in cfg.dispatch_axes if a in mesh.shape)
    E, Ep, K = cfg.num_experts, cfg.padded_experts, cfg.top_k
    if Ep % MP != 0:
        return moe_ffn(x, wp, cfg)
    EL = Ep // MP
    N, D = x.shape
    DA = 1
    for a in da:
        DA *= mesh.shape[a]
    if N % DA != 0:
        return moe_ffn(x, wp, cfg)
    NL = N // DA
    # inference-safe floor of 8; an expert can hold at most NL local tokens
    capL = min(NL, max(int(NL * K / Ep * cfg.capacity_factor), 8))

    def body(x_l, router, wg, wu, wd):
        m = jax.lax.axis_index(ep_ax)
        logits = (x_l.astype(jnp.float32) @ router.astype(jnp.float32))
        if Ep != E:
            logits = logits.at[:, E:].set(-1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, K)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

        e_lo = m * EL
        buf = jnp.zeros((EL, capL, D), dtype=x_l.dtype)
        slots, keeps, locals_ = [], [], []
        prev = jnp.zeros((Ep,), jnp.int32)
        for j in range(K):
            e = idx[:, j]
            oh = jax.nn.one_hot(e, Ep, dtype=jnp.int32)
            pos = jnp.cumsum(oh, axis=0) * oh
            slot = pos.sum(-1) - 1 + prev[e]
            prev = prev + oh.sum(0)
            is_local = (e >= e_lo) & (e < e_lo + EL)
            keep = (slot < capL) & is_local
            el = jnp.where(keep, e - e_lo, EL)      # EL -> dropped
            buf = buf.at[el, jnp.where(keep, slot, capL)].add(
                x_l, mode="drop")
            slots.append(slot)
            keeps.append(keep)
            locals_.append(el)
        g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(x_l.dtype),
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(x_l.dtype),
                       preferred_element_type=jnp.float32)
        hh = (jax.nn.silu(g) * u).astype(x_l.dtype)
        yb = jnp.einsum("ecf,efd->ecd", hh, wd.astype(x_l.dtype),
                        preferred_element_type=jnp.float32).astype(x_l.dtype)
        y = jnp.zeros_like(x_l)
        for j in range(K):
            el, slot, keep = locals_[j], slots[j], keeps[j]
            ytok = yb[jnp.clip(el, 0, EL - 1), jnp.clip(slot, 0, capL - 1)]
            y = y + jnp.where(keep[:, None], ytok, 0) * \
                gates[:, j:j + 1].astype(x_l.dtype)
        y = jax.lax.psum(y, ep_ax)                  # combine across experts
        me = probs[:, :E].mean(0)
        fe = jax.nn.one_hot(idx[:, 0], Ep, dtype=jnp.float32)[:, :E].mean(0)
        aux = cfg.aux_loss_coef * E * jnp.sum(me * fe)
        if da:
            aux = jax.lax.pmean(aux, da if len(da) > 1 else da[0])
        return y, aux

    xspec = P(da if len(da) > 1 else (da[0] if da else None), None)
    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(xspec, P(None, None), P(ep_ax, None, None),
                  P(ep_ax, None, None), P(ep_ax, None, None)),
        out_specs=(xspec, P()), check_vma=False)
    y, aux = fn(x, wp["router"], wp["w_gate"], wp["w_up"], wp["w_down"])

    if cfg.num_shared:
        gs = jax.nn.silu(x @ wp["shared_gate_w"].astype(x.dtype))
        us = x @ wp["shared_up"].astype(x.dtype)
        ys = (gs * us) @ wp["shared_down"].astype(x.dtype)
        if cfg.shared_gate:
            sg = jax.nn.sigmoid(x.astype(jnp.float32) @
                                wp["shared_out_gate"].astype(jnp.float32))
            ys = ys * sg.astype(x.dtype)
        y = y + ys
    return y, aux


def moe_apply(x, wp, cfg: MoEConfig):
    """Dispatch to the best MoE implementation for the ambient mesh."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        mesh = None
    if mesh is not None and cfg.ep_axis in getattr(mesh, "shape", {}):
        return moe_ffn_replicated_ep(x, wp, cfg)
    return moe_ffn_chunked(x, wp, cfg)


def moe_ffn_chunked(x, wp, cfg: MoEConfig):
    """Token-chunked MoE: scan x through moe_ffn in cfg.token_chunks pieces
    so the dispatch buffers never hold the full token stream."""
    N = x.shape[0]
    nc = cfg.token_chunks
    if nc <= 1 or N < nc * 8192 or N % nc != 0:
        return moe_ffn(x, wp, cfg)

    def body(aux, xc):
        yc, a = moe_ffn(xc, wp, cfg)
        return aux + a, yc

    aux, ys = jax.lax.scan(jax.checkpoint(body), jnp.float32(0.0),
                           x.reshape(nc, N // nc, -1))
    return ys.reshape(N, -1), aux / nc


def moe_ffn(x, wp, cfg: MoEConfig):
    """x: [N, D] tokens; wp: dict with router/w_gate/w_up/w_down (+shared).

    Returns (y [N, D], aux_loss scalar). Expert weights are stored with the
    *padded* expert count; rows past num_experts get zero routing mass.
    """
    N, D = x.shape
    E, Ep, K = cfg.num_experts, cfg.padded_experts, cfg.top_k
    router_logits = (x.astype(jnp.float32) @
                     wp["router"].astype(jnp.float32))          # [N, Ep]
    if Ep != E:  # padding experts never win
        router_logits = router_logits.at[:, E:].set(-1e30)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, K)                         # [N, K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    SD = cfg.dispatch_shards if (cfg.dispatch_shards > 1
                                 and N % cfg.dispatch_shards == 0) else 1
    cap = max(int(N * K / Ep * cfg.capacity_factor), 4)
    capL = max(cap // SD, 4)
    NL = N // SD
    # shard-local dispatch: tokens reshape to [SD, NL, D] (dim 0 == the data
    # shards), slots come from a cumsum along dim 1 only, and the scatter /
    # gather are vmapped over dim 0 — a *batched* scatter whose batch dim is
    # sharded identically on operand and updates, so the partitioner keeps
    # every write local instead of all-reducing the full buffer (§Perf).
    xs = x.reshape(SD, NL, D)
    buf = jnp.zeros((SD, Ep, capL, D), dtype=x.dtype)
    slots, keeps = [], []
    prev_count = jnp.zeros((SD, Ep), jnp.int32)
    scatter_add = jax.vmap(
        lambda b, e_, sl, xv: b.at[e_, sl].add(xv, mode="drop"))
    for j in range(K):
        e = idx[:, j].reshape(SD, NL)                            # [SD, NL]
        oh = jax.nn.one_hot(e, Ep, dtype=jnp.int32)              # [SD,NL,Ep]
        pos = jnp.cumsum(oh, axis=1) * oh
        slot = pos.sum(-1) - 1 + jnp.take_along_axis(
            prev_count[:, None, :].repeat(NL, 1), e[..., None], -1)[..., 0]
        keep = slot < capL
        # overflow tokens index slot == capL -> dropped by mode="drop"
        buf = scatter_add(buf, e, jnp.where(keep, slot, capL), xs)
        prev_count = prev_count + oh.sum(1)
        slots.append(slot)
        keeps.append(keep)

    # expert computation: [Ep, SD*capL, D] x [Ep, D, F] (SwiGLU).
    # Constrain the einsum operands so the contraction over D runs locally:
    # expert weights are EP-sharded but REPLICATED over data here (one small
    # weight all-gather) and the capacity axis stays data-sharded — without
    # this, FSDP's D-sharded weights make XLA all-reduce the [E, cap, F]
    # fp32 activations every layer (measured 2.6 TiB/step/chip on dbrx).
    from jax.sharding import PartitionSpec as P
    ep = cfg.ep_axis
    da = cfg.dispatch_axes if SD > 1 else None
    buff = buf.transpose(1, 0, 2, 3).reshape(Ep, SD * capL, D)
    buff = _maybe_constrain(buff, P(ep, da, None))
    wg = _maybe_constrain(wp["w_gate"].astype(x.dtype), P(ep, None, None))
    wu = _maybe_constrain(wp["w_up"].astype(x.dtype), P(ep, None, None))
    wd = _maybe_constrain(wp["w_down"].astype(x.dtype), P(ep, None, None))
    g = jnp.einsum("ecd,edf->ecf", buff, wg,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", buff, wu,
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    yb = jnp.einsum("ecf,efd->ecd", h, wd,
                    preferred_element_type=jnp.float32).astype(x.dtype)
    yb = yb.reshape(Ep, SD, capL, D).transpose(1, 0, 2, 3)  # [SD,Ep,capL,D]

    gather = jax.vmap(lambda b, e_, sl: b[e_, sl])
    y = jnp.zeros_like(xs)
    gates_s = gates.reshape(SD, NL, K)
    for j in range(K):
        e, slot, keep = (idx[:, j].reshape(SD, NL), slots[j], keeps[j])
        ytok = gather(yb, e, jnp.clip(slot, 0, capL - 1))
        y = y + jnp.where(keep[..., None], ytok, 0) * \
            gates_s[..., j:j + 1].astype(x.dtype)
    y = y.reshape(N, D)

    # Switch-style load-balance aux loss over the real experts
    me = probs[:, :E].mean(0)
    onehot_top1 = jax.nn.one_hot(idx[:, 0], Ep, dtype=jnp.float32)[:, :E]
    fe = onehot_top1.mean(0)
    aux = cfg.aux_loss_coef * E * jnp.sum(me * fe)

    if cfg.num_shared:
        gs = jax.nn.silu(x @ wp["shared_gate_w"].astype(x.dtype))
        us = x @ wp["shared_up"].astype(x.dtype)
        ys = (gs * us) @ wp["shared_down"].astype(x.dtype)
        if cfg.shared_gate:
            sg = jax.nn.sigmoid(
                x.astype(jnp.float32) @ wp["shared_out_gate"].astype(
                    jnp.float32))
            ys = ys * sg.astype(x.dtype)
        y = y + ys
    return y, aux

"""Attention: GQA/MHA with RoPE, causal or decode masking, optional sliding
window. Pure-jnp reference path used by training, prefill and decode; the
Pallas flash kernel (kernels/flash_attn.py) is an optional drop-in for real
TPU runs (kernels never lower in the CPU dry-run)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gqa_attention(q, k, v, *, causal: bool = True, q_offset=0,
                  kv_valid_len=None, window: int | None = None,
                  q_chunk: int | None = 512):
    """q: [B, T, Hq, Dh]; k/v: [B, S, Hkv, Dh]; Hq % Hkv == 0.

    q_offset: absolute position of q[0] (decode: the cache write position).
    kv_valid_len: mask kv positions >= this (decode with preallocated cache).
    window: sliding-window size (attend to the last `window` positions).
    q_chunk: scan over query blocks so the [T, S] score matrix never
      materializes beyond one block (exact math — per-block full softmax;
      the XLA analogue of the flash-attention memory profile).
    """
    T = q.shape[1]
    if q_chunk is not None and T > q_chunk and T % q_chunk == 0:
        nb = T // q_chunk

        def blk(carry, qb_off):
            qb = jax.lax.dynamic_slice_in_dim(q, qb_off, q_chunk, axis=1)
            ob = _gqa_attention_dense(qb, k, v, causal=causal,
                                      q_offset=q_offset + qb_off,
                                      kv_valid_len=kv_valid_len,
                                      window=window)
            return carry, ob

        _, outs = jax.lax.scan(blk, None, q_chunk * jnp.arange(nb))
        # outs: [nb, B, q_chunk, Hq, Dh] -> [B, T, Hq, Dh]
        return jnp.moveaxis(outs, 0, 1).reshape(q.shape)
    return _gqa_attention_dense(q, k, v, causal=causal, q_offset=q_offset,
                                kv_valid_len=kv_valid_len, window=window)


def _gqa_attention_dense(q, k, v, *, causal: bool = True, q_offset=0,
                         kv_valid_len=None, window: int | None = None):
    B, T, Hq, Dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = Dh ** -0.5
    qf = (q * scale).astype(jnp.bfloat16).reshape(B, T, Hkv, G, Dh)
    logits = jnp.einsum("bthgd,bshd->bhgts", qf, k.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
    qpos = q_offset + jnp.arange(T)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= kpos > qpos - window
    if kv_valid_len is not None:
        mask &= kpos < kv_valid_len
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhgts,bshd->bthgd", p.astype(jnp.bfloat16),
                     v.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, T, Hq, Dh).astype(q.dtype)

"""gatedgcn [arXiv:2003.00982]: 16L d=70, gated aggregator with edge
features."""
from ..models.gnn import GNNConfig
from .gnn_common import GNN_SHAPES, make_gnn_cell

SHAPES = list(GNN_SHAPES)


def get_config() -> GNNConfig:
    return GNNConfig("gatedgcn", "gatedgcn", n_layers=16, d_hidden=70,
                     d_feat=16, n_classes=2, d_edge=1)


def smoke_config() -> GNNConfig:
    return GNNConfig("gatedgcn-smoke", "gatedgcn", n_layers=2, d_hidden=14,
                     d_feat=8, n_classes=3, d_edge=1)


def make_cell(shape: str, multi_pod: bool = False):
    return make_gnn_cell(get_config(), shape, multi_pod,
                         arch_name="gatedgcn")

"""gin-tu [arXiv:1810.00826]: 5L d=64, sum aggregator, learnable eps."""
from ..models.gnn import GNNConfig
from .gnn_common import GNN_SHAPES, make_gnn_cell

SHAPES = list(GNN_SHAPES)


def get_config() -> GNNConfig:
    return GNNConfig("gin-tu", "gin", n_layers=5, d_hidden=64,
                     d_feat=16, n_classes=2, learnable_eps=True)


def smoke_config() -> GNNConfig:
    return GNNConfig("gin-smoke", "gin", n_layers=2, d_hidden=16,
                     d_feat=8, n_classes=3)


def make_cell(shape: str, multi_pod: bool = False):
    return make_gnn_cell(get_config(), shape, multi_pod, arch_name="gin-tu")

"""dbrx-132b [hf:databricks/dbrx-base]: 40L d=6144 48H (GQA kv=8)
d_ff=10752, 16 experts top-4, vocab 100352."""
from ..models.moe import MoEConfig
from ..models.transformer import LMConfig
from .lm_common import LM_SHAPES, make_lm_cell

SHAPES = list(LM_SHAPES)


def get_config() -> LMConfig:
    return LMConfig(
        name="dbrx-132b", n_layers=40, d_model=6144, n_heads=48,
        n_kv_heads=8, d_ff=10752, vocab=100352, d_head=128,
        rope_theta=5e5,
        moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752,
                      token_chunks=8, dispatch_shards=16),
        tp_size=16)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="dbrx-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=32, vocab=128, d_head=16,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32), tp_size=1)


def make_cell(shape: str, multi_pod: bool = False):
    return make_lm_cell(get_config(), shape, multi_pod)

"""Shared cell builders for the LM-family architectures.

Shapes (assigned): train_4k (train), prefill_32k (inference prefill),
decode_32k (one token vs 32k KV cache), long_500k (one token vs 512k KV
cache, batch 1). decode/long lower `serve_step`, not `train_step`.
All five LM archs are full-attention; long_500k is a *decode* shape, i.e.
O(L) per token, so it runs (the sub-quadratic concern applies to prefill —
see DESIGN.md; a sliding-window config exists for optional 500k prefill).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models import transformer as T
from ..train import optim as O
from ..train.loop import make_train_step
from .cell import Cell

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def _bd(multi_pod: bool):
    return ("pod", "data") if multi_pod else "data"


def lm_flops_train(cfg: T.LMConfig, tokens: int) -> float:
    return 6.0 * cfg.active_param_count() * tokens


def lm_flops_prefill(cfg: T.LMConfig, batch: int, seq: int) -> float:
    dense = 2.0 * cfg.active_param_count() * batch * seq
    attn = 2.0 * cfg.n_layers * batch * seq * seq * cfg.n_heads * cfg.d_head
    return dense + attn  # causal halves the attn term; keep upper bound /2
    # (reported MODEL_FLOPS uses the dense 2ND convention + attention term)


def lm_flops_decode(cfg: T.LMConfig, batch: int, kv_len: int) -> float:
    dense = 2.0 * cfg.active_param_count() * batch
    attn = 4.0 * cfg.n_layers * batch * kv_len * cfg.n_heads * cfg.d_head
    return dense + attn


def make_lm_cell(cfg: T.LMConfig, shape: str, multi_pod: bool = False) -> Cell:
    spec = LM_SHAPES[shape]
    bd = _bd(multi_pod)
    ps = T.param_shardings(cfg)
    ap = T.abstract_params(cfg)
    meta = {
        "family": "lm", "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "scan_trips": cfg.n_layers,
    }
    # residual-stream sharding: d_model over "model" when attention is
    # head-sharded; SEQUENCE over "model" (context parallelism) otherwise
    act_spec = (P(bd, None, "model") if cfg.heads_shardable
                else P(bd, "model", None))
    head_spec = None if cfg.heads_shardable else P(bd, None, "model")

    if spec["kind"] == "train":
        ocfg = O.OptimizerConfig()
        ao = O.abstract_opt_state(ocfg, ap)
        osd = O.opt_state_shardings(ocfg, ps)
        B, S = spec["batch"], spec["seq"]
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        bspec = {"tokens": P(bd, None), "labels": P(bd, None)}
        step = make_train_step(
            lambda p, b: T.loss_fn(p, cfg, b, act_spec=act_spec,
                                   head_act_spec=head_spec), ocfg)
        meta["model_flops"] = lm_flops_train(cfg, B * S)
        meta["tokens"] = B * S
        return Cell(cfg.name, shape, "train", step, (ap, ao, batch),
                    (ps, osd, bspec), (ps, osd, None), (0, 1), meta)

    if spec["kind"] == "prefill":
        B, S = spec["batch"], spec["seq"]
        toks = jax.ShapeDtypeStruct((B, S), jnp.int32)
        fn2 = lambda params, tokens: T.prefill_step(params, cfg, tokens,
                                                    act_spec=act_spec)
        # KV cache sharding (§Perf): kv-heads over "model" when divisible
        # (MHA archs), else sequence over "model" — the [L, B, S, Hkv, Dh]
        # scan output otherwise replicates over the model axis (96 GiB on
        # codeqwen prefill_32k)
        if cfg.n_kv_heads % cfg.tp_size == 0:
            cspec_p = P(None, bd, None, "model", None)
        else:
            cspec_p = P(None, bd, "model", None, None)
        cache_spec = {"k": cspec_p, "v": cspec_p}
        meta["model_flops"] = lm_flops_prefill(cfg, B, S)
        meta["tokens"] = B * S
        return Cell(cfg.name, shape, "prefill", fn2, (ap, toks),
                    (ps, P(bd, None)), (P(bd), cache_spec), (), meta)

    # decode shapes
    B, S = spec["batch"], spec["seq"]
    cache = T.init_cache_abstract(cfg, B, S)
    if B == 1:
        # batch of one: shard the KV length over every mesh axis
        all_axes = (("pod", "data", "model") if multi_pod
                    else ("data", "model"))
        cspec = P(None, None, all_axes, None, None)
        tspec = P(None)
    elif cfg.n_kv_heads % cfg.tp_size == 0:
        # shard kv heads over "model": decode attention stays head-local
        cspec = P(None, bd, None, "model", None)
        tspec = P(bd)
    else:
        cspec = P(None, bd, "model", None, None)
        tspec = P(bd)
    cache_spec = {"k": cspec, "v": cspec}
    toks = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(params, cache, tokens, pos):
        return T.decode_step(params, cfg, cache, tokens, pos)

    meta["model_flops"] = lm_flops_decode(cfg, B, S)
    meta["tokens"] = B
    meta["kv_bytes"] = (2 * cfg.n_layers * B * S * cfg.n_kv_heads
                        * cfg.d_head * 2)
    return Cell(cfg.name, shape, "decode", fn, (ap, cache, toks, pos),
                (ps, cache_spec, tspec, P()),
                (tspec, P(bd if B > 1 else None, "model"), cache_spec),
                (1,), meta)

"""nequip [arXiv:2101.03164]: 5 interaction layers, 32 channels, l_max=2,
8 Bessel RBF, cutoff 5.0, E(3) tensor products (Gaunt couplings, no e3nn).
Non-molecular shapes carry synthetic 3D coordinates (DESIGN.md §4)."""
from ..models.nequip import NequIPConfig
from .gnn_common import GNN_SHAPES, make_nequip_cell

SHAPES = list(GNN_SHAPES)


def get_config() -> NequIPConfig:
    return NequIPConfig("nequip", n_layers=5, channels=32, l_max=2,
                        n_rbf=8, cutoff=5.0)


def smoke_config() -> NequIPConfig:
    return NequIPConfig("nequip-smoke", n_layers=2, channels=8, l_max=2,
                        n_rbf=4, cutoff=5.0, d_feat=4)


def make_cell(shape: str, multi_pod: bool = False):
    return make_nequip_cell(get_config(), shape, multi_pod)

"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 24L d=2048 16H (kv=16)
d_ff_expert=1408 vocab=151936, 60 routed experts top-4 + 4 shared.
60 experts pad to 64 for EP over the 16-way model axis (4/device)."""
from ..models.moe import MoEConfig
from ..models.transformer import LMConfig
from .lm_common import LM_SHAPES, make_lm_cell

SHAPES = list(LM_SHAPES)


def get_config() -> LMConfig:
    return LMConfig(
        name="qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=1408, vocab=151936, d_head=128, qkv_bias=True,
        rope_theta=1e6,
        moe=MoEConfig(num_experts=60, top_k=4, d_ff_expert=1408,
                      num_shared=4, shared_gate=True, pad_experts_to=64,
                      token_chunks=8, dispatch_shards=16),
        tp_size=16)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="qwen2-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=32, vocab=128, d_head=16, qkv_bias=True,
        moe=MoEConfig(num_experts=6, top_k=4, d_ff_expert=32, num_shared=2,
                      shared_gate=True, pad_experts_to=8),
        tp_size=1)


def make_cell(shape: str, multi_pod: bool = False):
    return make_lm_cell(get_config(), shape, multi_pod)

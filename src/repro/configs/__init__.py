"""Architecture registry: --arch <id> resolves here. Each module exposes
get_config(), smoke_config(), SHAPES, make_cell(shape, multi_pod)."""
from __future__ import annotations

import importlib

ARCHS = {
    # LM family
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "llama3-8b": "repro.configs.llama3_8b",
    "codeqwen1.5-7b": "repro.configs.codeqwen15_7b",
    "qwen2.5-14b": "repro.configs.qwen25_14b",
    # GNN family
    "nequip": "repro.configs.nequip",
    "gatedgcn": "repro.configs.gatedgcn",
    "pna": "repro.configs.pna",
    "gin-tu": "repro.configs.gin_tu",
    # RecSys
    "xdeepfm": "repro.configs.xdeepfm_arch",
}


# bonus cells outside the assigned 40 (not yielded by all_cells)
EXTRA_ARCHS = {
    "wcsd-serve": "repro.configs.wcsd_serve",
}


def get_arch(name: str):
    if name in EXTRA_ARCHS:
        return importlib.import_module(EXTRA_ARCHS[name])
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: "
                       f"{list(ARCHS) + list(EXTRA_ARCHS)}")
    return importlib.import_module(ARCHS[name])


def all_cells(multi_pod: bool = False):
    """Yield every (arch x shape) Cell — the 40-cell dry-run matrix."""
    for name in ARCHS:
        mod = get_arch(name)
        for shape in mod.SHAPES:
            yield name, shape, mod.make_cell(shape, multi_pod=multi_pod)

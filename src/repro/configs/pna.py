"""pna [arXiv:2004.05718]: 4L d=75, aggregators mean/max/min/std,
scalers identity/amplification/attenuation."""
from ..models.gnn import GNNConfig
from .gnn_common import GNN_SHAPES, make_gnn_cell

SHAPES = list(GNN_SHAPES)


def get_config() -> GNNConfig:
    return GNNConfig("pna", "pna", n_layers=4, d_hidden=75,
                     d_feat=16, n_classes=2)


def smoke_config() -> GNNConfig:
    return GNNConfig("pna-smoke", "pna", n_layers=2, d_hidden=15,
                     d_feat=8, n_classes=3)


def make_cell(shape: str, multi_pod: bool = False):
    return make_gnn_cell(get_config(), shape, multi_pod, arch_name="pna")

"""Shared cell builders for the GNN-family architectures.

Shapes (assigned):
  full_graph_sm  Cora-like full batch: 2,708 nodes / 10,556 edges / d=1433
  minibatch_lg   Reddit-like sampled training: 1,024 seeds, fanout 15-10
                 (the real numpy sampler lives in data/graphs.py; the cell
                 lowers the padded block shapes it produces)
  ogb_products   2,449,029 nodes / 61,859,140 edges / d=100, full batch
  molecule       128 graphs x 30 nodes x 64 edges (graph classification)

Distribution: edge arrays shard over ("pod","data"); node features/states
shard over the same axes for the large graphs (per-layer gather -> the
collective cost measured in §Roofline) and replicate for the small ones.
Edge counts are padded to mesh-divisible sizes with sink-node self-edges.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models import gnn as G
from ..models import nequip as NQ
from ..train import optim as O
from ..train.loop import make_train_step
from .cell import Cell


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


# symmetrized + padded static shapes per assigned cell
GNN_SHAPES = {
    "full_graph_sm": dict(kind="train", n_nodes=2708, n_edges_raw=10556,
                          d_feat=1433, n_classes=7, graph_level=False,
                          shard_nodes=False),
    "minibatch_lg": dict(kind="train", n_nodes=184320, n_edges_raw=168960,
                         d_feat=602, n_classes=41, graph_level=False,
                         shard_nodes=True,
                         note="sampled block: 1024 seeds x fanout 15-10 on a"
                              " 232,965-node/115M-edge graph"),
    "ogb_products": dict(kind="train", n_nodes=2449029,
                         n_edges_raw=61859140, d_feat=100, n_classes=47,
                         graph_level=False, shard_nodes=True),
    "molecule": dict(kind="train", n_nodes=30 * 128, n_edges_raw=64 * 2 * 128,
                     d_feat=16, n_classes=2, graph_level=True, n_graphs=128,
                     shard_nodes=False),
}


def _bd(multi_pod: bool):
    return ("pod", "data") if multi_pod else "data"


def padded_edges(spec: dict, multi_pod: bool) -> int:
    raw = spec["n_edges_raw"] * (2 if spec["shape_sym"] else 1) \
        if "shape_sym" in spec else spec["n_edges_raw"] * 2
    return _ceil_to(raw, 1024)


def gnn_model_flops(cfg, E: int, N: int, tokensless=True) -> float:
    """Analytic per-step fwd+bwd FLOPs (documented upper-level estimate)."""
    d = cfg.d_hidden
    if cfg.kind == "gin":
        per_layer = 2 * E * d + 2 * 2 * N * d * d
    elif cfg.kind == "pna":
        per_layer = 2 * E * d * d + 8 * E * d + 2 * N * 13 * d * d
    else:  # gatedgcn
        per_layer = 5 * 2 * E * d * d + 10 * E * d
    return 3.0 * cfg.n_layers * per_layer  # x3 for bwd


def make_gnn_cell(cfg: G.GNNConfig, shape: str, multi_pod: bool = False,
                  arch_name: str | None = None) -> Cell:
    spec = GNN_SHAPES[shape]
    bd = _bd(multi_pod)
    E = _ceil_to(spec["n_edges_raw"] * 2, 1024)
    # +1 sink node absorbing edge padding; pad to 512 for shard divisibility
    N = _ceil_to(spec["n_nodes"] + 1, 512) if spec["shard_nodes"] \
        else spec["n_nodes"] + 1
    cfg = G.GNNConfig(cfg.name, cfg.kind, cfg.n_layers, cfg.d_hidden,
                      d_feat=spec["d_feat"], n_classes=spec["n_classes"],
                      graph_level=spec["graph_level"], d_edge=cfg.d_edge,
                      # bf16 activations on the huge full-batch cells
                      compute_dtype=("bfloat16" if spec["shard_nodes"]
                                     else "float32"))
    ap = G.abstract_params(cfg)
    ps = G.param_shardings(cfg)
    nspec = P(bd, None) if spec["shard_nodes"] else P(None, None)
    lspec = P(bd) if spec["shard_nodes"] else P(None)
    batch = {
        "feat": jax.ShapeDtypeStruct((N, spec["d_feat"]), jnp.float32),
        "edges_src": jax.ShapeDtypeStruct((E,), jnp.int32),
        "edges_dst": jax.ShapeDtypeStruct((E,), jnp.int32),
    }
    bspec = {"feat": nspec, "edges_src": P(bd), "edges_dst": P(bd)}
    ng = None
    if spec["graph_level"]:
        ng = spec["n_graphs"]
        batch["graph_id"] = jax.ShapeDtypeStruct((N,), jnp.int32)
        batch["labels"] = jax.ShapeDtypeStruct((ng,), jnp.int32)
        bspec["graph_id"] = P(None)
        bspec["labels"] = P(None)
    else:
        batch["labels"] = jax.ShapeDtypeStruct((N,), jnp.int32)
        bspec["labels"] = lspec

    ocfg = O.OptimizerConfig(lr=1e-3, weight_decay=0.0)
    ao = O.abstract_opt_state(ocfg, ap)
    osd = O.opt_state_shardings(ocfg, ps)
    step = make_train_step(
        lambda p, b: G.loss_fn(p, cfg, b, n_graphs=ng), ocfg)
    meta = {
        "family": "gnn", "scan_trips": 1,   # python-loop layers: no scan
        "model_flops": gnn_model_flops(cfg, E, N),
        "n_nodes": N, "n_edges": E,
        "params": sum(int(np.prod(s)) for s, _ in G.param_defs(cfg).values()),
    }
    if "note" in spec:
        meta["note"] = spec["note"]
    return Cell(arch_name or cfg.name, shape, "train", step,
                (ap, ao, batch), (ps, osd, bspec), (ps, osd, None), (0, 1),
                meta)


def make_nequip_cell(cfg: NQ.NequIPConfig, shape: str,
                     multi_pod: bool = False) -> Cell:
    spec = GNN_SHAPES[shape]
    bd = _bd(multi_pod)
    E = _ceil_to(spec["n_edges_raw"] * 2, 1024)
    N = _ceil_to(spec["n_nodes"] + 1, 512) if spec["shard_nodes"] \
        else spec["n_nodes"] + 1
    cfg = NQ.NequIPConfig(cfg.name, cfg.n_layers, cfg.channels, cfg.l_max,
                          cfg.n_rbf, cfg.cutoff, d_feat=spec["d_feat"],
                          radial_hidden=cfg.radial_hidden)
    ap = NQ.abstract_params(cfg)
    ps = NQ.param_shardings(cfg)
    # node irreps stay REPLICATED for nequip: every edge chunk gathers
    # h[src] by arbitrary index, so sharded nodes would all-gather the full
    # state once per chunk (measured 4 TiB/device on ogb_products);
    # replicated states + edge-sharded partial aggregates -> one all-reduce
    # per layer instead.
    nspec = P(None, None)
    ng = spec.get("n_graphs", 1)
    batch = {
        "feat": jax.ShapeDtypeStruct((N, spec["d_feat"]), jnp.float32),
        "pos": jax.ShapeDtypeStruct((N, 3), jnp.float32),
        "edges_src": jax.ShapeDtypeStruct((E,), jnp.int32),
        "edges_dst": jax.ShapeDtypeStruct((E,), jnp.int32),
        "graph_id": jax.ShapeDtypeStruct((N,), jnp.int32),
        "energy": jax.ShapeDtypeStruct((ng,), jnp.float32),
        "forces": jax.ShapeDtypeStruct((N, 3), jnp.float32),
    }
    bspec = {"feat": nspec, "pos": nspec, "edges_src": P(bd),
             "edges_dst": P(bd), "graph_id": P(None),
             "energy": P(None), "forces": nspec}
    # edge chunking for the huge cells (see models/nequip.py)
    edge_chunk = None
    if E > 4_000_000:
        edge_chunk = E // 64 if E % 64 == 0 else None
    elif E > 100_000:
        edge_chunk = E // 8 if E % 8 == 0 else None
    # forces only where the task is molecular (positions are physical)
    fw = 0.1 if shape == "molecule" else 0.0
    ocfg = O.OptimizerConfig(lr=1e-3, weight_decay=0.0)
    ao = O.abstract_opt_state(ocfg, ap)
    osd = O.opt_state_shardings(ocfg, ps)

    def loss(p, b):
        if fw:
            return NQ.loss_fn(p, cfg, b, n_graphs=ng, force_weight=fw)
        e = NQ.energy_fn(p, cfg, b, n_graphs=ng, edge_chunk=edge_chunk)
        return jnp.mean((e - b["energy"]) ** 2)

    step = make_train_step(loss, ocfg)
    n_paths = len(NQ._paths())
    C = cfg.channels
    meta = {
        "family": "gnn", "scan_trips": (E // edge_chunk if edge_chunk else 1),
        # per edge: radial MLP + n_paths tensor products over C channels
        "model_flops": 3.0 * cfg.n_layers * E * (
            2 * cfg.n_rbf * cfg.radial_hidden
            + 2 * cfg.radial_hidden * n_paths * C + n_paths * C * 45)
        + 3.0 * cfg.n_layers * N * 2 * C * C * 9,
        "n_nodes": N, "n_edges": E, "edge_chunk": edge_chunk,
        "params": sum(int(np.prod(s))
                      for s, _ in NQ.param_defs(cfg).values()),
        "note": "synthetic 3D coords for non-molecular graphs (DESIGN.md)",
    }
    return Cell(cfg.name, shape, "train", step, (ap, ao, batch),
                (ps, osd, bspec), (ps, osd, None), (0, 1), meta)

"""llama3-8b [arXiv:2407.21783]: 32L d=4096 32H (GQA kv=8) d_ff=14336,
vocab 128256."""
from ..models.transformer import LMConfig
from .lm_common import LM_SHAPES, make_lm_cell

SHAPES = list(LM_SHAPES)


def get_config() -> LMConfig:
    return LMConfig(
        name="llama3-8b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=14336, vocab=128256, d_head=128,
        rope_theta=5e5, tp_size=16)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="llama3-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=128, d_head=16, tp_size=1)


def make_cell(shape: str, multi_pod: bool = False):
    return make_lm_cell(get_config(), shape, multi_pod)

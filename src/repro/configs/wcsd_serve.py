"""The paper's serving workload: the dry-run compile cell (below) AND the
runnable `ServeConfig` consumed by `WCSDServer` / `launch.dryrun --serve`.

Labels for a ~1M-vertex graph (padded width 256) shard their vertex axis
over "model"; the query batch shards over ("pod","data"). This is the
serving configuration the WCSDServer would run pod-wide."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.query import profile_batch_jnp, query_batch_jnp
from .cell import Cell

SHAPES = ["serve_1m", "profile_1m"]


@dataclasses.dataclass
class ServeConfig:
    """Everything `WCSDServer` needs to stand up a serving stack.

    ``backend="sharded"`` builds a `ShardedQueryEngine` over a
    `launch.mesh.make_serving_mesh` mesh (batch sharded, labels replicated;
    vertex-sharded labels + row-gather reduce-scatter once the store
    exceeds ``device_budget_bytes``). ``use_pallas``/``interpret`` select
    the kernel path: ``interpret=None`` resolves through
    `kernels.ops.resolve_interpret` — compiled Pallas on TPU, interpret
    emulation elsewhere or by explicit request — so serving is NOT
    pinned to interpret mode. ``dispatch`` picks the CSR
    query path: "ragged" (one megakernel launch per flush, the default) or
    "bucket_pair" (the per-bucket-pair oracle loop). ``compressed`` (csr +
    ragged only) serves from the bf16/delta-coded `CompressedArena` —
    ~2.4x the rows per device under the same ``device_budget_bytes``; hub
    ids exact, distances within the documented bound. The same stack
    serves profile (staircase) queries — `WCSDServer.submit_profile`
    needs no extra configuration; its level count comes from the index.

    ``max_wait_us``/``min_batch`` enable continuous batching
    (docs/serving.md §1a): with a deadline set, a flush fires as soon as
    ``min_batch`` requests are queued and the in-flight slot is free, or
    when the oldest queued request has waited ``max_wait_us`` — so a
    trickle of traffic is never starved waiting for ``max_batch``.
    ``max_wait_us=None`` (default) keeps the epoch-flush behavior.

    The resilience knobs (docs/resilience.md) arm the flush watchdog:
    ``flush_timeout_ms`` is the in-flight deadline (None = wait forever,
    the pre-watchdog behavior), ``max_retries``/``backoff_base_ms`` the
    retry budget and backoff base, ``probe_interval`` the number of
    healthy flushes before a degraded server re-promotes one ladder rung.
    ``wal_path`` attaches the crash-safe update WAL — every
    `apply_updates` batch is logged before the index is touched."""

    backend: str = "sharded"          # "device" | "sharded"
    layout: str = "csr"               # "padded" | "csr"
    dispatch: str = "ragged"          # "ragged" | "bucket_pair"
    use_pallas: bool = False
    interpret: bool | None = None     # auto: compiled on TPU, else interpret
    max_batch: int = 1024
    memo_capacity: int = 65536
    undirected: bool = True
    multi_pod: bool = False           # ("pod", "data") batch axes
    device_budget_bytes: int | None = None
    compressed: bool = False          # CompressedArena store (csr + ragged)
    max_wait_us: float | None = None  # continuous-batching deadline
    min_batch: int = 1                # admission floor for early flushes
    flush_timeout_ms: float | None = None  # watchdog deadline per flush
    max_retries: int = 3              # retry budget per flush, per rung
    backoff_base_ms: float = 1.0      # exponential backoff base (jittered)
    probe_interval: int = 8           # healthy flushes before re-promotion
    wal_path: str | None = None       # crash-safe update WAL (None = off)

    def server_kwargs(self) -> dict:
        return dict(backend=self.backend, layout=self.layout,
                    dispatch=self.dispatch,
                    use_pallas=self.use_pallas, interpret=self.interpret,
                    max_batch=self.max_batch,
                    memo_capacity=self.memo_capacity,
                    undirected=self.undirected,
                    device_budget_bytes=self.device_budget_bytes,
                    multi_pod=self.multi_pod, compressed=self.compressed,
                    max_wait_us=self.max_wait_us, min_batch=self.min_batch,
                    flush_timeout_ms=self.flush_timeout_ms,
                    max_retries=self.max_retries,
                    backoff_base_ms=self.backoff_base_ms,
                    probe_interval=self.probe_interval,
                    wal_path=self.wal_path)


def serve_config() -> ServeConfig:
    """Production shape: compiled kernels (interpret auto-resolves False on
    accelerators), CSR store, ragged single-launch dispatch, sharded
    batch, 500µs admission deadline (continuous batching), 5s flush
    watchdog (a wedged collective is retried, then absorbed by the
    fallback ladder instead of hanging every caller)."""
    return ServeConfig(use_pallas=True, max_batch=4096,
                       max_wait_us=500.0, min_batch=32,
                       flush_timeout_ms=5000.0)


def smoke_serve_config() -> ServeConfig:
    """CI shape: interpret-mode kernels on virtual host devices."""
    return ServeConfig(use_pallas=True, interpret=True, max_batch=256)

_V = 1 << 20          # vertices
_L = 256              # padded label width
_B = 1 << 20          # queries per step


def get_config():
    return {"V": _V, "L": _L, "B": _B}


def smoke_config():
    return {"V": 256, "L": 16, "B": 64}


_W = 8                # quality levels of the profile serving cell
_BP = 1 << 17         # profile queries per step (each answers _W+1 levels)


def make_cell(shape: str = "serve_1m", multi_pod: bool = False) -> Cell:
    bd = ("pod", "data") if multi_pod else "data"
    lspec = P(None, None)   # labels replicated (3 GiB total, fits HBM)
    label_args = (
        jax.ShapeDtypeStruct((_V, _L), jnp.int32),   # hub
        jax.ShapeDtypeStruct((_V, _L), jnp.int32),   # dist
        jax.ShapeDtypeStruct((_V, _L), jnp.int32),   # wlev
        jax.ShapeDtypeStruct((_V,), jnp.int32),      # count
    )
    if shape == "profile_1m":
        # constraint-exploration workload: every query returns the full
        # (W + 1)-level staircase from ONE label sweep — the L-call loop
        # this replaces would multiply the gather volume by W + 1
        import functools
        args = label_args + (
            jax.ShapeDtypeStruct((_BP,), jnp.int32),  # s
            jax.ShapeDtypeStruct((_BP,), jnp.int32),  # t
        )
        in_sh = (lspec,) * 3 + (P(None), P(bd), P(bd))
        meta = {"family": "wcsd", "scan_trips": 1,
                # per query: L*L join + (W+1) bucketed min passes
                "model_flops": 2.0 * _BP * _L * _L * (_W + 1),
                "note": "one-pass profile serving cell (staircase per "
                        "query; see docs/profile-queries.md)"}
        fn = functools.partial(profile_batch_jnp, num_levels=_W)
        return Cell("wcsd-serve", shape, "serve", fn, args, in_sh,
                    P(bd), (), meta)
    args = label_args + (
        jax.ShapeDtypeStruct((_B,), jnp.int32),      # s
        jax.ShapeDtypeStruct((_B,), jnp.int32),      # t
        jax.ShapeDtypeStruct((_B,), jnp.int32),      # w
    )
    in_sh = (lspec, lspec, lspec, P(None), P(bd), P(bd), P(bd))
    meta = {"family": "wcsd", "scan_trips": 1,
            # per query: L*L compares + L*L adds (VPU op count proxy)
            "model_flops": 2.0 * _B * _L * _L,
            "note": "paper-technique serving cell (bonus, not in the 40)"}
    return Cell("wcsd-serve", shape, "serve", query_batch_jnp, args,
                in_sh, P(bd), (), meta)

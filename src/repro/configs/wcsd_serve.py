"""Bonus cell (outside the assigned 40): the paper's own workload on the
production mesh — batched WCSD queries against a device-resident WC-INDEX.

Labels for a ~1M-vertex graph (padded width 256) shard their vertex axis
over "model"; the query batch shards over ("pod","data"). This is the
serving configuration the WCSDServer would run pod-wide."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.query import query_batch_jnp
from .cell import Cell

SHAPES = ["serve_1m"]

_V = 1 << 20          # vertices
_L = 256              # padded label width
_B = 1 << 20          # queries per step


def get_config():
    return {"V": _V, "L": _L, "B": _B}


def smoke_config():
    return {"V": 256, "L": 16, "B": 64}


def make_cell(shape: str = "serve_1m", multi_pod: bool = False) -> Cell:
    bd = ("pod", "data") if multi_pod else "data"
    args = (
        jax.ShapeDtypeStruct((_V, _L), jnp.int32),   # hub
        jax.ShapeDtypeStruct((_V, _L), jnp.int32),   # dist
        jax.ShapeDtypeStruct((_V, _L), jnp.int32),   # wlev
        jax.ShapeDtypeStruct((_V,), jnp.int32),      # count
        jax.ShapeDtypeStruct((_B,), jnp.int32),      # s
        jax.ShapeDtypeStruct((_B,), jnp.int32),      # t
        jax.ShapeDtypeStruct((_B,), jnp.int32),      # w
    )
    lspec = P(None, None)   # labels replicated (3 GiB total, fits HBM)
    in_sh = (lspec, lspec, lspec, P(None), P(bd), P(bd), P(bd))
    meta = {"family": "wcsd", "scan_trips": 1,
            # per query: L*L compares + L*L adds (VPU op count proxy)
            "model_flops": 2.0 * _B * _L * _L,
            "note": "paper-technique serving cell (bonus, not in the 40)"}
    return Cell("wcsd-serve", shape, "serve", query_batch_jnp, args,
                in_sh, P(bd), (), meta)

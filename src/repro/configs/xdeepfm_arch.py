"""xdeepfm [arXiv:1803.05170]: 39 sparse fields, embed_dim 10, CIN
200-200-200, MLP 400-400. Shapes: train_batch (65,536), serve_p99 (512),
serve_bulk (262,144), retrieval_cand (1 query x 1,000,000 candidates)."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models import xdeepfm as X
from ..train import optim as O
from ..train.loop import make_train_step
from .cell import Cell

SHAPES = ["train_batch", "serve_p99", "serve_bulk", "retrieval_cand"]

_SHAPE_SPECS = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_cand=1_000_000),
}


def get_config() -> X.XDeepFMConfig:
    return X.XDeepFMConfig("xdeepfm")


def smoke_config() -> X.XDeepFMConfig:
    return X.XDeepFMConfig("xdeepfm-smoke", n_sparse=6, embed_dim=4,
                           cin_layers=(8, 8), mlp_layers=(16,),
                           big_fields=2, big_vocab=64, small_vocab=16)


def _flops_fwd(cfg: X.XDeepFMConfig, B: int) -> float:
    D = cfg.embed_dim
    f = 0.0
    h_prev = cfg.n_sparse
    for k in cfg.cin_layers:
        f += 2.0 * B * k * h_prev * cfg.n_sparse * D
        h_prev = k
    d_in = cfg.n_sparse * D
    for w in cfg.mlp_layers:
        f += 2.0 * B * d_in * w
        d_in = w
    f += 2.0 * B * d_in
    return f


def make_cell(shape: str, multi_pod: bool = False) -> Cell:
    cfg = get_config()
    spec = _SHAPE_SPECS[shape]
    bd = ("pod", "data") if multi_pod else "data"
    ap = X.abstract_params(cfg)
    ps = X.param_shardings(cfg)
    meta = {"family": "recsys", "scan_trips": cfg.embed_dim,  # CIN d-scan
            "params": cfg.total_rows * (cfg.embed_dim + 1),
            "embed_rows": cfg.total_rows}

    if spec["kind"] == "train":
        B = spec["batch"]
        batch = {"ids": jax.ShapeDtypeStruct((B, cfg.n_sparse), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((B,), jnp.int32)}
        bspec = {"ids": P(bd, None), "labels": P(bd)}
        ocfg = O.OptimizerConfig(lr=1e-3, weight_decay=0.0)
        ao = O.abstract_opt_state(ocfg, ap)
        osd = O.opt_state_shardings(ocfg, ps)
        step = make_train_step(lambda p, b: X.loss_fn(p, cfg, b), ocfg)
        meta["model_flops"] = 3.0 * _flops_fwd(cfg, B)
        return Cell("xdeepfm", shape, "train", step, (ap, ao, batch),
                    (ps, osd, bspec), (ps, osd, None), (0, 1), meta)

    if spec["kind"] == "serve":
        B = spec["batch"]
        batch = {"ids": jax.ShapeDtypeStruct((B, cfg.n_sparse), jnp.int32)}
        bspec = {"ids": P(bd, None)}

        def fn(params, batch):
            return X.forward(params, cfg, batch)

        meta["model_flops"] = _flops_fwd(cfg, B)
        return Cell("xdeepfm", shape, "serve", fn, (ap, batch),
                    (ps, bspec), P(bd), (), meta)

    # retrieval: one query against 1M candidate embeddings
    C = spec["n_cand"]
    qids = jax.ShapeDtypeStruct((1, cfg.n_sparse), jnp.int32)
    cand = jax.ShapeDtypeStruct((C, cfg.embed_dim), jnp.float32)

    def fn(params, query_ids, cand_emb):
        scores, (top_v, top_i) = X.retrieval_scores(params, cfg, query_ids,
                                                    cand_emb)
        return top_v, top_i

    meta["model_flops"] = 2.0 * C * cfg.embed_dim
    return Cell("xdeepfm", shape, "retrieval", fn, (ap, qids, cand),
                (ps, P(None, None), P(bd, None)), None, (), meta)

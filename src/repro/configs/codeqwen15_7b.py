"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B]: 32L d=4096 32H (kv=32, MHA)
d_ff=13440, vocab 92416, QKV bias (qwen1.5 arch)."""
from ..models.transformer import LMConfig
from .lm_common import LM_SHAPES, make_lm_cell

SHAPES = list(LM_SHAPES)


def get_config() -> LMConfig:
    return LMConfig(
        name="codeqwen1.5-7b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=32, d_ff=13440, vocab=92416, d_head=128, qkv_bias=True,
        rope_theta=1e6, tp_size=16)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="codeqwen-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=128, d_head=16, qkv_bias=True,
        tp_size=1)


def make_cell(shape: str, multi_pod: bool = False):
    return make_lm_cell(get_config(), shape, multi_pod)

"""qwen2.5-14b [hf:Qwen/Qwen2.5-*]: 48L d=5120 40H (GQA kv=8) d_ff=13824,
vocab 152064, QKV bias.

40 heads % 16 != 0 -> attention projections fall back to row-parallel
(d_model contracted over "model"); attention einsums replicate over the
model axis while FFN/vocab stay tensor-parallel. See DESIGN.md §4 and the
§Perf hillclimb for the context-parallel alternative."""
from ..models.transformer import LMConfig
from .lm_common import LM_SHAPES, make_lm_cell

SHAPES = list(LM_SHAPES)


def get_config() -> LMConfig:
    return LMConfig(
        name="qwen2.5-14b", n_layers=48, d_model=5120, n_heads=40,
        n_kv_heads=8, d_ff=13824, vocab=152064, d_head=128, qkv_bias=True,
        rope_theta=1e6, tp_size=16)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="qwen25-smoke", n_layers=2, d_model=60, n_heads=5, n_kv_heads=1,
        d_ff=128, vocab=128, d_head=12, qkv_bias=True, tp_size=2)


def make_cell(shape: str, multi_pod: bool = False):
    return make_lm_cell(get_config(), shape, multi_pod)

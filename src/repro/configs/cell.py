"""A Cell = one (architecture x input-shape) point of the dry-run matrix:
everything needed to lower + compile the step on a production mesh without
allocating real data."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str                     # train | prefill | decode | serve
    fn: Callable                  # jit target
    args: tuple                   # abstract args (ShapeDtypeStruct pytrees)
    in_shardings: tuple
    out_shardings: Any = None
    donate_argnums: tuple = ()
    # meta for the roofline: analytic MODEL_FLOPS, scan trip count for
    # collective extrapolation, param counts, notes
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def name(self) -> str:
        return f"{self.arch}__{self.shape}"

"""Sharded checkpointing: pytree -> per-leaf npz shards + JSON manifest.

The manifest records tree structure, shapes/dtypes, the mesh the state was
saved under, and a data-pipeline cursor — enough to restore onto a
*different* device count (elastic re-mesh): leaves are saved unsharded
(gathered) here on CPU; on a real multi-host run each host writes its local
shard and the manifest carries the global offsets (layout documented in
DESIGN.md). Atomicity: writes go to <dir>.tmp then os.replace."""
from __future__ import annotations

import json
import os
import shutil
import zlib

import numpy as np

import jax

from ..core.resilience import IndexIntegrityError, WALError, WALReplayError


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ----------------------------------------------------------------- save
    def save(self, step: int, state, extra: dict | None = None) -> str:
        flat, _ = _flatten_with_paths(state)
        path = os.path.join(self.dir, f"step_{step:08d}")
        tmp = path + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        arrays = {k: np.asarray(v) for k, v in flat.items()}
        np.savez(os.path.join(tmp, "state.npz"), **arrays)
        manifest = {
            "step": step,
            "leaves": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                       for k, a in arrays.items()},
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.replace(tmp, path)
        self._gc()
        return path

    # -------------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        return steps[-1] if steps else None

    def restore(self, like_state, step: int | None = None):
        """Restore into the structure of `like_state` (shapes must match —
        the elastic path re-shards by loading full arrays and letting jit's
        in_shardings re-partition them)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, "state.npz"))
        flat, treedef = _flatten_with_paths(like_state)
        restored = {}
        for k, leaf in flat.items():
            a = data[k]
            want = tuple(getattr(leaf, "shape", np.shape(leaf)))
            if tuple(a.shape) != want:
                raise ValueError(f"shape mismatch for {k}: {a.shape} vs {want}")
            restored[k] = a
        leaves = [restored[k] for k in flat.keys()]
        return jax.tree_util.tree_unflatten(treedef, leaves), step

    def manifest(self, step: int) -> dict:
        path = os.path.join(self.dir, f"step_{step:08d}", "manifest.json")
        with open(path) as f:
            return json.load(f)

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)


# ---------------------------------------------------------------------------
# On-disk WC-Index persistence (docs/dynamic-index.md §on-disk layout).
#
# Single-file format, designed for mmap zero-copy loads so sharded serving
# replicas warm-start without rebuilding (and without even reading the whole
# file eagerly):
#
#   [ 8B magic "WCSDIDX\x01" ][ 8B little-endian header length H ]
#   [ H bytes JSON header ][ zero pad to 64 ][ raw array blobs, 64-aligned ]
#
# The JSON header carries the format version, the graph version the index
# was built against, num_nodes / num_levels, and for every array its dtype,
# shape, absolute byte offset, length and CRC32, plus the expected payload
# end — a truncation check that does not require hashing the payload.
# Loads go through numpy memmaps: `PackedLabels.from_flat` keeps contiguous
# int32 views as-is, so the arena pages in lazily on first query. Format
# version 2 added the per-blob CRC32 table: `load_packed_index` verifies
# every blob against it by default (a single byte flipped anywhere in the
# payload raises `IndexIntegrityError` instead of loading silently), and
# stamps the expected checksums onto the returned index so
# `PackedWCIndex.verify_integrity()` can re-check the live arrays on
# demand (docs/resilience.md).

WCX_MAGIC = b"WCSDIDX\x01"
WCX_VERSION = 2
_WCX_ALIGN = 64


class IndexPersistenceError(RuntimeError):
    """Base class: a persisted index file cannot be served."""


class IndexHeaderError(IndexPersistenceError):
    """Bad magic or unparseable header — not a WC-Index file."""


class IndexVersionError(IndexPersistenceError):
    """The file's format version is not one this reader understands."""


class IndexTruncatedError(IndexPersistenceError):
    """The payload ends before the header says it should (torn write,
    partial copy, mid-write crash)."""


def _wcx_arrays(idx) -> dict:
    labels = idx.labels
    return {
        "order": np.ascontiguousarray(idx.order, dtype=np.int32),
        "rank": np.ascontiguousarray(idx.rank, dtype=np.int32),
        "levels": np.ascontiguousarray(idx.levels, dtype=np.float64),
        "hub_rank": np.ascontiguousarray(labels.hub_rank, dtype=np.int32),
        "dist": np.ascontiguousarray(labels.dist, dtype=np.int32),
        "wlev": np.ascontiguousarray(labels.wlev, dtype=np.int32),
        "offsets": np.ascontiguousarray(labels.offsets, dtype=np.int64),
    }


def save_packed_index(path: str, idx, *, graph_version: int = 0,
                      _open=open) -> str:
    """Persist a `PackedWCIndex` (or anything `as_packed_index` accepts).

    Atomic: writes to ``path + ".tmp"`` then `os.replace`, so readers never
    observe a half-written file under ``path`` — a crash mid-write leaves at
    most a stale tmp file behind. ``_open`` is injectable for fault tests
    (checkpoint/fault.py `crashing_open`)."""
    from ..core.wc_index import as_packed_index
    idx = as_packed_index(idx)
    arrays = _wcx_arrays(idx)
    table = {}
    base = 0  # filled once the header length is known
    blobs = []
    off = 0
    for name, a in arrays.items():
        off = -(-off // _WCX_ALIGN) * _WCX_ALIGN
        table[name] = {"dtype": str(a.dtype), "shape": list(a.shape),
                       "offset": off, "nbytes": int(a.nbytes),
                       "crc32": zlib.crc32(a.tobytes())}
        blobs.append((off, a))
        off += int(a.nbytes)
    header = {
        "version": WCX_VERSION,
        "graph_version": int(graph_version),
        "num_nodes": int(idx.num_nodes),
        "num_levels": int(idx.num_levels),
        "arrays": table,
        "payload_bytes": off,
    }
    hjson = json.dumps(header, sort_keys=True).encode()
    base = len(WCX_MAGIC) + 8 + len(hjson)
    base = -(-base // _WCX_ALIGN) * _WCX_ALIGN
    tmp = path + ".tmp"
    with _open(tmp, "wb") as f:
        f.write(WCX_MAGIC)
        f.write(len(hjson).to_bytes(8, "little"))
        f.write(hjson)
        f.write(b"\0" * (base - len(WCX_MAGIC) - 8 - len(hjson)))
        at = 0
        for off, a in blobs:
            if off > at:
                f.write(b"\0" * (off - at))
                at = off
            f.write(a.tobytes())
            at += a.nbytes
    os.replace(tmp, path)
    return path


def load_packed_index(path: str, *, mmap: bool = True, verify: bool = True):
    """Load a persisted index; returns ``(PackedWCIndex, header_dict)``.

    Validates magic, format version and payload length BEFORE constructing
    anything — a truncated or foreign file raises the typed error and never
    yields a partially-loaded arena. With ``mmap=True`` (default) array
    blobs are `np.memmap` views: zero-copy, paged in on first touch.

    With ``verify=True`` (default) every blob is additionally checked
    against the header's CRC32 table: a single flipped byte anywhere in
    the payload raises `IndexIntegrityError` instead of loading silently
    (the cost is one sequential read of the payload — under mmap the
    pages stay warm for serving). The expected checksums are stamped on
    the returned index, so `PackedWCIndex.verify_integrity()` re-checks
    the live arrays on demand. ``verify=False`` keeps loads lazy/zero-
    copy; `verify_integrity(expected={name: crc...})` with the header's
    table performs the same check later."""
    from ..core.wc_index import PackedLabels, PackedWCIndex
    try:
        size = os.path.getsize(path)
    except OSError as e:
        raise IndexPersistenceError(f"cannot stat {path!r}: {e}") from e
    with open(path, "rb") as f:
        magic = f.read(len(WCX_MAGIC))
        if magic != WCX_MAGIC:
            raise IndexHeaderError(
                f"{path!r} is not a WC-Index file (magic {magic!r})")
        raw = f.read(8)
        if len(raw) < 8:
            raise IndexTruncatedError(f"{path!r}: truncated header length")
        hlen = int.from_bytes(raw, "little")
        hjson = f.read(hlen)
        if len(hjson) < hlen:
            raise IndexTruncatedError(f"{path!r}: truncated header")
        try:
            header = json.loads(hjson)
        except ValueError as e:
            raise IndexHeaderError(f"{path!r}: unparseable header") from e
    version = header.get("version")
    if version != WCX_VERSION:
        raise IndexVersionError(
            f"{path!r}: format version {version!r}, reader supports "
            f"{WCX_VERSION}")
    base = len(WCX_MAGIC) + 8 + hlen
    base = -(-base // _WCX_ALIGN) * _WCX_ALIGN
    expected = base + int(header["payload_bytes"])
    if size < expected:
        raise IndexTruncatedError(
            f"{path!r}: {size} bytes on disk, header promises {expected}")
    out = {}
    for name, spec in header["arrays"].items():
        shape = tuple(spec["shape"])
        dtype = np.dtype(spec["dtype"])
        off = base + int(spec["offset"])
        if mmap:
            out[name] = np.memmap(path, mode="r", dtype=dtype, shape=shape,
                                  offset=off)
        else:
            with open(path, "rb") as f:
                f.seek(off)
                buf = f.read(int(spec["nbytes"]))
            if len(buf) < int(spec["nbytes"]):
                raise IndexTruncatedError(f"{path!r}: short read of {name}")
            out[name] = np.frombuffer(buf, dtype=dtype).reshape(shape)
    expected = {name: spec["crc32"]
                for name, spec in header["arrays"].items()
                if "crc32" in spec}
    if verify:
        bad = [name for name, crc in expected.items()
               if zlib.crc32(out[name].tobytes()) != crc]
        if bad:
            raise IndexIntegrityError(
                f"{path!r}: blob checksum mismatch in {sorted(bad)} — "
                "bit rot or torn copy; refusing to serve")
    labels = PackedLabels.from_flat(out["hub_rank"], out["dist"],
                                    out["wlev"], out["offsets"])
    idx = PackedWCIndex(order=out["order"], rank=out["rank"],
                        levels=out["levels"], labels=labels)
    idx._expected_crc = expected or None
    return idx, header


# ---------------------------------------------------------------------------
# Crash-safe update WAL (docs/resilience.md §WAL).
#
# `WCSDServer.apply_updates` appends each mutation batch here BEFORE the
# index is touched, so a crash anywhere between the append and the engine
# rebuild loses nothing: a replica warm-starting from the last persisted
# index (`load_packed_index`) replays the WAL tail and converges to the
# pre-crash graph version exactly (applying a logged record from the
# pre-crash state is idempotent by construction — it is the apply that
# never happened). Layout:
#
#   [ 8B magic "WCSDWAL\x01" ][ 8B little-endian base_version ]
#   [ records: 4B LE payload length | 4B LE CRC32 | JSON payload ]...
#
# ``base_version`` is the graph version the log starts from; record k
# carries ``graph_version == base_version + k + 1`` (every apply bumps by
# exactly one — a gap is corruption, not truncation). A torn TAIL record
# (mid-append crash, injected via `fault.crashing_open`) is tolerated:
# replay stops at the first short/CRC-failing record, which is exactly
# the append that never committed. `truncate` reuses the save path's
# atomic tmp + `os.replace` idiom, so compaction can never tear the log.

WAL_MAGIC = b"WCSDWAL\x01"


class UpdateWAL:
    """Checksummed append-only log of `apply_updates` mutation batches.

    ``_open`` is injectable for fault tests (`fault.crashing_open` tears
    an append mid-record); ``fsync=False`` trades durability for append
    speed (benchmarked as ``wal_append_us``)."""

    def __init__(self, path: str, *, base_version: int = 0,
                 fsync: bool = True, _open=open):
        self.path = path
        self._fsync = bool(fsync)
        self._open = _open
        if not os.path.exists(path):
            self._reset(base_version)

    # ------------------------------------------------------------ plumbing
    def _reset(self, base_version: int) -> None:
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(WAL_MAGIC)
            f.write(int(base_version).to_bytes(8, "little"))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)

    def base_version(self) -> int:
        return self._scan()[0]

    def _scan(self) -> tuple[int, list[dict], bool]:
        """(base_version, committed records, torn_tail). Stops at the
        first short or checksum-failing record — under the append
        protocol that can only be the mid-crash tail; anything after it
        was never acknowledged."""
        try:
            with open(self.path, "rb") as f:
                head = f.read(len(WAL_MAGIC) + 8)
                if (len(head) < len(WAL_MAGIC) + 8
                        or head[:len(WAL_MAGIC)] != WAL_MAGIC):
                    raise WALError(f"{self.path!r} is not a WCSD WAL "
                                   f"(header {head[:8]!r})")
                base = int.from_bytes(head[len(WAL_MAGIC):], "little")
                records, torn, expect = [], False, base + 1
                while True:
                    hdr = f.read(8)
                    if not hdr:
                        break                      # clean EOF
                    if len(hdr) < 8:
                        torn = True
                        break
                    n = int.from_bytes(hdr[:4], "little")
                    crc = int.from_bytes(hdr[4:], "little")
                    payload = f.read(n)
                    if len(payload) < n or zlib.crc32(payload) != crc:
                        torn = True
                        break
                    try:
                        rec = json.loads(payload)
                    except ValueError:
                        torn = True
                        break
                    if rec.get("graph_version") != expect:
                        raise WALError(
                            f"{self.path!r}: record sequence gap — got "
                            f"graph_version {rec.get('graph_version')!r}, "
                            f"expected {expect}")
                    records.append(rec)
                    expect += 1
        except OSError as e:
            raise WALError(f"cannot read WAL {self.path!r}: {e}") from e
        return base, records, torn

    # ------------------------------------------------------------- writing
    def append(self, inserts=(), deletes=(), *, graph_version: int) -> int:
        """Log one mutation batch (the graph version it will PRODUCE);
        returns the record's byte size. Flushed (and fsynced unless
        constructed with ``fsync=False``) before returning — once this
        returns, a crash-restart replay re-applies the batch."""
        payload = json.dumps(
            {"graph_version": int(graph_version),
             "inserts": [[int(u), int(v), float(q)] for u, v, q in inserts],
             "deletes": [[int(u), int(v)] for u, v in deletes]},
            sort_keys=True).encode()
        rec = (len(payload).to_bytes(4, "little")
               + zlib.crc32(payload).to_bytes(4, "little") + payload)
        with self._open(self.path, "ab") as f:
            f.write(rec)
            f.flush()
            if self._fsync:
                try:
                    os.fsync(f.fileno())
                except (AttributeError, OSError):
                    pass
        return len(rec)

    def truncate(self, base_version: int) -> None:
        """Drop every record (compaction folded them into the base
        index) and restart the log at ``base_version``. Atomic."""
        self._reset(int(base_version))

    # ------------------------------------------------------------- reading
    def records(self) -> list[dict]:
        """Every committed record, oldest first (torn tail excluded)."""
        return self._scan()[1]

    def replay(self, start_version: int = 0) -> list[dict]:
        """The records a warm start from ``start_version`` must apply,
        in order. Raises `WALReplayError` when the log no longer reaches
        back to ``start_version`` (compacted past the checkpoint)."""
        base, records, _torn = self._scan()
        if start_version < base:
            raise WALReplayError(
                f"{self.path!r}: checkpoint at graph version "
                f"{start_version} predates the WAL base {base} — the log "
                "was compacted past it; warm-start from a newer "
                "checkpoint")
        return [r for r in records if r["graph_version"] > start_version]

"""Sharded checkpointing: pytree -> per-leaf npz shards + JSON manifest.

The manifest records tree structure, shapes/dtypes, the mesh the state was
saved under, and a data-pipeline cursor — enough to restore onto a
*different* device count (elastic re-mesh): leaves are saved unsharded
(gathered) here on CPU; on a real multi-host run each host writes its local
shard and the manifest carries the global offsets (layout documented in
DESIGN.md). Atomicity: writes go to <dir>.tmp then os.replace."""
from __future__ import annotations

import json
import os
import shutil

import numpy as np

import jax


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ----------------------------------------------------------------- save
    def save(self, step: int, state, extra: dict | None = None) -> str:
        flat, _ = _flatten_with_paths(state)
        path = os.path.join(self.dir, f"step_{step:08d}")
        tmp = path + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        arrays = {k: np.asarray(v) for k, v in flat.items()}
        np.savez(os.path.join(tmp, "state.npz"), **arrays)
        manifest = {
            "step": step,
            "leaves": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                       for k, a in arrays.items()},
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.replace(tmp, path)
        self._gc()
        return path

    # -------------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        return steps[-1] if steps else None

    def restore(self, like_state, step: int | None = None):
        """Restore into the structure of `like_state` (shapes must match —
        the elastic path re-shards by loading full arrays and letting jit's
        in_shardings re-partition them)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, "state.npz"))
        flat, treedef = _flatten_with_paths(like_state)
        restored = {}
        for k, leaf in flat.items():
            a = data[k]
            want = tuple(getattr(leaf, "shape", np.shape(leaf)))
            if tuple(a.shape) != want:
                raise ValueError(f"shape mismatch for {k}: {a.shape} vs {want}")
            restored[k] = a
        leaves = [restored[k] for k in flat.keys()]
        return jax.tree_util.tree_unflatten(treedef, leaves), step

    def manifest(self, step: int) -> dict:
        path = os.path.join(self.dir, f"step_{step:08d}", "manifest.json")
        with open(path) as f:
            return json.load(f)

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

"""Fault tolerance: checkpoint/restart runner, heartbeat-based failure
detection, straggler mitigation, and elastic re-mesh.

This container is single-host, so node failure is *simulated* (exceptions
injected by tests / a failure_schedule); the control flow is exactly what a
multi-host launcher runs per host:

  loop:
    wait for all heartbeats (timeout -> declare peer dead)
    if dead peers: re-mesh to the surviving device set, restore latest ckpt
    run step; on local exception: restore latest ckpt and continue
    observe step time; persistent straggler -> request re-shard
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable, Optional

from .ckpt import CheckpointManager
from ..train.loop import StepTimeMonitor


@dataclasses.dataclass
class Heartbeat:
    """Simulated heartbeat table for N workers."""
    n_workers: int
    timeout_s: float = 10.0
    last: dict = dataclasses.field(default_factory=dict)

    def beat(self, worker: int, t: Optional[float] = None):
        self.last[worker] = time.monotonic() if t is None else t

    def dead_workers(self, now: Optional[float] = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return [w for w in range(self.n_workers)
                if now - self.last.get(w, -1e18) > self.timeout_s]


class FaultTolerantRunner:
    """Wraps a train step with restart-on-failure + straggler accounting.

    failure_schedule: {step: Exception} injected before the step runs
    (tests); in production the exception comes from the collective layer.
    remesh_fn: called with the surviving worker count when a peer dies;
    returns a (train_step, params, opt_state) rebuilt for the smaller mesh
    (elastic scaling)."""

    def __init__(self, train_step: Callable, params, opt_state,
                 ckpt: CheckpointManager, *, ckpt_every: int = 5,
                 max_restarts: int = 10,
                 failure_schedule: Optional[dict] = None,
                 heartbeat: Optional[Heartbeat] = None,
                 remesh_fn: Optional[Callable] = None):
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.failures = dict(failure_schedule or {})
        self.heartbeat = heartbeat
        self.remesh_fn = remesh_fn
        self.monitor = StepTimeMonitor()
        self.restarts = 0
        self.step = 0
        self.log: list[dict] = []

    def _restore(self):
        state, step = self.ckpt.restore(
            {"params": self.params, "opt_state": self.opt_state})
        self.params = state["params"]
        self.opt_state = state["opt_state"]
        self.step = step
        self.restarts += 1
        if self.restarts > self.max_restarts:
            raise RuntimeError("restart budget exhausted")

    def run(self, batches: Iterable, max_steps: int,
            batch_for_step: Optional[Callable] = None):
        """batch_for_step(step) lets restarts replay the right batch
        (deterministic data cursor)."""
        it = iter(batches) if batches is not None else None
        self.ckpt.save(self.step, {"params": self.params,
                                   "opt_state": self.opt_state})
        while self.step < max_steps:
            if self.heartbeat:
                dead = self.heartbeat.dead_workers()
                if dead and self.remesh_fn:
                    self.train_step, self.params, self.opt_state = \
                        self.remesh_fn(self.heartbeat.n_workers - len(dead))
                    self.heartbeat = Heartbeat(
                        self.heartbeat.n_workers - len(dead),
                        self.heartbeat.timeout_s)
                    self._restore()
            batch = (batch_for_step(self.step) if batch_for_step
                     else next(it))
            t0 = time.perf_counter()
            try:
                if self.step in self.failures:
                    raise self.failures.pop(self.step)
                self.params, self.opt_state, m = self.train_step(
                    self.params, self.opt_state, batch)
            except Exception as e:  # noqa: BLE001 — restart on any step fault
                self.log.append({"step": self.step, "event": "failure",
                                 "error": repr(e)})
                self._restore()
                continue
            dt = time.perf_counter() - t0
            straggler = self.monitor.observe(dt)
            self.log.append({"step": self.step, "event": "step",
                             "loss": float(m["loss"]), "time_s": dt,
                             "straggler": straggler})
            self.step += 1
            if self.step % self.ckpt_every == 0:
                self.ckpt.save(self.step, {"params": self.params,
                                           "opt_state": self.opt_state})
        return self.log


# ---------------------------------------------------------------------------
# Write-path fault injection for the persisted WC-Index
# (`ckpt.save_packed_index`). The saver takes an injectable ``_open``; this
# one returns files that die after a byte budget, simulating a crash in the
# middle of the tmp-file write. The contract under test: the target path is
# either absent or a complete previous version — never a torn file — because
# the saver only `os.replace`s a fully-written tmp.


class MidWriteCrash(RuntimeError):
    """Injected crash while bytes were still being written."""


def crashing_open(fail_after_bytes: int):
    """An ``open()`` substitute whose writes raise `MidWriteCrash` once
    ``fail_after_bytes`` have been flushed (the partial prefix IS written,
    like a real torn write)."""

    class _CrashingFile:
        def __init__(self, f):
            self._f = f
            self._left = int(fail_after_bytes)

        def write(self, data):
            if len(data) > self._left:
                self._f.write(data[:self._left])
                self._f.flush()
                self._left = 0
                raise MidWriteCrash(
                    f"injected crash after {fail_after_bytes} bytes")
            self._left -= len(data)
            return self._f.write(data)

        def __enter__(self):
            return self

        def __exit__(self, *exc):
            self._f.close()
            return False

        def __getattr__(self, name):
            return getattr(self._f, name)

    def _open(path, mode="wb"):
        return _CrashingFile(open(path, mode))

    return _open

"""Fault tolerance: checkpoint/restart runner, heartbeat-based failure
detection, straggler mitigation, and elastic re-mesh.

This container is single-host, so node failure is *simulated* (exceptions
injected by tests / a failure_schedule); the control flow is exactly what a
multi-host launcher runs per host:

  loop:
    wait for all heartbeats (timeout -> declare peer dead)
    if dead peers: re-mesh to the surviving device set, restore latest ckpt
    run step; on local exception: restore latest ckpt and continue
    observe step time; persistent straggler -> request re-shard
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Iterable, Optional

import numpy as np

from .ckpt import CheckpointManager
from ..core.query import PendingResult
from ..train.loop import StepTimeMonitor


@dataclasses.dataclass
class Heartbeat:
    """Simulated heartbeat table for N workers."""
    n_workers: int
    timeout_s: float = 10.0
    last: dict = dataclasses.field(default_factory=dict)

    def beat(self, worker: int, t: Optional[float] = None):
        self.last[worker] = time.monotonic() if t is None else t

    def dead_workers(self, now: Optional[float] = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return [w for w in range(self.n_workers)
                if now - self.last.get(w, -1e18) > self.timeout_s]


class FaultTolerantRunner:
    """Wraps a train step with restart-on-failure + straggler accounting.

    failure_schedule: {step: Exception} injected before the step runs
    (tests); in production the exception comes from the collective layer.
    remesh_fn: called with the surviving worker count when a peer dies;
    returns a (train_step, params, opt_state) rebuilt for the smaller mesh
    (elastic scaling)."""

    def __init__(self, train_step: Callable, params, opt_state,
                 ckpt: CheckpointManager, *, ckpt_every: int = 5,
                 max_restarts: int = 10,
                 failure_schedule: Optional[dict] = None,
                 heartbeat: Optional[Heartbeat] = None,
                 remesh_fn: Optional[Callable] = None):
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.failures = dict(failure_schedule or {})
        self.heartbeat = heartbeat
        self.remesh_fn = remesh_fn
        self.monitor = StepTimeMonitor()
        self.restarts = 0
        self.step = 0
        self.log: list[dict] = []

    def _restore(self):
        state, step = self.ckpt.restore(
            {"params": self.params, "opt_state": self.opt_state})
        self.params = state["params"]
        self.opt_state = state["opt_state"]
        self.step = step
        self.restarts += 1
        if self.restarts > self.max_restarts:
            raise RuntimeError("restart budget exhausted")

    def run(self, batches: Iterable, max_steps: int,
            batch_for_step: Optional[Callable] = None):
        """batch_for_step(step) lets restarts replay the right batch
        (deterministic data cursor)."""
        it = iter(batches) if batches is not None else None
        self.ckpt.save(self.step, {"params": self.params,
                                   "opt_state": self.opt_state})
        while self.step < max_steps:
            if self.heartbeat:
                dead = self.heartbeat.dead_workers()
                if dead and self.remesh_fn:
                    self.train_step, self.params, self.opt_state = \
                        self.remesh_fn(self.heartbeat.n_workers - len(dead))
                    self.heartbeat = Heartbeat(
                        self.heartbeat.n_workers - len(dead),
                        self.heartbeat.timeout_s)
                    self._restore()
            batch = (batch_for_step(self.step) if batch_for_step
                     else next(it))
            t0 = time.perf_counter()
            try:
                if self.step in self.failures:
                    raise self.failures.pop(self.step)
                self.params, self.opt_state, m = self.train_step(
                    self.params, self.opt_state, batch)
            except Exception as e:  # noqa: BLE001 — restart on any step fault
                self.log.append({"step": self.step, "event": "failure",
                                 "error": repr(e)})
                self._restore()
                continue
            dt = time.perf_counter() - t0
            straggler = self.monitor.observe(dt)
            self.log.append({"step": self.step, "event": "step",
                             "loss": float(m["loss"]), "time_s": dt,
                             "straggler": straggler})
            self.step += 1
            if self.step % self.ckpt_every == 0:
                self.ckpt.save(self.step, {"params": self.params,
                                           "opt_state": self.opt_state})
        return self.log


# ---------------------------------------------------------------------------
# Write-path fault injection for the persisted WC-Index
# (`ckpt.save_packed_index`). The saver takes an injectable ``_open``; this
# one returns files that die after a byte budget, simulating a crash in the
# middle of the tmp-file write. The contract under test: the target path is
# either absent or a complete previous version — never a torn file — because
# the saver only `os.replace`s a fully-written tmp.


class MidWriteCrash(RuntimeError):
    """Injected crash while bytes were still being written."""


def crashing_open(fail_after_bytes: int):
    """An ``open()`` substitute whose writes raise `MidWriteCrash` once
    ``fail_after_bytes`` have been flushed (the partial prefix IS written,
    like a real torn write)."""

    class _CrashingFile:
        def __init__(self, f):
            self._f = f
            self._left = int(fail_after_bytes)

        def write(self, data):
            if len(data) > self._left:
                self._f.write(data[:self._left])
                self._f.flush()
                self._left = 0
                raise MidWriteCrash(
                    f"injected crash after {fail_after_bytes} bytes")
            self._left -= len(data)
            return self._f.write(data)

        def __enter__(self):
            return self

        def __exit__(self, *exc):
            self._f.close()
            return False

        def __getattr__(self, name):
            return getattr(self._f, name)

    def _open(path, mode="wb"):
        return _CrashingFile(open(path, mode))

    return _open


# ---------------------------------------------------------------------------
# Chaos harness (docs/resilience.md §chaos): seeded fault injection across
# every layer the serving stack learned to survive — engine raises, flush
# hangs, arena bit-flips, torn WAL records. `FaultyEngine` wraps any query
# engine (WCSDServer's ``engine_wrapper=`` re-applies it across rebuilds,
# so demoted/promoted engines stay under injection); `FaultSchedule` makes
# the whole run reproducible from one seed. The byte-flip helpers corrupt
# saved indices and live arrays for the integrity tests.


class InjectedEngineError(RuntimeError):
    """A chaos-injected engine failure (stands in for a sharded gather
    OOM, a poisoned compile cache, a dead collective, ...)."""


class FaultSchedule:
    """Seeded draw-by-draw fault plan.

    ``rates`` maps a fault kind to its probability per draw (e.g.
    ``{"engine_raise": 0.05, "flush_hang": 0.02}``); ``fixed`` pins a
    kind to a specific draw index (deterministic placement for tests:
    ``{7: "engine_raise"}``). The same seed replays the same faults."""

    def __init__(self, seed: int = 0, rates: dict | None = None,
                 fixed: dict | None = None):
        import numpy as np
        self._rng = np.random.default_rng(seed)
        self.rates = dict(rates or {})
        self.fixed = dict(fixed or {})
        self.draws = 0
        self.injected: list[tuple[int, str]] = []  # (draw, kind) audit log

    def draw(self) -> str | None:
        """The fault kind for this draw, or None (healthy). One draw per
        protected operation."""
        i = self.draws
        self.draws += 1
        kind = self.fixed.get(i)
        if kind is None:
            for k, p in self.rates.items():
                if p > 0 and self._rng.random() < p:
                    kind = k
                    break
            else:
                self._rng.random()  # keep the stream aligned when rateless
        if kind is not None:
            self.injected.append((i, kind))
        return kind


class _HangingResult(PendingResult):
    """A handle that is never ready: `ready()` stays False (the wedged
    collective never lands), while `wait()` still delegates — so only a
    watchdog with a deadline can recover; a deadline-less server would
    block in wait() and get the (eventual) answer."""

    def __init__(self, inner: PendingResult):
        super().__init__(inner.wait, deps=())
        self.deadline = getattr(inner, "deadline", None)

    def ready(self) -> bool:
        return False


class FaultyEngine:
    """Chaos wrapper around a query engine: every dispatch draws from the
    `FaultSchedule` and either raises (`engine_raise`), returns a handle
    that never reports ready (`flush_hang`), or passes through. All other
    attributes (num_levels, layout, ...) delegate to the wrapped engine,
    so the server cannot tell it apart from the real one."""

    def __init__(self, engine, schedule: FaultSchedule):
        self._engine = engine
        self._schedule = schedule

    def __getattr__(self, name):
        return getattr(self._engine, name)

    def _protect(self, dispatch, *args):
        kind = self._schedule.draw()
        if kind == "engine_raise":
            raise InjectedEngineError(
                f"injected engine raise (draw {self._schedule.draws - 1})")
        handle = dispatch(*args)
        if kind == "flush_hang":
            return _HangingResult(handle)
        return handle

    def query_async(self, s, t, wl):
        qa = getattr(self._engine, "query_async", None)
        if qa is None:
            def dispatch(s=s, t=t, wl=wl):
                return PendingResult(lambda: self._engine.query(s, t, wl))
            return self._protect(dispatch)
        return self._protect(qa, s, t, wl)

    def query_profile_async(self, s, t):
        qa = getattr(self._engine, "query_profile_async", None)
        if qa is None:
            def dispatch(s=s, t=t):
                return PendingResult(
                    lambda: self._engine.query_profile(s, t))
            return self._protect(dispatch)
        return self._protect(qa, s, t)


# --------------------------------------------------------------- bit flips


def flip_byte_on_disk(path: str, offset: int, mask: int = 0xFF) -> int:
    """XOR one byte of a file in place (bit rot / torn copy injection);
    returns the original byte so the caller can restore it."""
    with open(path, "r+b") as f:
        f.seek(offset)
        orig = f.read(1)[0]
        f.seek(offset)
        f.write(bytes([orig ^ (mask & 0xFF)]))
    return orig


def flip_array_cell(arr, flat_index: int = 0, mask: int = 1):
    """XOR one byte of a live numpy array in place (in-memory corruption
    of an arena tile). Returns an undo closure restoring the byte —
    chaos steps corrupt, observe the typed integrity error, and heal."""
    flat = arr.reshape(-1).view(np.uint8)
    i = int(flat_index) % flat.size
    orig = int(flat[i])
    flat[i] = orig ^ (mask & 0xFF)

    def undo():
        flat[i] = orig
    return undo


def tear_file_tail(path: str, nbytes: int) -> int:
    """Truncate the last ``nbytes`` of a file (a torn append — the WAL's
    mid-crash tail). Returns the new size."""
    size = os.path.getsize(path)
    new = max(0, size - int(nbytes))
    with open(path, "r+b") as f:
        f.truncate(new)
    return new


# ------------------------------------------------------------ chaos driver


def run_chaos_schedule(server_kwargs: dict | None = None, *, steps: int = 200,
                       seed: int = 0, rates: dict | None = None,
                       fixed: dict | None = None,
                       n_nodes: int = 36, avg_degree: float = 3.0,
                       num_levels: int = 4, workdir: str,
                       crash_step: int | None = None,
                       verbose: bool = False) -> dict:
    """The seeded end-to-end chaos schedule (ISSUE 10 acceptance): ``steps``
    randomized steps mixing submits, profile submits, result reads, polls,
    graph updates, injected engine raises/hangs, live bit-flip integrity
    probes and torn-WAL probes — plus, at ``crash_step``, a simulated crash
    between the WAL append and the index apply followed by a
    checkpoint+WAL-replay warm restart that REPLACES the server.

    Every answered query is checked against the BFS oracle
    (`constrained_distance_grid`) for exactly the graph version stamped on
    the answer; the run then goes fault-free until the server climbs back
    to its top (non-degraded) mode. Raises on any mismatch, lost request,
    or double delivery; returns a summary dict for reporting."""
    from ..core.baselines import constrained_distance_grid
    from ..core.generators import erdos_renyi
    from ..core.resilience import (IndexIntegrityError, UnknownRequestError)
    from ..core.serve import WCSDServer
    from ..core.wc_index import build_wc_index, as_packed_index
    from .ckpt import save_packed_index, load_packed_index

    server_kwargs = dict(server_kwargs or {})
    rates = dict(rates if rates is not None
                 else {"engine_raise": 0.06, "flush_hang": 0.03})
    if fixed is None:
        # guaranteed coverage on top of the random rates: a retry chain
        # long enough to exhaust the budget (max_retries=2 -> draws 6-8
        # demote one rung, draw 9 retries on the demoted engine) and a
        # deterministic hang for the timeout path
        fixed = {6: "engine_raise", 7: "engine_raise", 8: "engine_raise",
                 9: "engine_raise", 18: "flush_hang"}
    rng = np.random.default_rng(seed + 1)
    sched = FaultSchedule(seed=seed, rates=rates, fixed=fixed)

    g0 = erdos_renyi(n_nodes, avg_degree, num_levels=num_levels, seed=seed)
    idx0 = as_packed_index(build_wc_index(g0))
    os.makedirs(workdir, exist_ok=True)
    ckpt_path = os.path.join(workdir, "chaos_base.wcx")
    wal_path = os.path.join(workdir, "chaos_wal.log")
    save_packed_index(ckpt_path, idx0, graph_version=0)

    kwargs = dict(layout="csr", backend="device", dispatch="ragged",
                  compact_threshold=None,   # keep the WAL reaching back to v0
                  flush_timeout_ms=50.0, max_retries=2,
                  backoff_base_ms=0.05, probe_interval=3, max_batch=32)
    kwargs.update(server_kwargs)
    kwargs.update(graph=g0, wal_path=wal_path,
                  engine_wrapper=lambda e: FaultyEngine(e, sched))
    srv = WCSDServer(idx0, **kwargs)

    graphs = {0: g0}          # version -> Graph (old objects stay valid)
    grids: dict = {}

    def grid(ver):
        if ver not in grids:
            grids[ver] = constrained_distance_grid(graphs[ver])
        return grids[ver]

    outstanding: dict = {}        # rid -> (s, t, wl)
    outstanding_prof: dict = {}   # rid -> (s, t)
    summary = {"submitted": 0, "answered": 0, "updates": 0, "crashes": 0,
               "integrity_probes": 0, "wal_probes": 0}
    # retry/mode counters survive the crash-restart (the dead server's
    # stats die with it; the run-level totals must not)
    dead_stats = {"timeout_retries": 0, "error_retries": 0, "exhausted": 0,
                  "demotions": 0, "promotions": 0, "wal_appends": 0}

    def check_scalar(rid):
        s, t, wl = outstanding.pop(rid)
        val, ver, mode = srv.result_full(rid)
        exp = int(grid(ver)[s, t, wl])
        if int(val) != exp:
            raise AssertionError(
                f"chaos mismatch rid={rid} ({s},{t},{wl}) v{ver} "
                f"mode={mode}: got {val}, oracle {exp}")
        try:                      # double delivery must be impossible
            srv.result(rid)
            raise AssertionError(f"rid {rid} delivered twice")
        except UnknownRequestError:
            pass
        summary["answered"] += 1

    def check_profile(rid):
        s, t = outstanding_prof.pop(rid)
        prof, ver, mode = srv.profile_result_full(rid)
        exp = grid(ver)[s, t, :]
        if not np.array_equal(np.asarray(prof), exp):
            raise AssertionError(
                f"chaos profile mismatch rid={rid} ({s},{t}) v{ver} "
                f"mode={mode}")
        summary["answered"] += 1

    def drain_all():
        srv.flush()
        for rid in list(outstanding):
            check_scalar(rid)
        for rid in list(outstanding_prof):
            check_profile(rid)

    def random_mutation():
        cur = srv.index.graph
        if rng.random() < 0.5 and cur.num_edges > 4:
            e = int(rng.integers(cur.num_edges))
            # src array from indptr: find the edge's endpoint pair
            u = int(np.searchsorted(cur.indptr, e, side="right") - 1)
            v = int(cur.nbr[e])
            return {"deletes": [(u, v)]}
        u = int(rng.integers(n_nodes))
        v = int(rng.integers(n_nodes))
        if u == v:
            v = (v + 1) % n_nodes
        q = float(cur.levels[int(rng.integers(len(cur.levels)))])
        return {"inserts": [(u, v, q)]}

    for step in range(int(steps)):
        if crash_step is not None and step == crash_step:
            # deliver everything, then crash between WAL append and apply
            drain_all()
            mut = random_mutation()
            pre_crash_version = srv.graph_version + 1
            srv.wal.append(mut.get("inserts", ()), mut.get("deletes", ()),
                           graph_version=pre_crash_version)
            from ..core.graph import mutate_edges
            graphs[pre_crash_version] = mutate_edges(
                graphs[srv.graph_version], inserts=mut.get("inserts", ()),
                deletes=mut.get("deletes", ()))
            # warm restart: checkpoint (v0) + WAL tail replay
            for k in dead_stats:
                dead_stats[k] += getattr(srv.stats, k)
            base, _hdr = load_packed_index(ckpt_path)
            srv = WCSDServer(base, **kwargs)
            replayed = srv.replay_wal()
            if srv.graph_version != pre_crash_version:
                raise AssertionError(
                    f"replay converged to v{srv.graph_version}, "
                    f"pre-crash was v{pre_crash_version}")
            summary["crashes"] += 1
            summary["replayed_records"] = replayed
            if verbose:
                print(f"[chaos {step}] crash+restart: replayed {replayed} "
                      f"records to v{srv.graph_version}", flush=True)
            continue
        r = rng.random()
        if r < 0.45:
            s = int(rng.integers(n_nodes)); t = int(rng.integers(n_nodes))
            wl = int(rng.integers(num_levels + 1))
            outstanding[srv.submit(s, t, wl)] = (s, t, wl)
            summary["submitted"] += 1
        elif r < 0.55:
            s = int(rng.integers(n_nodes)); t = int(rng.integers(n_nodes))
            outstanding_prof[srv.submit_profile(s, t)] = (s, t)
            summary["submitted"] += 1
        elif r < 0.75:
            if outstanding:
                check_scalar(next(iter(outstanding)))
            elif outstanding_prof:
                check_profile(next(iter(outstanding_prof)))
        elif r < 0.82:
            srv.poll()
        elif r < 0.88:
            drain_all()
            srv.apply_updates(**random_mutation())
            graphs[srv.graph_version] = srv.index.graph
            summary["updates"] += 1
        elif r < 0.94:
            # bit-flip: corruption must surface as the typed integrity
            # error, never a wrong distance — flip, observe, heal,
            # re-verify. Live arrays are flipped in place; a warm-started
            # (read-only mmap) base is probed through its on-disk file.
            base_idx = srv.index.base
            base_idx.verify_integrity()
            arr = base_idx.labels.dist
            if arr.flags.writeable:
                undo = flip_array_cell(arr, int(rng.integers(arr.size * 4)))
                try:
                    base_idx.verify_integrity()
                    raise AssertionError("bit flip passed verify_integrity")
                except IndexIntegrityError:
                    pass
                undo()
                base_idx.verify_integrity()
            else:
                import shutil
                corrupt = os.path.join(workdir, "corrupt.wcx")
                shutil.copyfile(ckpt_path, corrupt)
                flip_byte_on_disk(
                    corrupt, os.path.getsize(corrupt)
                    - 1 - int(rng.integers(64)))
                try:
                    load_packed_index(corrupt)
                    raise AssertionError("disk bit flip loaded silently")
                except IndexIntegrityError:
                    pass
                os.remove(corrupt)
            summary["integrity_probes"] += 1
        else:
            # torn-WAL probe on a COPY (the live log stays intact): a
            # mid-append crash tail must be tolerated, not fatal
            import shutil
            from .ckpt import UpdateWAL
            torn = os.path.join(workdir, "torn_wal.log")
            shutil.copyfile(wal_path, torn)
            committed = len(srv.wal.records())
            with open(torn, "ab") as f:     # half an append, then "crash"
                f.write(b"\x99\x00\x00\x00\xde\xad")
            kept = len(UpdateWAL(torn).records())
            if kept != committed:
                raise AssertionError(
                    f"torn WAL tail changed committed records: "
                    f"{kept} != {committed}")
            os.remove(torn)
            summary["wal_probes"] += 1

    # quiet tail: no more injections; drain and climb back to the top mode
    sched.rates = {}
    drain_all()
    guard = 0
    while srv.mode_index > 0:
        guard += 1
        if guard > 100:
            raise AssertionError(
                f"server stuck in degraded mode {srv.mode!r}")
        s = int(rng.integers(n_nodes)); t = int(rng.integers(n_nodes))
        wl = int(rng.integers(num_levels + 1))
        outstanding[srv.submit(s, t, wl)] = (s, t, wl)
        summary["submitted"] += 1
        drain_all()
    if srv.mode != "primary":
        raise AssertionError(f"final mode {srv.mode!r}, expected primary")
    if outstanding or outstanding_prof:
        raise AssertionError("requests lost: "
                             f"{len(outstanding)} scalar, "
                             f"{len(outstanding_prof)} profile")
    st = srv.stats
    summary.update(
        final_mode=srv.mode, graph_version=srv.graph_version,
        injected=len(sched.injected),
        **{k: v + getattr(st, k) for k, v in dead_stats.items()})
    return summary

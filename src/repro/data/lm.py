"""Synthetic LM data pipeline: a deterministic zipf-ish token stream with
document structure, packed into fixed-length sequences (causal labels =
inputs shifted left, -1 at document pads). Deterministic per (seed, step) so
fault-tolerant restarts can resume the cursor exactly."""
from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0,
                 doc_len_mean: int = 512):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = batch
        self.seed = seed
        self.doc_len_mean = doc_len_mean
        self.step = 0

    def set_cursor(self, step: int):
        self.step = step

    def next_batch(self) -> dict:
        rng = np.random.default_rng((self.seed, self.step))
        # zipf-ish marginal over the vocab (heavy head like natural text)
        n = self.batch * (self.seq_len + 1)
        u = rng.random(n)
        toks = np.minimum((self.vocab - 1) * u ** 3, self.vocab - 1)
        toks = toks.astype(np.int32).reshape(self.batch, self.seq_len + 1)
        # inject EOD boundaries
        eod = rng.random((self.batch, self.seq_len + 1)) < 1.0 / self.doc_len_mean
        toks = np.where(eod, 0, toks)
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def __iter__(self):
        while True:
            yield self.next_batch()

"""Criteo-like synthetic recsys stream: per-field categorical ids with
zipf-ish popularity, click labels correlated with a hidden linear model (so
training actually reduces loss), deterministic per (seed, step)."""
from __future__ import annotations

import numpy as np


class CTRStream:
    def __init__(self, field_vocabs, field_offsets, batch: int, seed: int = 0):
        self.vocabs = np.asarray(field_vocabs, dtype=np.int64)
        self.offsets = np.asarray(field_offsets, dtype=np.int64)
        self.batch = batch
        self.seed = seed
        self.step = 0
        rng = np.random.default_rng(seed + 1)
        self._field_w = rng.standard_normal(len(field_vocabs)) * 3.0

    def set_cursor(self, step: int):
        self.step = step

    def next_batch(self) -> dict:
        rng = np.random.default_rng((self.seed, self.step))
        u = rng.random((self.batch, len(self.vocabs)))
        local = np.minimum((self.vocabs[None, :] - 1) * u ** 2,
                           self.vocabs[None, :] - 1).astype(np.int64)
        ids = (local + self.offsets[None, :]).astype(np.int32)
        # hidden signal: popularity-weighted field mix
        sig = ((local / self.vocabs[None, :]) * self._field_w[None, :]).sum(1)
        p = 1.0 / (1.0 + np.exp(-2.0 * (sig - sig.mean())))
        labels = (rng.random(self.batch) < p).astype(np.int32)
        self.step += 1
        return {"ids": ids, "labels": labels}

    def __iter__(self):
        while True:
            yield self.next_batch()

"""Graph data pipeline: full-batch loaders, batched small graphs, and a real
CSR fanout neighbor sampler (GraphSAGE-style, required by the minibatch_lg
shape) — plus the WC-INDEX integration: quality-constrained distance
encodings as node features (the paper's technique feeding the GNN substrate).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.graph import Graph, INF_DIST
from ..core.wc_index import WCIndex


# ----------------------------------------------------------- fanout sampler
class NeighborSampler:
    """Uniform fanout sampling over CSR adjacency, numpy-vectorized.

    sample(seeds, fanouts) returns a *block*: the union node set (seeds
    first), a remapped edge list (src/dst into the union set), and the seed
    count — the standard GraphSAGE block layout."""

    def __init__(self, g: Graph, seed: int = 0):
        self.g = g
        self.rng = np.random.default_rng(seed)

    def _sample_layer(self, frontier: np.ndarray, fanout: int):
        g = self.g
        deg = (g.indptr[frontier + 1] - g.indptr[frontier]).astype(np.int64)
        # with replacement when deg > 0 (uniform), skip deg == 0
        has = deg > 0
        f = frontier[has]
        d = deg[has]
        if len(f) == 0:
            z = np.zeros(0, dtype=np.int32)
            return z, z
        offs = self.rng.integers(0, d[:, None], size=(len(f), fanout))
        eidx = self.g.indptr[f][:, None] + offs
        nbrs = self.g.nbr[eidx]                        # [F, fanout]
        src = nbrs.reshape(-1).astype(np.int32)
        dst = np.repeat(f.astype(np.int32), fanout)
        return src, dst

    def sample(self, seeds: np.ndarray, fanouts: list[int]) -> dict:
        seeds = np.asarray(seeds, dtype=np.int32)
        all_src, all_dst = [], []
        frontier = seeds
        for fo in fanouts:
            src, dst = self._sample_layer(frontier, fo)
            all_src.append(src)
            all_dst.append(dst)
            frontier = np.unique(src)
        src = np.concatenate(all_src) if all_src else np.zeros(0, np.int32)
        dst = np.concatenate(all_dst) if all_dst else np.zeros(0, np.int32)
        nodes, inv = np.unique(np.concatenate([seeds, src, dst]),
                               return_inverse=True)
        # remap so seeds occupy the first len(seeds) slots
        order = np.concatenate([
            np.searchsorted(nodes, seeds),
            np.setdiff1d(np.arange(len(nodes)),
                         np.searchsorted(nodes, seeds))])
        pos = np.empty(len(nodes), dtype=np.int64)
        pos[order] = np.arange(len(nodes))
        k = len(seeds)
        return {
            "nodes": nodes[order].astype(np.int32),
            "edges_src": pos[np.searchsorted(nodes, src)].astype(np.int32),
            "edges_dst": pos[np.searchsorted(nodes, dst)].astype(np.int32),
            "num_seeds": k,
        }


def pad_block(block: dict, num_nodes: int, num_edges: int) -> dict:
    """Pad a sampled block to static shapes (drop overflow, pad with a
    sink node that receives no gradients)."""
    n = len(block["nodes"])
    e = len(block["edges_src"])
    out = dict(block)
    out["nodes"] = np.resize(block["nodes"], num_nodes)
    if n < num_nodes:
        out["nodes"][n:] = 0
    src = block["edges_src"][:num_edges]
    dst = block["edges_dst"][:num_edges]
    pad_e = num_edges - len(src)
    if pad_e > 0:
        src = np.concatenate([src, np.full(pad_e, num_nodes - 1, np.int32)])
        dst = np.concatenate([dst, np.full(pad_e, num_nodes - 1, np.int32)])
    out["edges_src"], out["edges_dst"] = src, dst
    return out


# ------------------------------------------------- WC-INDEX feature plug-in
def distance_encoding(idx: WCIndex, nodes: np.ndarray,
                      landmarks: np.ndarray, w_levels: list[int],
                      clip: int = 32) -> np.ndarray:
    """Quality-constrained distance encodings: feature[i, (j, l)] =
    dist_w_l(node_i, landmark_j) (clipped). This is the paper's index used
    as a first-class feature pipeline for the GNN substrate."""
    feats = []
    for l in w_levels:
        for lm in landmarks:
            s = np.asarray(nodes, dtype=np.int64)
            t = np.full(len(s), lm, dtype=np.int64)
            d = idx.query_batch(s, t, np.full(len(s), l, np.int32))
            feats.append(np.minimum(d, clip))
    return np.stack(feats, axis=1).astype(np.float32)


# ------------------------------------------------------ synthetic features
def synthetic_node_task(g: Graph, d_feat: int, n_classes: int,
                        seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    half = g.edges_src < g.edges_dst
    return {
        "feat": rng.standard_normal((g.num_nodes, d_feat)).astype(np.float32),
        "edges_src": g.edges_src.astype(np.int32),
        "edges_dst": g.edges_dst.astype(np.int32),
        "labels": rng.integers(0, n_classes, g.num_nodes).astype(np.int32),
    }


def synthetic_molecules(batch: int, n_nodes: int, n_edges: int, d_feat: int,
                        seed: int = 0) -> dict:
    """Batched small graphs, flattened with graph_id (molecule shape)."""
    rng = np.random.default_rng(seed)
    N = batch * n_nodes
    src = (rng.integers(0, n_nodes, (batch, n_edges))
           + n_nodes * np.arange(batch)[:, None]).reshape(-1)
    dst = (rng.integers(0, n_nodes, (batch, n_edges))
           + n_nodes * np.arange(batch)[:, None]).reshape(-1)
    return {
        "feat": rng.standard_normal((N, d_feat)).astype(np.float32),
        "pos": (rng.standard_normal((N, 3)) * 2).astype(np.float32),
        "edges_src": src.astype(np.int32),
        "edges_dst": dst.astype(np.int32),
        "graph_id": np.repeat(np.arange(batch), n_nodes).astype(np.int32),
        "labels": rng.integers(0, 2, batch).astype(np.int32),
        "energy": rng.standard_normal(batch).astype(np.float32),
        "forces": rng.standard_normal((N, 3)).astype(np.float32),
    }

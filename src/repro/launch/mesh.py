"""Production mesh definition.

Single pod: (16, 16) = 256 chips, axes ("data", "model") — a TPU v5e pod.
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis carries cross-pod data parallelism (and the pipeline axis for
the GPipe driver). A FUNCTION, not a module constant: importing this module
must never touch jax device state (smoke tests see 1 device; only
launch/dryrun.py sets xla_force_host_platform_device_count)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def batch_axes(multi_pod: bool):
    """Mesh axes that shard the global batch / edge / query dimension."""
    return ("pod", "data") if multi_pod else ("data",)


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW_PER_LINK = 50e9         # bytes/s per link (~ per assignment)
ICI_LINKS = 4                  # 2D torus in-pod links per chip

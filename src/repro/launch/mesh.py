"""Production mesh definition.

Single pod: (16, 16) = 256 chips, axes ("data", "model") — a TPU v5e pod.
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis carries cross-pod data parallelism (and the pipeline axis for
the GPipe driver). A FUNCTION, not a module constant: importing this module
must never touch jax device state (smoke tests see 1 device; only
launch/dryrun.py sets xla_force_host_platform_device_count)."""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def batch_axes(multi_pod: bool):
    """Mesh axes that shard the global batch / edge / query dimension."""
    return ("pod", "data") if multi_pod else ("data",)


def make_serving_mesh(devices=None, *, multi_pod: bool = False):
    """Mesh for the sharded query-serving path, sized to whatever devices
    are actually attached (TPU slice, or virtual host devices under
    ``xla_force_host_platform_device_count``) rather than the fixed
    production pod shapes above.

    Single-pod: (n,) over ("data",). multi_pod=True splits off a leading
    "pod" axis of 2 (requires an even device count) so the ("pod", "data")
    batch-axis spelling is exercised end-to-end. Built via `jax.sharding.
    Mesh` directly — works on every jax version the repo supports, unlike
    `jax.make_mesh(..., axis_types=...)`."""
    devices = list(jax.devices()) if devices is None else list(devices)
    n = len(devices)
    if multi_pod:
        if n % 2:
            raise ValueError(f"multi_pod mesh needs an even device count, "
                             f"got {n}")
        return jax.sharding.Mesh(
            np.array(devices).reshape(2, n // 2), ("pod", "data"))
    return jax.sharding.Mesh(np.array(devices).reshape(n), ("data",))


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW_PER_LINK = 50e9         # bytes/s per link (~ per assignment)
ICI_LINKS = 4                  # 2D torus in-pod links per chip

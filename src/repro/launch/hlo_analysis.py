"""Post-optimization HLO text analysis for the roofline.

XLA's compiled.cost_analysis() counts a while-loop body ONCE, regardless of
trip count (verified empirically) — useless for scan-over-layers programs.
This module re-derives per-device totals from compiled.as_text():

  * computation graph (ENTRY -> while bodies/conds -> fused calls), with a
    per-computation execution multiplier = product of enclosing loop trip
    counts (trips parsed from each loop condition's largest literal);
  * FLOPs: dot/convolution ops only (MXU convention — elementwise VPU work
    excluded, as in standard MFU accounting), 2 * result_elems * K;
  * HBM bytes: sum of (result + operand) bytes over top-level ops (fusion
    internals excluded — they live in registers/VMEM);
  * collective bytes per op kind (all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute); reduce-scatter payload is scaled by
    its replica-group size (the result shape is the post-scatter shard).

All shapes in a post-SPMD module are per-device shards, so every total here
is per-chip. Known approximations are documented in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "iota"}


def shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += _DTYPE_BYTES[dt] * n
    return total


def shape_elems_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 1, []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    n = 1
    for d in dims:
        n *= d
    return n, dims


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    type_str: str
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    defs: dict  # op name -> type string


def _parse_type(rest: str):
    """rest starts right after '= '. Returns (type_str, remainder)."""
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rest[: i + 1], rest[i + 1:].lstrip()
        return rest, ""
    sp = rest.find(" ")
    if sp < 0:
        return rest, ""
    return rest[:sp], rest[sp + 1:]


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->")


def parse_module(text: str) -> tuple[dict, str]:
    """Returns ({name: Computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if (not line.startswith(" ") and line.rstrip().endswith("{")
                and "->" in line and not line.startswith("HloModule")):
            s = line.strip()
            is_entry = s.startswith("ENTRY")
            if is_entry:
                s = s[len("ENTRY"):].strip()
            m = re.match(r"%?([\w\.\-]+)\s*\(", s)
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                if is_entry:
                    entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        type_str, remainder = _parse_type(rest)
        opm = re.match(r"([\w\-]+)", remainder)
        opcode = opm.group(1) if opm else "unknown"
        cur.ops.append(Op(name, opcode, type_str, remainder))
        cur.defs[name] = type_str
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


def _operands(op: Op) -> list[str]:
    """Operand names from the first (...) after the opcode."""
    start = op.rest.find("(")
    if start < 0:
        return []
    depth = 0
    for i in range(start, len(op.rest)):
        if op.rest[i] == "(":
            depth += 1
        elif op.rest[i] == ")":
            depth -= 1
            if depth == 0:
                inner = op.rest[start + 1:i]
                return re.findall(r"%([\w\.\-]+)", inner)
    return []


def _trip_count(cond: Computation) -> int:
    best = 1
    for op in cond.ops:
        for c in re.findall(r"constant\((\d+)\)", op.rest):
            v = int(c)
            if 1 < v <= 10_000_000:
                best = max(best, v)
    return best


def _called(op: Op) -> dict[str, str]:
    """Edges from attributes: kind -> computation name."""
    out = {}
    for attr, kind in (("body", "body"), ("condition", "cond"),
                       ("calls", "call"), ("to_apply", "apply"),
                       ("true_computation", "call"),
                       ("false_computation", "call")):
        m = re.search(attr + r"=%?([\w\.\-]+)", op.rest)
        if m:
            out[m.group(1)] = kind
    m = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
    if m:
        for name in re.findall(r"%([\w\.\-]+)", m.group(1)):
            out[name] = "call"
    return out


def _group_size(op: Op, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", op.rest)
    if m:
        return len(m.group(1).split(","))
    return default


def analyze(text: str, default_group: int = 16) -> dict:
    comps, entry = parse_module(text)
    # multipliers: (computation, counts_bytes) BFS from entry
    mult: dict[str, float] = defaultdict(float)
    bytes_on: dict[str, bool] = defaultdict(bool)
    mult[entry] = 1.0
    bytes_on[entry] = True
    stack = [entry]
    seen_edges = set()
    while stack:
        cname = stack.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for op in comp.ops:
            if op.opcode in ("while", "fusion", "call", "conditional",
                            "reduce", "scatter", "reduce-window", "sort",
                            "map", "select-and-scatter", "all-reduce",
                            "reduce-scatter", "custom-call"):
                for child, kind in _called(op).items():
                    if kind == "apply":
                        continue
                    trips = 1
                    cb = False
                    if kind == "body":
                        condname = _called(op).get
                        # find the matching condition computation
                        cm = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                        trips = _trip_count(comps[cm.group(1)]) \
                            if cm and cm.group(1) in comps else 1
                        cb = bytes_on[cname]
                    elif kind == "cond":
                        cb = False
                    else:
                        cb = False  # fusion internals: no HBM bytes
                    edge = (cname, child)
                    mult[child] += m * trips
                    bytes_on[child] = bytes_on[child] or cb
                    if edge not in seen_edges:
                        seen_edges.add(edge)
                        stack.append(child)

    flops = 0.0
    hbm_bytes = 0.0
    coll = defaultdict(float)
    coll_count = defaultdict(int)
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for op in comp.ops:
            if op.opcode in ("dot", "convolution"):
                n_out, _ = shape_elems_dims(op.type_str)
                # contracted size: lhs shape at lhs_contracting_dims
                ops_ = _operands(op)
                k = 1
                mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
                if mm and ops_:
                    lhs_type = comp.defs.get(ops_[0], "")
                    _, ldims = shape_elems_dims(lhs_type)
                    for d in (mm.group(1).split(",") if mm.group(1) else []):
                        di = int(d)
                        if di < len(ldims):
                            k *= ldims[di]
                flops += m * 2.0 * n_out * k
            base = op.opcode.replace("-start", "")
            if base in _COLLECTIVES and not op.opcode.endswith("-done"):
                b = shape_bytes(op.type_str)
                if base == "reduce-scatter":
                    b *= _group_size(op, default_group)
                coll[base] += m * b
                coll_count[base] += 1
            if bytes_on.get(cname) and op.opcode not in _FREE_OPS:
                b = shape_bytes(op.type_str)
                for o in _operands(op):
                    b += shape_bytes(comp.defs.get(o, ""))
                hbm_bytes += m * b
    return {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "collective_bytes": dict(coll),
        "collective_bytes_total": float(sum(coll.values())),
        "collective_counts": dict(coll_count),
        "n_computations": len(comps),
    }

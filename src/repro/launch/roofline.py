"""Roofline aggregation: read the dry-run JSONs and derive, per (arch x
shape x mesh):

  compute    = HLO_FLOPs / peak_FLOPs_per_chip          (197 TF/s bf16 v5e)
  memory     = HLO_bytes / HBM_bw_per_chip              (819 GB/s)
  collective = collective_bytes / ICI_link_bw           (50 GB/s, 1 link
               conservative; shapes in a post-SPMD module are per-chip, so
               no further division by chip count)

All terms are seconds-per-step per chip; the max identifies the bottleneck.
MODEL_FLOPS (6*N*D / 2*N*D analytic) over HLO_FLOPs*chips measures how much
compiled compute is useful (remat/dispatch overhead shows up here).

Usage: python -m repro.launch.roofline --dir experiments/dryrun [--csv out]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def load_records(d: str) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def roofline_row(rec: dict) -> dict:
    hlo = rec["hlo"]
    chips = rec["chips"]
    t_c = hlo["flops"] / PEAK_FLOPS
    t_m = hlo["hbm_bytes"] / HBM_BW
    t_x = hlo["collective_bytes_total"] / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    mf = rec["meta"].get("model_flops", 0.0)
    useful = mf / (hlo["flops"] * chips) if hlo["flops"] else 0.0
    peak_gib = rec["memory"]["peak_bytes"] / 2**30
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"], "chips": chips,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "bottleneck": bottleneck,
        "step_s": max(terms.values()),
        "model_flops": mf,
        "hlo_flops_chip": hlo["flops"],
        "useful_flops_frac": useful,
        # roofline fraction: achievable-compute share of the bound step time
        "roofline_frac": (t_c / max(terms.values())) if max(
            terms.values()) else 0.0,
        "peak_gib": peak_gib,
        "fits_16g": peak_gib <= 16.0,
        "coll_breakdown": hlo["collective_bytes"],
        "compile_s": rec["compile_s"],
    }


def fmt_table(rows: list[dict], mesh: str = "16x16") -> str:
    rows = [r for r in rows if r["mesh"] == mesh]
    hdr = (f"| arch | shape | kind | compute s | memory s | collective s | "
           f"bound | roofline frac | useful FLOPs | peak GiB | fits |")
    sep = "|" + "---|" * 11
    out = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | {r['bottleneck']} | "
            f"{r['roofline_frac']:.2f} | {r['useful_flops_frac']:.2f} | "
            f"{r['peak_gib']:.2f} | {'y' if r['fits_16g'] else 'NO'} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--csv")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = [roofline_row(r) for r in load_records(args.dir)]
    print(fmt_table(rows, args.mesh))
    if args.csv:
        import csv
        keys = [k for k in rows[0] if k != "coll_breakdown"]
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys, extrasaction="ignore")
            w.writeheader()
            w.writerows(rows)
    # summary: interesting hillclimb candidates
    single = [r for r in rows if r["mesh"] == args.mesh]
    worst = min(single, key=lambda r: r["roofline_frac"])
    collb = max(single, key=lambda r: r["collective_s"])
    print(f"\nworst roofline fraction: {worst['arch']}x{worst['shape']} "
          f"({worst['roofline_frac']:.3f})")
    print(f"most collective-bound:  {collb['arch']}x{collb['shape']} "
          f"({collb['collective_s']:.3e}s)")
    over = [r for r in single if not r["fits_16g"]]
    if over:
        print("over 16 GiB:", [(r["arch"], r["shape"],
                                round(r["peak_gib"], 1)) for r in over])


if __name__ == "__main__":
    main()

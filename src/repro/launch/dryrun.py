import os
import sys

# The XLA_FLAGS line MUST run before any other import (including repro.*):
# jax locks the device count at first initialization. The compile matrix
# wants the full 512-chip virtual topology; --serve and --chaos actually
# EXECUTE the serving stack, so they run on 8 virtual host devices instead.
_N_DEV = "8" if ("--serve" in sys.argv or "--chaos" in sys.argv) else "512"
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_N_DEV}"

import argparse  # noqa: E402
import json  # noqa: E402
import subprocess  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes — (16,16)=256 chips single-pod and
(2,16,16)=512 chips multi-pod — and record memory/cost/collective analysis
for EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --all          # full 40-cell matrix x 2
                                               # meshes, one subprocess per
                                               # cell (bounds compile RAM)
  python -m repro.launch.dryrun --serve        # run the sharded WCSD
                                               # serving stack end-to-end
                                               # on 8 virtual host devices
  python -m repro.launch.dryrun --chaos        # seeded fault-injection
                                               # schedule (docs/resilience
                                               # .md) across engine modes
"""


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str) -> dict:
    import jax
    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh
    from repro.launch import hlo_analysis

    mod = get_arch(arch)
    cell = mod.make_cell(shape, multi_pod=multi_pod)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256
    rec = {"arch": arch, "shape": shape, "kind": cell.kind,
           "mesh": "2x16x16" if multi_pod else "16x16", "chips": n_chips,
           "meta": {k: v for k, v in cell.meta.items()}}
    t0 = time.time()
    with jax.set_mesh(mesh):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        lowered = jitted.lower(*cell.args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        txt = compiled.as_text()
    rec.update(
        lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
        memory=dict(
            argument_bytes=int(ma.argument_size_in_bytes),
            output_bytes=int(ma.output_size_in_bytes),
            temp_bytes=int(ma.temp_size_in_bytes),
            alias_bytes=int(ma.alias_size_in_bytes),
            peak_bytes=int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                           + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
        ),
        xla_cost=dict(flops=float(ca.get("flops", 0.0)),
                      bytes_accessed=float(ca.get("bytes accessed", 0.0))),
        hlo=hlo_analysis.analyze(txt, default_group=16),
        hlo_chars=len(txt),
    )
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape}__{rec['mesh'].replace('x', '-')}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def run_serve(quick: bool) -> None:
    """Execute (not just compile) the sharded serving stack on the virtual
    host devices: every engine mode vs the single-device engine, bit for
    bit, on differential-harness-style instances, plus an async-flush
    `WCSDServer` epoch over the sharded backend."""
    import numpy as np
    import jax
    from repro.configs.wcsd_serve import smoke_serve_config
    from repro.core.generators import erdos_renyi, random_queries
    from repro.core.query import DeviceQueryEngine, ShardedQueryEngine
    from repro.core.serve import WCSDServer
    from repro.core.wc_index import build_wc_index
    from repro.launch.mesh import make_serving_mesh

    n_dev = len(jax.devices())
    assert n_dev >= 8, f"expected >= 8 virtual devices, got {n_dev}"
    cfg = smoke_serve_config()
    instances = [(12, 3.5, 3, 5), (10, 2.5, 2, 11)] if quick else \
        [(12, 3.5, 3, 5), (10, 2.5, 2, 11), (60, 4.0, 4, 7),
         (120, 3.0, 5, 13)]
    t0 = time.time()
    for V, deg, W, seed in instances:
        g = erdos_renyi(V, deg, num_levels=W, seed=seed)
        idx = build_wc_index(g)
        if V <= 16:  # full (s, t, w) grid on the tiny instances
            s, t, wl = np.meshgrid(np.arange(V), np.arange(V),
                                   np.arange(W + 1), indexing="ij")
            s, t, wl = (a.ravel().astype(np.int32) for a in (s, t, wl))
        else:
            s, t, wl = random_queries(g, 512, seed=seed + 1)
        for layout, dispatch in (("csr", "ragged"), ("csr", "bucket_pair"),
                                 ("padded", "ragged")):
            # every layout x dispatch x placement combo; dispatch only
            # differentiates the csr layout (the ragged megakernel vs the
            # bucket-pair oracle loop)
            dev_eng = DeviceQueryEngine(
                idx, layout=layout, use_pallas=cfg.use_pallas,
                interpret=cfg.interpret, dispatch=dispatch)
            exp = np.asarray(dev_eng.query(s, t, wl))
            # profile expectation: the per-level loop the one-pass replaces
            exp_prof = np.stack(
                [np.asarray(dev_eng.query(
                    s, t, np.full(len(s), w, np.int32)))
                 for w in range(W + 1)], axis=1)
            if not np.array_equal(np.asarray(dev_eng.query_profile(s, t)),
                                  exp_prof):
                raise SystemExit(f"MISMATCH V={V} layout={layout} "
                                 "device profile vs per-level loop")
            # the compressed arena rides the csr-ragged legs only (it is
            # the megakernel's format); hop distances here stay below the
            # bf16 exact-integer range, so even compressed answers are
            # bit-identical to the uncompressed expectation
            comp_legs = ((False, True) if (layout, dispatch)
                         == ("csr", "ragged") else (False,))
            for multi_pod in (False, True):
                mesh = make_serving_mesh(multi_pod=multi_pod)
                for budget in (None, 1):  # replicated / sharded_labels
                    for compressed in comp_legs:
                        eng = ShardedQueryEngine(
                            idx, mesh=mesh, layout=layout,
                            use_pallas=cfg.use_pallas,
                            interpret=cfg.interpret,
                            device_budget_bytes=budget, dispatch=dispatch,
                            compressed=compressed)
                        got = np.asarray(eng.query(s, t, wl))
                        tag = (f"V={V} layout={layout} "
                               f"dispatch={eng.dispatch} "
                               f"mesh={'2x4' if multi_pod else '8'} "
                               f"mode={eng.mode}"
                               + (" compressed" if eng.compressed else ""))
                        if not np.array_equal(got, exp):
                            raise SystemExit(
                                f"MISMATCH {tag}: "
                                f"{np.flatnonzero(got != exp)[:8]}")
                        got_prof = np.asarray(eng.query_profile(s, t))
                        if not np.array_equal(got_prof, exp_prof):
                            raise SystemExit(f"MISMATCH profile {tag}")
                        print(f"OK {tag}: {len(s)} queries + profiles "
                              "bit-identical", flush=True)
        # async double-buffered server over the sharded backend
        srv = WCSDServer(idx, mesh=make_serving_mesh(),
                         **{**cfg.server_kwargs(), "max_batch": 64})
        got = srv.query_many(s, t, wl)
        if not np.array_equal(got, exp):
            raise SystemExit(f"MISMATCH async server V={V}")
        assert not srv.results, "read-once delivery left results behind"
        if not np.array_equal(srv.query_profile_many(s, t), exp_prof):
            raise SystemExit(f"MISMATCH async server profiles V={V}")
        assert not srv.profile_results, "profile read-once left results"
        print(f"OK V={V} async server (+profiles): {srv.stats.batches} "
              f"batches, {srv.stats.memo_hits} memo hits", flush=True)
        # continuous-batching epoch: deadline + opportunistic flushes on,
        # same stream of submissions, answers identical to the epoch-flush
        # server (docs/serving.md §1a)
        srv_cb = WCSDServer(idx, mesh=make_serving_mesh(),
                            **{**cfg.server_kwargs(), "max_batch": 64,
                               "max_wait_us": 200.0, "min_batch": 4})
        rids = [srv_cb.submit(int(a), int(b), int(c))
                for a, b, c in zip(s, t, wl)]
        srv_cb.flush()
        got = np.array([srv_cb.result(r) for r in rids], dtype=np.int32)
        if not np.array_equal(got, exp):
            raise SystemExit(f"MISMATCH continuous-batching server V={V}")
        lat = srv_cb.latency_summary()
        st = srv_cb.stats
        print(f"OK V={V} continuous batching: {st.batches} batches "
              f"({st.opportunistic_flushes} opportunistic, "
              f"{st.deadline_flushes} deadline), p50 {lat['p50_us']:.0f}us "
              f"p99 {lat['p99_us']:.0f}us", flush=True)
    print(f"serve dryrun PASS on {n_dev} virtual devices "
          f"({time.time() - t0:.1f}s)")


def run_chaos(quick: bool) -> None:
    """Seeded chaos schedules (docs/resilience.md §6) over several engine
    configurations: injected engine raises / flush hangs / bit-flips /
    torn WAL tails plus one mid-`apply_updates` crash with a WAL-replay
    warm restart — every answer differentially checked against the BFS
    oracle, server back in its top mode at the end."""
    import tempfile

    import jax
    from repro.checkpoint.fault import run_chaos_schedule
    from repro.launch.mesh import make_serving_mesh

    n_dev = len(jax.devices())
    assert n_dev >= 8, f"expected >= 8 virtual devices, got {n_dev}"
    # (tag, steps, seed, crash_step, server_kwargs-overrides)
    legs = [("csr-ragged-device", 200, 3, 100, {}),
            ("csr-ragged-sharded", 120 if quick else 200, 7, 60, {
                "backend": "sharded", "mesh": make_serving_mesh()})]
    if not quick:
        legs += [("compressed-sharded", 200, 11, 110, {
                     "backend": "sharded", "mesh": make_serving_mesh(),
                     "compressed": True}),
                 # pallas-interpret primary so the ladder has a real
                 # pure-jnp oracle rung below it (a padded no-pallas
                 # primary IS the oracle — one rung, nothing to demote to)
                 ("padded-single", 200, 13, 90, {
                     "layout": "padded", "use_pallas": True,
                     "interpret": True})]
    if quick:
        legs[0] = ("csr-ragged-device", 120, 3, 60, {})
    t0 = time.time()
    for tag, steps, seed, crash_step, overrides in legs:
        with tempfile.TemporaryDirectory() as tmp:
            s = run_chaos_schedule(server_kwargs=overrides, steps=steps,
                                   seed=seed, crash_step=crash_step,
                                   workdir=tmp)
        assert s["final_mode"] == "primary", s
        assert s["answered"] == s["submitted"], s
        assert s["injected"] > 0 and s["crashes"] == 1, s
        print(f"OK chaos {tag}: {s['submitted']} answered, "
              f"{s['injected']} faults injected "
              f"({s['error_retries']}err/{s['timeout_retries']}to retries, "
              f"{s['demotions']} demotions, {s['promotions']} promotions), "
              f"{s['replayed_records']} WAL records replayed, "
              f"final mode {s['final_mode']}", flush=True)
    print(f"chaos dryrun PASS on {n_dev} virtual devices "
          f"({time.time() - t0:.1f}s)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--serve", action="store_true")
    ap.add_argument("--chaos", action="store_true")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.serve:
        run_serve(quick=args.quick)
        return

    if args.chaos:
        run_chaos(quick=args.quick)
        return

    if args.all:
        from repro.configs import ARCHS, get_arch
        jobs = []
        for arch in ARCHS:
            for shape in get_arch(arch).SHAPES:
                for mesh in (["single", "multi"] if args.mesh == "both"
                             else [args.mesh]):
                    jobs.append((arch, shape, mesh))
        failures = []
        for i, (arch, shape, mesh) in enumerate(jobs):
            mtag = "2-16-16" if mesh == "multi" else "16-16"
            fname = os.path.join(args.out, f"{arch}__{shape}__{mtag}.json")
            if args.skip_existing and os.path.exists(fname):
                print(f"[{i+1}/{len(jobs)}] skip {arch} {shape} {mesh}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh,
                   "--out", args.out]
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True,
                               env={**os.environ})
            ok = r.returncode == 0
            print(f"[{i+1}/{len(jobs)}] {arch:18s} {shape:14s} {mesh:6s} "
                  f"{'OK' if ok else 'FAIL'} {time.time()-t0:6.1f}s",
                  flush=True)
            if not ok:
                failures.append((arch, shape, mesh))
                print(r.stdout[-2000:])
                print(r.stderr[-4000:])
        print(f"done: {len(jobs) - len(failures)}/{len(jobs)} OK")
        if failures:
            print("FAILURES:", failures)
            sys.exit(1)
        return

    for mesh in (["single", "multi"] if args.mesh == "both"
                 else [args.mesh]):
        try:
            rec = run_cell(args.arch, args.shape, mesh == "multi", args.out)
            m = rec["memory"]
            print(f"{rec['arch']} {rec['shape']} {rec['mesh']}: compile "
                  f"{rec['compile_s']}s peak/device "
                  f"{m['peak_bytes']/2**30:.2f} GiB, hlo_flops "
                  f"{rec['hlo']['flops']:.3e}, coll "
                  f"{rec['hlo']['collective_bytes_total']/2**20:.1f} MiB")
        except Exception:
            traceback.print_exc()
            sys.exit(1)


if __name__ == "__main__":
    main()

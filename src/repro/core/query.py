"""Device-side batched WCSD query engine.

The serving hot path: given padded label arrays resident on device, answer
batches of (s, t, w_level) queries. Three implementations:

  - `query_batch_jnp`: pure-jnp masked outer join (oracle; also what the XLA
    fallback runs when Pallas is unavailable).
  - `kernels.ops.wcsd_query`: the Pallas TPU kernel (VMEM-tiled).
  - `WCIndex.query_one`: host sort-merge (paper Alg. 5), for tiny workloads.

Distribution: queries are embarrassingly parallel -> shard the batch axis
over ("pod", "data") and replicate labels; for graphs whose labels exceed a
chip, shard the *vertex* axis of the label arrays over "model" and gather
the (at most) two label rows per query with collective-permute-free
`jnp.take` (XLA turns this into an all-gather of only the touched rows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .graph import INF_DIST
from .wc_index import WCIndex

DEV_INF = jnp.int32(1 << 29)


@functools.partial(jax.jit, static_argnames=())
def query_batch_jnp(hub, dist, wlev, count, s, t, w_level):
    """[B] w-constrained distances via masked outer join over padded labels.

    hub/dist/wlev: [V, L] int32 padded label arrays, count: [V].
    s/t/w_level: [B] int32 queries. Returns int32 [B] (INF_DIST = no path).
    """
    L = hub.shape[1]
    col = jnp.arange(L)
    hs, ht = hub[s], hub[t]                       # [B, L]
    ms = (col[None, :] < count[s, None]) & (wlev[s] >= w_level[:, None])
    mt = (col[None, :] < count[t, None]) & (wlev[t] >= w_level[:, None])
    ds = jnp.where(ms, jnp.minimum(dist[s], DEV_INF), DEV_INF)
    dt = jnp.where(mt, jnp.minimum(dist[t], DEV_INF), DEV_INF)
    eq = hs[:, :, None] == ht[:, None, :]         # [B, L, L]
    dsum = ds[:, :, None] + dt[:, None, :]
    best = jnp.where(eq, dsum, DEV_INF).min(axis=(1, 2))
    return jnp.where(best >= DEV_INF, INF_DIST, best).astype(jnp.int32)


def query_batch_sorted_jnp(hub, dist, wlev, count, s, t, w_level):
    """Theorem-3-aware variant: per hub only the FIRST quality-feasible entry
    matters, so we first reduce each side to its per-hub minimum distance
    (segmented min over the sorted-by-hub label row), then do the outer join
    on the reduced rows. Same result, ~W× fewer outer-compare FLOPs when
    labels hold multiple quality tiers per hub."""
    L = hub.shape[1]
    col = jnp.arange(L)

    def reduce_side(v):
        h = hub[v]
        m = (col[None, :] < count[v, None]) & (wlev[v] >= w_level[:, None])
        d = jnp.where(m, jnp.minimum(dist[v], DEV_INF), DEV_INF)
        # entries are hub-sorted; keep min dist at first occurrence of hub
        first = jnp.concatenate([jnp.ones_like(h[:, :1], dtype=bool),
                                 h[:, 1:] != h[:, :-1]], axis=1)
        # backward running-min within equal-hub runs via reverse scan trick:
        # since within a hub run dist ascends (Thm. 3), the first feasible
        # entry already has the run's min -> segment min == min over run
        run_min = jax.lax.associative_scan(
            lambda a, b: (jnp.where(b[1], b[0], jnp.minimum(a[0], b[0])),
                          a[1] | b[1]),
            (d, first), axis=1)[0]
        # value at last element of each run = run min; scatter back: for the
        # outer join it is enough to keep per-entry run_min at run heads and
        # DEV_INF elsewhere (dedup), so equal hubs do not double-count.
        last = jnp.concatenate([h[:, :-1] != h[:, 1:],
                                jnp.ones_like(h[:, :1], dtype=bool)], axis=1)
        red = jnp.where(last, run_min, DEV_INF)
        return h, red

    hs, ds = reduce_side(s)
    ht, dt = reduce_side(t)
    eq = hs[:, :, None] == ht[:, None, :]
    best = jnp.where(eq, ds[:, :, None] + dt[:, None, :], DEV_INF)
    best = best.min(axis=(1, 2))
    return jnp.where(best >= DEV_INF, INF_DIST, best).astype(jnp.int32)


class DeviceQueryEngine:
    """Holds device-resident padded labels and answers query batches."""

    def __init__(self, idx: WCIndex, cap: int | None = None,
                 use_pallas: bool = False, interpret: bool = True):
        h, d, w, c = idx.padded_device_arrays(cap)
        # pad label width to a lane-friendly multiple of 128 for the kernel
        L = h.shape[1]
        Lp = max(128, int(np.ceil(L / 128)) * 128) if use_pallas else L
        if Lp != L:
            pad = ((0, 0), (0, Lp - L))
            h = np.pad(h, pad, constant_values=-1)
            d = np.pad(d, pad, constant_values=INF_DIST)
            w = np.pad(w, pad, constant_values=-1)
        self.hub = jnp.asarray(h)
        self.dist = jnp.asarray(d)
        self.wlev = jnp.asarray(w)
        self.count = jnp.asarray(c)
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.num_levels = idx.num_levels

    def query(self, s, t, w_level) -> jax.Array:
        s = jnp.asarray(s, jnp.int32)
        t = jnp.asarray(t, jnp.int32)
        w_level = jnp.asarray(w_level, jnp.int32)
        if self.use_pallas:
            from ..kernels import ops as kops
            return kops.wcsd_query(self.hub, self.dist, self.wlev, self.count,
                                   s, t, w_level, interpret=self.interpret)
        return query_batch_jnp(self.hub, self.dist, self.wlev, self.count,
                               s, t, w_level)

    def query_from_quality(self, s, t, w: np.ndarray, levels: np.ndarray):
        """Real-valued thresholds -> levels (exact canonicalization)."""
        wl = np.searchsorted(levels, np.asarray(w), side="left")
        return self.query(s, t, wl.astype(np.int32))

"""Device-side batched WCSD query engine.

The serving hot path: given padded label arrays resident on device, answer
batches of (s, t, w_level) queries. Three implementations:

  - `query_batch_jnp`: pure-jnp masked outer join (oracle; also what the XLA
    fallback runs when Pallas is unavailable).
  - `kernels.ops.wcsd_query`: the Pallas TPU kernel (VMEM-tiled).
  - `WCIndex.query_one`: host sort-merge (paper Alg. 5), for tiny workloads.

Distribution: queries are embarrassingly parallel -> shard the batch axis
over ("pod", "data") and replicate labels; for graphs whose labels exceed a
chip, shard the *vertex* axis of the label arrays over "model" and gather
the (at most) two label rows per query with collective-permute-free
`jnp.take` (XLA turns this into an all-gather of only the touched rows).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .graph import INF_DIST
from .wc_index import (PackedLabels, PackedWCIndex, WCIndex, round_to_lane,
                       round_to_pow2)

DEV_INF = jnp.int32(1 << 29)


@functools.partial(jax.jit, static_argnames=())
def query_batch_jnp(hub, dist, wlev, count, s, t, w_level):
    """[B] w-constrained distances via masked outer join over padded labels.

    hub/dist/wlev: [V, L] int32 padded label arrays, count: [V].
    s/t/w_level: [B] int32 queries. Returns int32 [B] (INF_DIST = no path).
    """
    L = hub.shape[1]
    col = jnp.arange(L)
    hs, ht = hub[s], hub[t]                       # [B, L]
    ms = (col[None, :] < count[s, None]) & (wlev[s] >= w_level[:, None])
    mt = (col[None, :] < count[t, None]) & (wlev[t] >= w_level[:, None])
    ds = jnp.where(ms, jnp.minimum(dist[s], DEV_INF), DEV_INF)
    dt = jnp.where(mt, jnp.minimum(dist[t], DEV_INF), DEV_INF)
    eq = hs[:, :, None] == ht[:, None, :]         # [B, L, L]
    dsum = ds[:, :, None] + dt[:, None, :]
    best = jnp.where(eq, dsum, DEV_INF).min(axis=(1, 2))
    return jnp.where(best >= DEV_INF, INF_DIST, best).astype(jnp.int32)


@jax.jit
def query_batch_sorted_jnp(hub, dist, wlev, count, s, t, w_level):
    """Theorem-3-aware variant: per hub only the FIRST quality-feasible entry
    matters, so we first reduce each side to its per-hub minimum distance
    (segmented min over the sorted-by-hub label row), then do the outer join
    on the reduced rows. Same result, ~W× fewer outer-compare FLOPs when
    labels hold multiple quality tiers per hub."""
    L = hub.shape[1]
    col = jnp.arange(L)

    def reduce_side(v):
        h = hub[v]
        m = (col[None, :] < count[v, None]) & (wlev[v] >= w_level[:, None])
        d = jnp.where(m, jnp.minimum(dist[v], DEV_INF), DEV_INF)
        # entries are hub-sorted; keep min dist at first occurrence of hub
        first = jnp.concatenate([jnp.ones_like(h[:, :1], dtype=bool),
                                 h[:, 1:] != h[:, :-1]], axis=1)
        # backward running-min within equal-hub runs via reverse scan trick:
        # since within a hub run dist ascends (Thm. 3), the first feasible
        # entry already has the run's min -> segment min == min over run
        run_min = jax.lax.associative_scan(
            lambda a, b: (jnp.where(b[1], b[0], jnp.minimum(a[0], b[0])),
                          a[1] | b[1]),
            (d, first), axis=1)[0]
        # value at last element of each run = run min; scatter back: for the
        # outer join it is enough to keep per-entry run_min at run heads and
        # DEV_INF elsewhere (dedup), so equal hubs do not double-count.
        last = jnp.concatenate([h[:, :-1] != h[:, 1:],
                                jnp.ones_like(h[:, :1], dtype=bool)], axis=1)
        red = jnp.where(last, run_min, DEV_INF)
        return h, red

    hs, ds = reduce_side(s)
    ht, dt = reduce_side(t)
    eq = hs[:, :, None] == ht[:, None, :]
    best = jnp.where(eq, ds[:, :, None] + dt[:, None, :], DEV_INF)
    best = best.min(axis=(1, 2))
    return jnp.where(best >= DEV_INF, INF_DIST, best).astype(jnp.int32)


@dataclasses.dataclass
class QuerySubBatch:
    """One bucket-pair slice of an incoming batch (see `plan_query_batch`)."""
    bucket_s: int
    bucket_t: int
    positions: np.ndarray  # [n] indices into the original batch


def plan_query_batch(bucket_of: np.ndarray, s: np.ndarray, t: np.ndarray
                     ) -> list[QuerySubBatch]:
    """Group a (s, t) batch by the (bucket(s), bucket(t)) pair.

    The dense path pays ``B * cap^2`` hub compares where cap is the *global*
    max label length; routing each query to the tile pair sized for its own
    endpoints bounds the compare volume per query by
    ``width(bucket(s)) * width(bucket(t))`` — on skewed label distributions
    almost every query lands in the smallest bucket pair. Sub-batches come
    back in a deterministic (bucket_s, bucket_t) order and their position
    arrays partition ``arange(len(s))``.
    """
    bucket_of = np.asarray(bucket_of)
    bs = bucket_of[np.asarray(s)]
    bt = bucket_of[np.asarray(t)]
    nb = int(bucket_of.max()) + 1 if len(bucket_of) else 1
    key = bs.astype(np.int64) * nb + bt
    order = np.argsort(key, kind="stable")
    uniq, starts = np.unique(key[order], return_index=True)
    bounds = np.append(starts, len(order))
    return [QuerySubBatch(bucket_s=int(k // nb), bucket_t=int(k % nb),
                          positions=order[a:b])
            for k, a, b in zip(uniq, bounds[:-1], bounds[1:])]


class DeviceQueryEngine:
    """Holds device-resident labels and answers query batches.

    layout="padded": one [V, cap] store, every query pays the global-max
    label width (kernel: `wcsd_query_gathered`).
    layout="csr": the CSR-packed store's length-bucketed tiles; batches are
    split by `plan_query_batch` and each sub-batch runs the segmented
    kernel shaped for its own bucket pair (`wcsd_query_segmented`).

    ``idx`` may be a padded `WCIndex` or a `PackedWCIndex` from the
    device-resident batched builder; for the latter the csr layout adopts
    the already-packed store as-is (`idx.packed()` is the store itself —
    no repack between construction and serving).
    """

    def __init__(self, idx: WCIndex | PackedWCIndex, cap: int | None = None,
                 use_pallas: bool = False, interpret: bool = True,
                 layout: str = "padded"):
        if layout not in ("padded", "csr"):
            raise ValueError(f"unknown layout: {layout!r}")
        if layout == "csr" and cap is not None:
            raise ValueError("cap (label-row trimming) only applies to the "
                             "padded layout; the CSR store keeps exact rows")
        self.layout = layout
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.num_levels = idx.num_levels
        if layout == "csr":
            packed = idx.packed()
            self.packed = packed
            self._bucket_of = packed.bucket_of
            self._slot_of = packed.slot_of
            self._tiles = [tuple(jnp.asarray(a) for a in packed.bucket_tiles(b))
                           for b in range(packed.num_buckets)]
            return
        h, d, w, c = idx.padded_device_arrays(cap)
        # pad label width to a lane-friendly multiple of 128 for the kernel
        L = h.shape[1]
        Lp = round_to_lane(L) if use_pallas else L
        if Lp != L:
            pad = ((0, 0), (0, Lp - L))
            h = np.pad(h, pad, constant_values=-1)
            d = np.pad(d, pad, constant_values=INF_DIST)
            w = np.pad(w, pad, constant_values=-1)
        self.hub = jnp.asarray(h)
        self.dist = jnp.asarray(d)
        self.wlev = jnp.asarray(w)
        self.count = jnp.asarray(c)

    def query(self, s, t, w_level) -> jax.Array:
        if self.layout == "csr":
            return self._query_segmented(s, t, w_level)
        s = jnp.asarray(s, jnp.int32)
        t = jnp.asarray(t, jnp.int32)
        w_level = jnp.asarray(w_level, jnp.int32)
        if self.use_pallas:
            from ..kernels import ops as kops
            return kops.wcsd_query(self.hub, self.dist, self.wlev, self.count,
                                   s, t, w_level, interpret=self.interpret)
        return query_batch_jnp(self.hub, self.dist, self.wlev, self.count,
                               s, t, w_level)

    def _query_segmented(self, s, t, w_level) -> jax.Array:
        """Plan on host, route each sub-batch to its bucket-pair kernel."""
        from ..kernels import ops as kops
        s = np.asarray(s, np.int32)
        t = np.asarray(t, np.int32)
        w_level = np.asarray(w_level, np.int32)
        out = np.full(s.shape[0], INF_DIST, dtype=np.int32)
        for sub in plan_query_batch(self._bucket_of, s, t):
            pos = sub.positions
            n = len(pos)
            # pad sub-batch to the next power of two: the compiled kernel
            # count stays O(buckets^2 * log B) instead of one per batch size
            npad = round_to_pow2(n)
            srow = np.zeros(npad, dtype=np.int32)
            trow = np.zeros(npad, dtype=np.int32)
            wq = np.full(npad, self.num_levels + 1, dtype=np.int32)  # pad:
            srow[:n] = self._slot_of[s[pos]]      # infeasible at any level
            trow[:n] = self._slot_of[t[pos]]
            wq[:n] = w_level[pos]
            hs, ds, ws = self._tiles[sub.bucket_s]
            ht, dt, wt = self._tiles[sub.bucket_t]
            res = kops.wcsd_query_segmented(
                hs, ds, ws, ht, dt, wt,
                jnp.asarray(srow), jnp.asarray(trow), jnp.asarray(wq),
                interpret=self.interpret, use_kernel=self.use_pallas)
            out[pos] = np.asarray(res)[:n]
        return jnp.asarray(out)

    def query_from_quality(self, s, t, w: np.ndarray, levels: np.ndarray):
        """Real-valued thresholds -> levels (exact canonicalization)."""
        wl = np.searchsorted(levels, np.asarray(w), side="left")
        return self.query(s, t, wl.astype(np.int32))

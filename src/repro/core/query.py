"""Device-side batched WCSD query engine.

The serving hot path: given device-resident labels, answer batches of
(s, t, w_level) queries. Implementations:

  - `query_batch_jnp`: pure-jnp masked outer join (oracle; also what the XLA
    fallback runs when Pallas is unavailable).
  - `kernels.ops.wcsd_query`: the Pallas TPU kernel (VMEM-tiled).
  - `WCIndex.query_one`: host sort-merge (paper Alg. 5), for tiny workloads.
  - the CSR layout's ragged megakernel path (default): ONE launch per flush
    over the lane-tiled `LabelArena`, batch plan = a device-emitted
    tile-pair worklist (`emit_ragged_worklist`) — the bucket-pair dispatch
    loop survives as `dispatch="bucket_pair"`, the differential oracle.
    See docs/query-engine.md for the dispatch-cost model.

Distribution (`ShardedQueryEngine`): queries are embarrassingly parallel ->
shard the batch axis over ("data",) / ("pod", "data") and replicate the
label store on every device; when the store exceeds a per-device HBM
budget, fall back to sharding the *vertex* (tile-row) axis of the label
arrays over the same devices and gather the two label rows per query with
the `row_gather_psum` collective — per query only the touched rows cross
the interconnect.

Profiles (`query_profile` on both engines): the full ``dist(s, t, w)``
staircase for every level from ONE sweep of the two label rows —
`_staircase_from_rows` is the shared min-scan core, docs/profile-queries.md
the spec. Same planner, same placements, L× fewer row gathers than the
per-level loop it replaces.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .graph import INF_DIST
from .wc_index import (PackedLabels, PackedWCIndex, WCIndex, ceil_to,
                       round_to_lane, round_to_pow2)

DEV_INF = jnp.int32(1 << 29)


def shard_map_compat(f, mesh, in_specs, out_specs):
    """`jax.shard_map` where it exists, `jax.experimental.shard_map` on
    older jax — the serving engines replicate per-query integer math, so
    replication checking is disabled on both spellings."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


@functools.partial(jax.jit, static_argnames=())
def query_batch_jnp(hub, dist, wlev, count, s, t, w_level):
    """[B] w-constrained distances via masked outer join over padded labels.

    hub/dist/wlev: [V, L] int32 padded label arrays, count: [V].
    s/t/w_level: [B] int32 queries. Returns int32 [B] (INF_DIST = no path).
    """
    L = hub.shape[1]
    col = jnp.arange(L)
    hs, ht = hub[s], hub[t]                       # [B, L]
    ms = (col[None, :] < count[s, None]) & (wlev[s] >= w_level[:, None])
    mt = (col[None, :] < count[t, None]) & (wlev[t] >= w_level[:, None])
    ds = jnp.where(ms, jnp.minimum(dist[s], DEV_INF), DEV_INF)
    dt = jnp.where(mt, jnp.minimum(dist[t], DEV_INF), DEV_INF)
    eq = hs[:, :, None] == ht[:, None, :]         # [B, L, L]
    dsum = ds[:, :, None] + dt[:, None, :]
    best = jnp.where(eq, dsum, DEV_INF).min(axis=(1, 2))
    return jnp.where(best >= DEV_INF, INF_DIST, best).astype(jnp.int32)


def _staircase_from_rows(hs, ds, ws, ht, dt, wt, num_levels: int):
    """[B, *] masked label rows -> [B, W + 1] profile staircase.

    The shared min-scan core of every profile path: a hub meet (i, j) is
    feasible at exactly the levels <= min(ws[i], wt[j]), so its distance
    sum lands in one pair-level bucket and the suffix min over buckets is
    the full staircase ``dist(s, t, w)`` for w = 0..W. ds/dt must already
    be clamped to DEV_INF (pads included); ws/wt pads must be -1 so they
    fall below every bucket. Widths of the two sides may differ."""
    eq = hs[:, :, None] == ht[:, None, :]
    dsum = jnp.where(eq, ds[:, :, None] + dt[:, None, :], DEV_INF)
    mw = jnp.minimum(ws[:, :, None], wt[:, None, :])
    bucket = jnp.stack([jnp.where(mw == lev, dsum, DEV_INF).min(axis=(1, 2))
                        for lev in range(num_levels + 1)], axis=1)
    prof = jax.lax.cummin(bucket, axis=1, reverse=True)
    return jnp.where(prof >= DEV_INF, INF_DIST, prof).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_levels",))
def profile_batch_jnp(hub, dist, wlev, count, s, t, *, num_levels: int):
    """[B, W + 1] staircases via ONE masked outer join over padded labels.

    The profile analogue of `query_batch_jnp`: both label rows are
    gathered once and every constraint level 0..W is answered from that
    single sweep — ``out[:, w] == query_batch_jnp(..., w)`` pointwise."""
    L = hub.shape[1]
    col = jnp.arange(L)

    def side(v):
        m = col[None, :] < count[v, None]
        d = jnp.where(m, jnp.minimum(dist[v], DEV_INF), DEV_INF)
        w = jnp.where(m, wlev[v], -1)
        return hub[v], d, w

    return _staircase_from_rows(*side(s), *side(t), num_levels)


@jax.jit
def query_batch_sorted_jnp(hub, dist, wlev, count, s, t, w_level):
    """Theorem-3-aware variant: per hub only the FIRST quality-feasible entry
    matters, so we first reduce each side to its per-hub minimum distance
    (segmented min over the sorted-by-hub label row), then do the outer join
    on the reduced rows. Same result, ~W× fewer outer-compare FLOPs when
    labels hold multiple quality tiers per hub."""
    L = hub.shape[1]
    col = jnp.arange(L)

    def reduce_side(v):
        h = hub[v]
        m = (col[None, :] < count[v, None]) & (wlev[v] >= w_level[:, None])
        d = jnp.where(m, jnp.minimum(dist[v], DEV_INF), DEV_INF)
        # entries are hub-sorted; keep min dist at first occurrence of hub
        first = jnp.concatenate([jnp.ones_like(h[:, :1], dtype=bool),
                                 h[:, 1:] != h[:, :-1]], axis=1)
        # backward running-min within equal-hub runs via reverse scan trick:
        # since within a hub run dist ascends (Thm. 3), the first feasible
        # entry already has the run's min -> segment min == min over run
        run_min = jax.lax.associative_scan(
            lambda a, b: (jnp.where(b[1], b[0], jnp.minimum(a[0], b[0])),
                          a[1] | b[1]),
            (d, first), axis=1)[0]
        # value at last element of each run = run min; scatter back: for the
        # outer join it is enough to keep per-entry run_min at run heads and
        # DEV_INF elsewhere (dedup), so equal hubs do not double-count.
        last = jnp.concatenate([h[:, :-1] != h[:, 1:],
                                jnp.ones_like(h[:, :1], dtype=bool)], axis=1)
        red = jnp.where(last, run_min, DEV_INF)
        return h, red

    hs, ds = reduce_side(s)
    ht, dt = reduce_side(t)
    eq = hs[:, :, None] == ht[:, None, :]
    best = jnp.where(eq, ds[:, :, None] + dt[:, None, :], DEV_INF)
    best = best.min(axis=(1, 2))
    return jnp.where(best >= DEV_INF, INF_DIST, best).astype(jnp.int32)


@dataclasses.dataclass
class QuerySubBatch:
    """One bucket-pair slice of an incoming batch (see `plan_query_batch`)."""
    bucket_s: int
    bucket_t: int
    positions: np.ndarray  # [n] indices into the original batch


def plan_query_batch(bucket_of: np.ndarray, s: np.ndarray, t: np.ndarray,
                     num_buckets: int | None = None) -> list[QuerySubBatch]:
    """Group a (s, t) batch by the (bucket(s), bucket(t)) pair.

    The dense path pays ``B * cap^2`` hub compares where cap is the *global*
    max label length; routing each query to the tile pair sized for its own
    endpoints bounds the compare volume per query by
    ``width(bucket(s)) * width(bucket(t))`` — on skewed label distributions
    almost every query lands in the smallest bucket pair. Sub-batches come
    back in a deterministic (bucket_s, bucket_t) order and their position
    arrays partition ``arange(len(s))``.

    ``num_buckets``: pass the store's bucket count (the engines cache it)
    to skip the O(V) ``bucket_of.max()`` scan this planner otherwise pays
    on EVERY flush.
    """
    bucket_of = np.asarray(bucket_of)
    bs = bucket_of[np.asarray(s)]
    bt = bucket_of[np.asarray(t)]
    if num_buckets is not None:
        nb = int(num_buckets)
    else:
        nb = int(bucket_of.max()) + 1 if len(bucket_of) else 1
    key = bs.astype(np.int64) * nb + bt
    order = np.argsort(key, kind="stable")
    uniq, starts = np.unique(key[order], return_index=True)
    bounds = np.append(starts, len(order))
    return [QuerySubBatch(bucket_s=int(k // nb), bucket_t=int(k % nb),
                          positions=order[a:b])
            for k, a, b in zip(uniq, bounds[:-1], bounds[1:])]


# -------------------------------------------------------- ragged dispatch
@functools.partial(jax.jit, static_argnames=("worklist_len",))
def emit_ragged_worklist(tile_base, tile_cnt, s, t, *, worklist_len: int):
    """Device-side ragged plan: the flat (query, s_tile, t_tile) worklist.

    Query q over rows with ``tile_cnt[s[q]]`` x ``tile_cnt[t[q]]`` arena
    tiles owns that many consecutive work items (query-major via an
    exclusive prefix sum — no wasted lanes on skewed length mixes, and the
    megakernel's output row is revisited only consecutively). This IS the
    batch plan, jitted: it replaces the per-flush host argsort/unique of
    the bucket-pair planner, so the host contributes only the O(B)
    worklist-capacity sum (`ragged_worklist_len`).

    Returns (qidx, stile, ttile, first), all int32 [worklist_len]. Work
    items beyond the real total carry ``qidx == len(s)`` — the caller's
    kernel output owns one trash row at that index — and tile 0 on both
    sides. ``first`` marks each output row's first work item (kernel-side
    DEV_INF init), including the trash row's.
    """
    Q = s.shape[0]
    ts = tile_cnt[s].astype(jnp.int32)
    tt = tile_cnt[t].astype(jnp.int32)
    c = ts * tt                                            # [Q] >= 1
    cum = jnp.cumsum(c)
    k = jnp.arange(worklist_len, dtype=jnp.int32)
    qidx = jnp.searchsorted(cum, k, side="right").astype(jnp.int32)
    qc = jnp.minimum(qidx, Q - 1)                          # clamp for pads
    local = k - (cum[qc] - c[qc])
    pad = qidx >= Q
    stile = jnp.where(pad, 0, tile_base[s[qc]] + local // tt[qc])
    ttile = jnp.where(pad, 0, tile_base[t[qc]] + local % tt[qc])
    first = jnp.concatenate(
        [jnp.ones((1,), jnp.int32),
         (qidx[1:] != qidx[:-1]).astype(jnp.int32)])
    return qidx, stile, ttile, first


def ragged_worklist_len(tile_cnt: np.ndarray, s: np.ndarray, t: np.ndarray
                        ) -> int:
    """Host-side worklist capacity: the total tile-pair count of the batch,
    rounded to the next power of two (compiled-shape count stays
    logarithmic). O(B) gather + sum — the ONLY per-flush host arithmetic
    left on the ragged path."""
    total = int(tile_cnt[s].astype(np.int64) @ tile_cnt[t].astype(np.int64))
    return round_to_pow2(total)


@functools.partial(jax.jit, static_argnames=("worklist_len", "interpret",
                                             "use_kernel", "compressed"))
def ragged_query_batch(hub, dist, wlev, tile_lo, tile_hi,
                       tile_base, tile_cnt, stq, *, worklist_len: int,
                       interpret: bool = True, use_kernel: bool = True,
                       compressed: bool = False):
    """Plan + launch, fused into ONE device call: emit the worklist from
    the staged queries and answer every query with a single ragged kernel
    launch.

    hub..tile_cnt: the `LabelArena` arrays; stq: [3, Q] staged
    (s, t, w_level) — one H2D transfer carries the whole batch. Returns
    [Q] int32 distances (INF_DIST when no feasible path); pad queries
    should carry an infeasible level and are the caller's to discard.
    ``compressed=True`` reads `CompressedArena` arrays instead (hub deltas,
    float distances, int8 levels — decoded in-kernel); hub/dist/wlev must
    then be the compressed trio, the index arrays are shared."""
    from ..kernels import ops as kops
    s, t, wl = stq[0], stq[1], stq[2]
    qidx, stile, ttile, first = emit_ragged_worklist(
        tile_base, tile_cnt, s, t, worklist_len=worklist_len)
    # one trash output row for worklist pads; no stored wlev reaches 2^20,
    # so its level is infeasible at every entry
    wq = jnp.concatenate([wl, jnp.full((1,), 1 << 20, jnp.int32)])
    op = (kops.wcsd_query_ragged_compressed if compressed
          else kops.wcsd_query_ragged)
    out = op(hub, dist, wlev, tile_lo, tile_hi, qidx, stile, ttile, first,
             wq, interpret=interpret, use_kernel=use_kernel)
    return out[: s.shape[0]]


@functools.partial(jax.jit, static_argnames=("worklist_len", "num_levels",
                                             "interpret", "use_kernel",
                                             "compressed"))
def ragged_profile_batch(hub, dist, wlev, tile_lo, tile_hi,
                         tile_base, tile_cnt, stq, *, worklist_len: int,
                         num_levels: int, interpret: bool = True,
                         use_kernel: bool = True, compressed: bool = False):
    """Profile twin of `ragged_query_batch`: stq is [2, Q] staged (s, t);
    every constraint level of every query is answered by the one launch.
    Returns [Q, num_levels + 1] staircases."""
    from ..kernels import ops as kops
    s, t = stq[0], stq[1]
    qidx, stile, ttile, first = emit_ragged_worklist(
        tile_base, tile_cnt, s, t, worklist_len=worklist_len)
    op = (kops.wcsd_profile_ragged_compressed if compressed
          else kops.wcsd_profile_ragged)
    out = op(hub, dist, wlev, tile_lo, tile_hi, qidx, stile, ttile, first,
             num_rows=int(s.shape[0]) + 1, num_levels=num_levels,
             interpret=interpret, use_kernel=use_kernel)
    return out[: s.shape[0]]


class PendingResult:
    """Handle to an in-flight query batch.

    Device work is already dispatched when the handle is created; `wait()`
    materializes the answers on host (once — the handle caches). This is
    what lets `WCSDServer` overlap host-side planning of batch k+1 with
    device execution of batch k. ``deps`` are the in-flight device arrays
    the finalizer will read: `ready()` probes them without blocking, which
    is what lets the server dispatch opportunistically the moment the
    in-flight slot's device work finishes.

    ``deadline`` (absolute `time.monotonic()` seconds, or None) is stamped
    by the flush watchdog at dispatch: a handle past its deadline that is
    still not `ready()` is treated as wedged and abandoned — device work
    is not interruptible, so "cancel" means its result is never read and
    the SAME batch is re-dispatched (core/serve.py retry loop)."""

    def __init__(self, finalize, deps=()):
        self._finalize = finalize
        self._deps = tuple(deps)
        self._out = None
        self.deadline = None

    def expired(self, now: float) -> bool:
        """True when a deadline is set, has passed, and the handle still
        is not ready — the watchdog's timeout predicate."""
        return (self.deadline is not None and now > self.deadline
                and not self.ready())

    def ready(self) -> bool:
        """Non-blocking: True once every declared device dependency has
        its data on host reach (so `wait()` would not block on the
        device). Handles with no declared deps — synchronous stubs, or
        already-waited handles — report ready."""
        if self._finalize is None:
            return True
        return all(d.is_ready() for d in self._deps
                   if hasattr(d, "is_ready"))

    def wait(self) -> np.ndarray:
        if self._finalize is not None:
            self._out = np.asarray(self._finalize())
            self._finalize = None
        return self._out


def _pad_sub_batch(slot_of, num_levels, pos, s, t, w_level, npad):
    """One planned sub-batch as a single [3, npad] staging array stacking
    (srow, trow, wq) — ONE H2D transfer instead of three; the device side
    unpacks in-jit (`ops.wcsd_query_segmented_staged`). Pads point at slot
    0 with query level num_levels + 1 — infeasible at any stored wlev, so
    pad lanes compute INF and are discarded."""
    n = len(pos)
    stq = np.zeros((3, npad), dtype=np.int32)
    stq[2, :] = num_levels + 1
    stq[0, :n] = slot_of[s[pos]]
    stq[1, :n] = slot_of[t[pos]]
    stq[2, :n] = w_level[pos]
    return stq


def _build_padded_store(idx, cap, lane_pad: bool):
    """[V, L] padded label arrays (+ lane padding for the Pallas kernel)."""
    h, d, w, c = idx.padded_device_arrays(cap)
    L = h.shape[1]
    Lp = round_to_lane(L) if lane_pad else L
    if Lp != L:
        pad = ((0, 0), (0, Lp - L))
        h = np.pad(h, pad, constant_values=-1)
        d = np.pad(d, pad, constant_values=INF_DIST)
        w = np.pad(w, pad, constant_values=-1)
    return h, d, w, c


class _QueryEngineBase:
    """Shared engine plumbing: the host-side bucket-pair plan / pad /
    dispatch / assemble loop of the CSR layout, and quality-threshold
    canonicalization. Subclasses provide ``_bucket_of`` / ``_slot_of`` /
    ``num_buckets`` / ``num_levels`` and a per-sub-batch dispatch."""

    def _plan_segmented(self, s, t, w_level, pad_len, dispatch
                        ) -> PendingResult:
        """Plan on host, dispatch each sub-batch (padded to ``pad_len(n)``,
        staged as one [3, npad] array) via ``dispatch(sub, stq)``;
        materialization of every sub-result is deferred to `wait()`."""
        s = np.asarray(s, np.int32)
        t = np.asarray(t, np.int32)
        w_level = np.asarray(w_level, np.int32)
        parts = []
        for sub in plan_query_batch(self._bucket_of, s, t,
                                    num_buckets=self.num_buckets):
            pos = sub.positions
            stq = _pad_sub_batch(self._slot_of, self.num_levels,
                                 pos, s, t, w_level, pad_len(len(pos)))
            parts.append((pos, dispatch(sub, stq)))

        def assemble():
            out = np.full(len(s), INF_DIST, dtype=np.int32)
            for pos, res in parts:
                out[pos] = np.asarray(res)[:len(pos)]
            return out
        return PendingResult(assemble, deps=[r for _, r in parts])

    def _plan_profile(self, s, t, pad_len, dispatch) -> PendingResult:
        """Profile variant of `_plan_segmented`: no per-query level — every
        level is answered by the one sweep — so the [2, npad] staging array
        carries only row ids (pads point at slot 0 and are sliced off on
        assembly) and assembly scatters [n, W + 1] staircases into the
        batch order."""
        s = np.asarray(s, np.int32)
        t = np.asarray(t, np.int32)
        parts = []
        for sub in plan_query_batch(self._bucket_of, s, t,
                                    num_buckets=self.num_buckets):
            pos = sub.positions
            n = len(pos)
            stq = np.zeros((2, pad_len(n)), dtype=np.int32)
            stq[0, :n] = self._slot_of[s[pos]]
            stq[1, :n] = self._slot_of[t[pos]]
            parts.append((pos, dispatch(sub, stq)))

        def assemble():
            out = np.full((len(s), self.num_levels + 1), INF_DIST,
                          dtype=np.int32)
            for pos, res in parts:
                out[pos] = np.asarray(res)[:len(pos)]
            return out
        return PendingResult(assemble, deps=[r for _, r in parts])

    # ----------------------------------------------------- ragged dispatch
    def _stage_ragged(self, s, t, w_level=None):
        """Staged query array for one ragged flush: queries padded by the
        engine's batch rule, stacked into one [3 or 2, Q] H2D staging
        array. Pad lanes use the arena's minimal-tile-count vertex at an
        infeasible level — a hub-heavy vertex 0 must not cost every pad
        lane its tile count squared in worklist items."""
        n = len(s)
        Q = self._ragged_pad(n)
        if w_level is not None:
            stq = np.full((3, Q), self._pad_vertex, dtype=np.int32)
            stq[2, :] = self.num_levels + 1
            stq[2, :n] = w_level
        else:
            stq = np.full((2, Q), self._pad_vertex, dtype=np.int32)
        stq[0, :n] = s
        stq[1, :n] = t
        return stq

    def query_from_quality(self, s, t, w: np.ndarray, levels: np.ndarray):
        """Real-valued thresholds -> levels (exact canonicalization)."""
        wl = np.searchsorted(levels, np.asarray(w), side="left")
        return self.query(s, t, wl.astype(np.int32))


class DeviceQueryEngine(_QueryEngineBase):
    """Holds device-resident labels and answers query batches.

    layout="padded": one [V, cap] store, every query pays the global-max
    label width (kernel: `wcsd_query_gathered`).
    layout="csr": the CSR-packed store, two dispatch modes:

      dispatch="ragged" (default): the whole batch — every bucket mix —
      runs as ONE kernel launch over the lane-tiled `LabelArena`; the
      batch plan is a device-emitted tile-pair worklist
      (`emit_ragged_worklist`), no host argsort/unique per flush.
      dispatch="bucket_pair": the original per-(bucket_s, bucket_t)
      dispatch loop (`plan_query_batch` + `wcsd_query_segmented`), kept as
      the ragged path's differential oracle.

    ``idx`` may be a padded `WCIndex` or a `PackedWCIndex` from the
    device-resident batched builder; for the latter the csr layout adopts
    the already-packed store as-is (`idx.packed()` is the store itself —
    no repack between construction and serving).

    ``interpret=None`` resolves via `kernels.ops.resolve_interpret`:
    compiled kernels on TPU (the only backend that lowers these Mosaic
    kernels), interpret emulation elsewhere or by explicit request.
    """

    def __init__(self, idx: WCIndex | PackedWCIndex, cap: int | None = None,
                 use_pallas: bool = False, interpret: bool | None = None,
                 layout: str = "padded", dispatch: str = "ragged",
                 lane: int | None = None, compressed: bool = False):
        from ..kernels.ops import resolve_interpret
        if layout not in ("padded", "csr"):
            raise ValueError(f"unknown layout: {layout!r}")
        if dispatch not in ("ragged", "bucket_pair"):
            raise ValueError(f"unknown dispatch: {dispatch!r}")
        if layout == "csr" and cap is not None:
            raise ValueError("cap (label-row trimming) only applies to the "
                             "padded layout; the CSR store keeps exact rows")
        if compressed and (layout, dispatch) != ("csr", "ragged"):
            raise ValueError("compressed=True requires layout='csr' with "
                             "dispatch='ragged' (only the arena megakernel "
                             "decodes the compressed tile format)")
        self.layout = layout
        self.use_pallas = use_pallas
        self.interpret = resolve_interpret(interpret)
        self.num_levels = idx.num_levels
        self.compressed = False
        self.compression_overflow = False
        if layout == "csr":
            from .wc_index import LANE
            lane = LANE if lane is None else int(lane)
            packed = idx.packed(lane=lane)
            self.packed = packed
            self.dispatch = dispatch
            self._bucket_of = packed.bucket_of
            self._slot_of = packed.slot_of
            self.num_buckets = packed.num_buckets
            if dispatch == "ragged":
                ar = packed.arena(lane=lane)
                self._tile_cnt_np = ar.tile_cnt
                self._pad_vertex = int(np.argmin(ar.tile_cnt))
                src = ar
                if compressed:
                    comp = packed.compressed_arena(lane=lane)
                    if comp.num_overflow_tiles:
                        # the store does not fit the compressed format
                        # losslessly (hub-delta / level / distance range
                        # overflow) — serve uncompressed and say so rather
                        # than silently corrupting answers
                        self.compression_overflow = True
                    else:
                        self.compressed = True
                        src = comp
                trio = ((src.hub_delta, src.dist, src.wlev)
                        if self.compressed else (src.hub, src.dist, src.wlev))
                self._arena = tuple(jnp.asarray(a) for a in trio + (
                    src.tile_lo, src.tile_hi, src.tile_base, src.tile_cnt))
            else:
                self._tiles = [tuple(jnp.asarray(a)
                                     for a in packed.bucket_tiles(b))
                               for b in range(packed.num_buckets)]
            return
        self.dispatch = "dense"
        h, d, w, c = _build_padded_store(idx, cap, lane_pad=use_pallas)
        self.hub = jnp.asarray(h)
        self.dist = jnp.asarray(d)
        self.wlev = jnp.asarray(w)
        self.count = jnp.asarray(c)

    def query(self, s, t, w_level) -> jax.Array:
        if self.layout == "csr":
            return jnp.asarray(self.query_async(s, t, w_level).wait())
        # dense path: hand back the dispatched device array directly — no
        # host round trip for callers that keep computing on device
        return self._query_dense(s, t, w_level)

    def query_async(self, s, t, w_level) -> PendingResult:
        """Dispatch a batch without materializing answers: host planning is
        done and every device call issued when this returns; `wait()` on
        the handle syncs."""
        if self.layout == "csr":
            if self.dispatch == "ragged":
                return self._query_ragged_async(s, t, w_level)
            return self._query_segmented_async(s, t, w_level)
        res = self._query_dense(s, t, w_level)
        return PendingResult(lambda: res, deps=(res,))

    def _query_dense(self, s, t, w_level) -> jax.Array:
        s = jnp.asarray(s, jnp.int32)
        t = jnp.asarray(t, jnp.int32)
        w_level = jnp.asarray(w_level, jnp.int32)
        if self.use_pallas:
            from ..kernels import ops as kops
            return kops.wcsd_query(self.hub, self.dist, self.wlev, self.count,
                                   s, t, w_level, interpret=self.interpret)
        return query_batch_jnp(self.hub, self.dist, self.wlev, self.count,
                               s, t, w_level)

    _ragged_pad = staticmethod(round_to_pow2)

    def _query_ragged_async(self, s, t, w_level) -> PendingResult:
        s = np.asarray(s, np.int32)
        t = np.asarray(t, np.int32)
        w_level = np.asarray(w_level, np.int32)
        n = len(s)
        stq = self._stage_ragged(s, t, w_level)
        wl_len = ragged_worklist_len(self._tile_cnt_np, stq[0], stq[1])
        res = ragged_query_batch(*self._arena, jnp.asarray(stq),
                                 worklist_len=wl_len,
                                 interpret=self.interpret,
                                 use_kernel=self.use_pallas,
                                 compressed=self.compressed)
        return PendingResult(lambda: np.asarray(res)[:n], deps=(res,))

    def _query_segmented_async(self, s, t, w_level) -> PendingResult:
        from ..kernels import ops as kops

        def dispatch(sub, stq):
            hs, ds, ws = self._tiles[sub.bucket_s]
            ht, dt, wt = self._tiles[sub.bucket_t]
            return kops.wcsd_query_segmented_staged(
                hs, ds, ws, ht, dt, wt, jnp.asarray(stq),
                interpret=self.interpret, use_kernel=self.use_pallas)

        # pad sub-batches to the next power of two: the compiled kernel
        # count stays O(buckets^2 * log B) instead of one per batch size
        return self._plan_segmented(s, t, w_level, round_to_pow2, dispatch)

    # ------------------------------------------------------------- profiles
    def query_profile(self, s, t) -> np.ndarray:
        """[B, W + 1] staircases: ``out[b, w] == query(s, t, w)[b]`` for
        every level in one label sweep (see `_staircase_from_rows`)."""
        if self.layout == "csr":
            return self.query_profile_async(s, t).wait()
        return np.asarray(self._profile_dense(s, t))

    def query_profile_async(self, s, t) -> PendingResult:
        if self.layout == "csr":
            if self.dispatch == "ragged":
                return self._profile_ragged_async(s, t)
            return self._profile_segmented_async(s, t)
        res = self._profile_dense(s, t)
        return PendingResult(lambda: res, deps=(res,))

    def _profile_dense(self, s, t) -> jax.Array:
        # the padded layout profiles on the XLA path for either kernel
        # setting: the one-sweep win is the single gather + fused min-scan,
        # which XLA already gives the dense store
        s = jnp.asarray(s, jnp.int32)
        t = jnp.asarray(t, jnp.int32)
        return profile_batch_jnp(self.hub, self.dist, self.wlev, self.count,
                                 s, t, num_levels=self.num_levels)

    def _profile_ragged_async(self, s, t) -> PendingResult:
        s = np.asarray(s, np.int32)
        t = np.asarray(t, np.int32)
        n = len(s)
        stq = self._stage_ragged(s, t)
        wl_len = ragged_worklist_len(self._tile_cnt_np, stq[0], stq[1])
        res = ragged_profile_batch(*self._arena, jnp.asarray(stq),
                                   worklist_len=wl_len,
                                   num_levels=self.num_levels,
                                   interpret=self.interpret,
                                   use_kernel=self.use_pallas,
                                   compressed=self.compressed)
        return PendingResult(lambda: np.asarray(res)[:n], deps=(res,))

    def _profile_segmented_async(self, s, t) -> PendingResult:
        from ..kernels import ops as kops

        def dispatch(sub, stq):
            hs, ds, ws = self._tiles[sub.bucket_s]
            ht, dt, wt = self._tiles[sub.bucket_t]
            return kops.wcsd_profile_segmented_staged(
                hs, ds, ws, ht, dt, wt, jnp.asarray(stq),
                num_levels=self.num_levels,
                interpret=self.interpret, use_kernel=self.use_pallas)

        return self._plan_profile(s, t, round_to_pow2, dispatch)


class ShardedQueryEngine(_QueryEngineBase):
    """Multi-device serving engine: the label store on a mesh, the query
    batch sharded over its ("pod",) "data" axes.

    Two placements, chosen by a per-device HBM budget:

    mode="replicated" (default): every device holds the full label store
    (`NamedSharding` with an all-`None` spec) and answers its slice of the
    batch under `shard_map` — zero per-query communication, linear
    throughput scaling. layout="csr" defaults to the ragged megakernel
    (dispatch="ragged"): the arena is replicated, the staged batch splits
    over the mesh, and each device emits + launches the worklist of its
    own slice — one kernel launch per device per flush, no host planner.
    dispatch="bucket_pair" keeps the host-side planner: each planned
    sub-batch is padded to a device multiple and the segmented
    scalar-prefetch kernel runs inside `shard_map`.

    mode="sharded_labels": when the store exceeds ``device_budget_bytes``,
    the label store shards its vertex/tile-row axis over the same devices
    in contiguous blocks. Query row ids are replicated; each device
    contributes its owned label rows and one reduce-scatter
    (`distributed.collectives`) hands every device exactly the gathered
    rows of its own batch slice — only touched rows cross the
    interconnect, and each crosses it once. dispatch="ragged" keeps the
    megakernel in this mode too: every device emits the ragged worklist
    of its own batch slice, ONE fused reduce-scatter
    (`ragged_tile_gather`) delivers the worklist's arena tiles to their
    consuming device, and the one-per-device ragged launch joins the
    gathered tiles — a flush is one kernel launch per device plus one
    collective, and `use_pallas` / `interpret` route through
    `kernels.ops` exactly as in replicated mode. dispatch="bucket_pair"
    keeps the per-bucket row-gather loop as the differential oracle.

    ``compressed=True`` (csr + ragged only) serves from the
    `CompressedArena` — bf16 distances, delta-coded int16 hub ids, int8
    levels, decoded in-kernel — roughly 2.4x the rows per device under
    the same ``device_budget_bytes``. Hub ids and levels are exact; see
    `CompressedArena` for the documented distance error bound. Stores
    whose deltas/levels overflow the compressed format fall back to the
    uncompressed arena with ``compression_overflow = True``.

    Every query is answered by per-query integer min-plus reductions that
    no partitioning reorders, so results are bit-for-bit identical to
    `DeviceQueryEngine` on the same index (exactly, when uncompressed;
    within the documented distance bound when compressed).
    """

    def __init__(self, idx: WCIndex | PackedWCIndex, mesh=None,
                 cap: int | None = None, use_pallas: bool = False,
                 interpret: bool | None = None, layout: str = "csr",
                 device_budget_bytes: int | None = None,
                 multi_pod: bool = False, dispatch: str = "ragged",
                 lane: int | None = None, compressed: bool = False):
        from jax.sharding import NamedSharding, PartitionSpec as P

        from ..kernels.ops import resolve_interpret

        if layout not in ("padded", "csr"):
            raise ValueError(f"unknown layout: {layout!r}")
        if dispatch not in ("ragged", "bucket_pair"):
            raise ValueError(f"unknown dispatch: {dispatch!r}")
        if layout == "csr" and cap is not None:
            raise ValueError("cap (label-row trimming) only applies to the "
                             "padded layout; the CSR store keeps exact rows")
        if mesh is None:
            from ..launch.mesh import make_serving_mesh
            mesh = make_serving_mesh(multi_pod=multi_pod)
        self.mesh = mesh
        self.batch_axes = tuple(a for a in mesh.axis_names
                                if a in ("pod", "data"))
        if not self.batch_axes:
            raise ValueError(f"mesh axes {mesh.axis_names} carry no "
                             "('pod', 'data') batch axis")
        self.ndev = int(np.prod([mesh.shape[a] for a in self.batch_axes]))
        self.layout = layout
        self.use_pallas = use_pallas
        self.interpret = resolve_interpret(interpret)
        self.num_levels = idx.num_levels
        self._P = P
        self._qspec = P(self.batch_axes)
        self._qsharding = NamedSharding(mesh, self._qspec)
        # sharded_labels mode wants the query ids replicated: every shard
        # scores the full row-id list and a reduce-scatter hands each its
        # own batch slice of the gathered rows
        self._qreplicated = NamedSharding(mesh, P(None))
        self._fns: dict = {}  # jitted shard_map callables, one per path

        if compressed and (layout, dispatch) != ("csr", "ragged"):
            raise ValueError("compressed=True requires layout='csr' with "
                             "dispatch='ragged' (only the arena megakernel "
                             "decodes the compressed tile format)")
        self.compressed = False
        self.compression_overflow = False
        if layout == "csr":
            from .wc_index import LANE
            lane = LANE if lane is None else int(lane)
            packed = idx.packed(lane=lane)
            self.packed = packed
            self._bucket_of = packed.bucket_of
            self._slot_of = packed.slot_of
            self.num_buckets = packed.num_buckets
            if dispatch == "ragged":
                ar = packed.arena(lane=lane)
                src = ar
                if compressed:
                    comp = packed.compressed_arena(lane=lane)
                    if comp.num_overflow_tiles:
                        # lossless fallback: the store overflows the
                        # compressed cell ranges, serve uncompressed
                        self.compression_overflow = True
                    else:
                        self.compressed = True
                        src = comp
                # the mode decision sees the bytes the chosen arena
                # actually costs — compression raises the row count a
                # fixed budget admits before sharding kicks in
                self.store_bytes_per_device = src.memory_bytes()
            else:
                self.store_bytes_per_device = packed.tile_memory_bytes()
        else:
            h, d, w, c = _build_padded_store(idx, cap, lane_pad=use_pallas)
            self.store_bytes_per_device = int(
                h.nbytes + d.nbytes + w.nbytes + c.nbytes)
        self.mode = ("replicated"
                     if device_budget_bytes is None
                     or self.store_bytes_per_device <= device_budget_bytes
                     else "sharded_labels")
        if self.mode == "sharded_labels":
            self.store_bytes_per_device = ceil_to(
                self.store_bytes_per_device, self.ndev) // self.ndev
        # the csr layout keeps the requested dispatch in BOTH placements:
        # row-sharded ragged routes each device's worklist tiles to their
        # consumer with one fused reduce-scatter (`ragged_tile_gather`).
        # The padded layout has no dispatch choice (one store, one path).
        self.dispatch = dispatch if layout == "csr" else "dense"

        rep = NamedSharding(mesh, P(*(None, None)))
        if layout == "csr":
            if self.dispatch == "ragged":
                self._tile_cnt_np = ar.tile_cnt
                self._tile_base_np = ar.tile_base
                self._num_tiles_np = int(ar.num_tiles)
                self._pad_vertex = int(np.argmin(ar.tile_cnt))
                trio = ((src.hub_delta, src.dist, src.wlev)
                        if self.compressed else (src.hub, src.dist, src.wlev))
                rest = (src.tile_lo, src.tile_hi, src.tile_base, src.tile_cnt)
                rep1 = NamedSharding(mesh, P(None))
                if self.mode == "sharded_labels":
                    trio = self._shard_arena_tiles(trio)
                else:
                    trio = tuple(jax.device_put(a, rep) for a in trio)
                self._arena = trio + tuple(jax.device_put(a, rep1)
                                           for a in rest)
            else:
                self._tiles = []
                for b in range(packed.num_buckets):
                    tiles = packed.bucket_tiles(b)
                    if self.mode == "sharded_labels":
                        tiles = self._shard_tile_rows(tiles)
                    else:
                        tiles = tuple(jax.device_put(a, rep) for a in tiles)
                    self._tiles.append(tiles)
        elif self.mode == "sharded_labels":
            (self.hub, self.dist, self.wlev), self.count, self._rows_per = \
                self._shard_store_rows((h, d, w), c)
        else:
            crep = NamedSharding(mesh, P(None))
            self.hub = jax.device_put(h, rep)
            self.dist = jax.device_put(d, rep)
            self.wlev = jax.device_put(w, rep)
            self.count = jax.device_put(c, crep)

    # ------------------------------------------------------------ placement
    def _shard_tile_rows(self, tiles):
        """Pad a bucket tile's row count to a device multiple (standard pad
        contract) and shard the row axis over the batch axes."""
        from jax.sharding import NamedSharding
        h, d, w = tiles
        n = h.shape[0]
        npad = ceil_to(max(n, 1), self.ndev)
        if npad != n:
            h = np.pad(h, ((0, npad - n), (0, 0)), constant_values=-1)
            d = np.pad(d, ((0, npad - n), (0, 0)), constant_values=INF_DIST)
            w = np.pad(w, ((0, npad - n), (0, 0)), constant_values=-1)
        sh = NamedSharding(self.mesh, self._P(self.batch_axes, None))
        return tuple(jax.device_put(a, sh) for a in (h, d, w))

    def _shard_arena_tiles(self, trio):
        """Pad the arena trio's tile-row axis to a device multiple (pad
        tiles carry the standard pad contract and are never named by any
        worklist — tile_base/tile_cnt only address real tiles) and shard
        it over the batch axes; records the per-device block height for
        the worklist tile gather."""
        from jax.sharding import NamedSharding
        h, d, w = trio
        T = h.shape[0]
        Tpad = ceil_to(max(T, 1), self.ndev)
        self._tiles_per = Tpad // self.ndev
        if Tpad != T:
            pad = ((0, Tpad - T), (0, 0))
            dfill = INF_DIST if d.dtype == np.int32 else np.inf
            h = np.pad(h, pad, constant_values=-1)
            d = np.pad(d, pad, constant_values=dfill)
            w = np.pad(w, pad, constant_values=-1)
        sh = NamedSharding(self.mesh, self._P(self.batch_axes, None))
        return tuple(jax.device_put(a, sh) for a in (h, d, w))

    def _shard_store_rows(self, arrays, count):
        """Pad the padded store's vertex axis to a device multiple and
        shard it; returns (sharded arrays, sharded count, rows/device)."""
        from jax.sharding import NamedSharding
        V = arrays[0].shape[0]
        Vp = ceil_to(V, self.ndev)
        fills = (-1, INF_DIST, -1)
        if Vp != V:
            arrays = tuple(np.pad(a, ((0, Vp - V), (0, 0)),
                                  constant_values=f)
                           for a, f in zip(arrays, fills))
            count = np.pad(count, (0, Vp - V))
        sh2 = NamedSharding(self.mesh, self._P(self.batch_axes, None))
        sh1 = NamedSharding(self.mesh, self._P(self.batch_axes))
        return (tuple(jax.device_put(a, sh2) for a in arrays),
                jax.device_put(count, sh1), Vp // self.ndev)

    # -------------------------------------------------------------- queries
    def query(self, s, t, w_level) -> jax.Array:
        if self.layout == "csr":
            return jnp.asarray(self.query_async(s, t, w_level).wait())
        # dense path: hand back the (sharded) device array directly
        res, n = self._dispatch_padded(s, t, w_level)
        return res[:n]

    def query_async(self, s, t, w_level) -> PendingResult:
        s = np.asarray(s, np.int32)
        t = np.asarray(t, np.int32)
        w_level = np.asarray(w_level, np.int32)
        if self.layout == "csr":
            return self._query_csr_async(s, t, w_level)
        res, n = self._dispatch_padded(s, t, w_level)
        return PendingResult(lambda: np.asarray(res)[:n], deps=(res,))

    def _batch_pad(self, n: int) -> int:
        """Power-of-two batch padding, rounded up to a device multiple so
        shard_map can split the batch axis evenly."""
        return ceil_to(max(round_to_pow2(n), self.ndev), self.ndev)

    def _put_queries(self, *arrays):
        sh = (self._qreplicated if self.mode == "sharded_labels"
              else self._qsharding)
        return (jax.device_put(a, sh) for a in arrays)

    def _put_staged(self, stq):
        """Place one [k, npad] staging array: the query axis (axis 1)
        sharded over the batch axes in replicated mode, fully replicated
        in sharded_labels mode (every shard scores the full row-id list)."""
        from jax.sharding import NamedSharding
        spec = (self._P(None, None) if self.mode == "sharded_labels"
                else self._P(None, self.batch_axes))
        return jax.device_put(stq, NamedSharding(self.mesh, spec))

    # ---- padded layout
    def _dispatch_padded(self, s, t, w_level):
        """Dispatch one dense batch; returns (device result [npad], n)."""
        s = np.asarray(s, np.int32)
        t = np.asarray(t, np.int32)
        w_level = np.asarray(w_level, np.int32)
        n = len(s)
        npad = self._batch_pad(n)
        sp = np.zeros(npad, dtype=np.int32)
        tp = np.zeros(npad, dtype=np.int32)
        wp = np.full(npad, self.num_levels + 1, dtype=np.int32)  # infeasible
        sp[:n], tp[:n], wp[:n] = s, t, w_level
        fn = self._padded_fn()
        return fn(self.hub, self.dist, self.wlev, self.count,
                  *self._put_queries(sp, tp, wp)), n

    def _padded_fn(self):
        key = ("padded", self.mode)
        if key in self._fns:
            return self._fns[key]
        P, q = self._P, self._qspec
        if self.mode == "replicated":
            use_pallas, interpret = self.use_pallas, self.interpret

            def local(hub, dist, wlev, count, s, t, wq):
                if use_pallas:
                    from ..kernels import ops as kops
                    return kops.wcsd_query(hub, dist, wlev, count, s, t, wq,
                                           interpret=interpret)
                return query_batch_jnp(hub, dist, wlev, count, s, t, wq)

            in_specs = (P(None, None),) * 3 + (P(None),) + (q,) * 3
        else:
            axes, rows_per, ndev = self.batch_axes, self._rows_per, self.ndev

            def local(hub, dist, wlev, count, s, t, wq):
                # s/t/wq arrive REPLICATED: every shard scores the full
                # row-id list against its row block and a reduce-scatter
                # leaves each shard the gathered rows of its batch slice
                from ..distributed.collectives import (
                    batch_slice, row_gather_psum_scatter)
                wq_loc = batch_slice(wq, axes, s.shape[0] // ndev)

                def side(v):
                    h = row_gather_psum_scatter(hub, v, axes, rows_per)
                    dd = row_gather_psum_scatter(dist, v, axes, rows_per)
                    ww = row_gather_psum_scatter(wlev, v, axes, rows_per)
                    cc = row_gather_psum_scatter(count, v, axes, rows_per)
                    col = jnp.arange(h.shape[1])
                    m = (col[None, :] < cc[:, None]) & (ww >= wq_loc[:, None])
                    return h, jnp.where(m, jnp.minimum(dd, DEV_INF), DEV_INF)

                hs, ds = side(s)
                ht, dt = side(t)
                eq = hs[:, :, None] == ht[:, None, :]
                best = jnp.where(eq, ds[:, :, None] + dt[:, None, :],
                                 DEV_INF).min(axis=(1, 2))
                return jnp.where(best >= DEV_INF, INF_DIST,
                                 best).astype(jnp.int32)

            in_specs = (P(self.batch_axes, None),) * 3 \
                + (P(self.batch_axes),) + (P(None),) * 3
        fn = jax.jit(shard_map_compat(local, self.mesh, in_specs, q))
        self._fns[key] = fn
        return fn

    # ---- csr layout
    def _query_csr_async(self, s, t, w_level) -> PendingResult:
        if self.dispatch == "ragged":
            return self._query_ragged_async(s, t, w_level)
        fn = self._segmented_fn()

        def dispatch(sub, stq):
            hs, ds, ws = self._tiles[sub.bucket_s]
            ht, dt, wt = self._tiles[sub.bucket_t]
            return fn(hs, ds, ws, ht, dt, wt, self._put_staged(stq))

        return self._plan_segmented(s, t, w_level, self._batch_pad, dispatch)

    def _ragged_pad(self, n: int) -> int:
        return self._batch_pad(n)

    def _shard_worklist_len(self, stq) -> int:
        """Per-shard worklist capacity: each shard plans its own contiguous
        batch slice inside shard_map, so the static capacity is the max
        over shards' tile-pair totals."""
        b_loc = stq.shape[1] // self.ndev
        return max(ragged_worklist_len(
            self._tile_cnt_np, stq[0, k * b_loc:(k + 1) * b_loc],
            stq[1, k * b_loc:(k + 1) * b_loc]) for k in range(self.ndev))

    def _balance_ragged(self, stq):
        """Load-balanced device assignment for the row-sharded flush: hot
        queries usually arrive clustered (one tenant, one hot subgraph),
        and the static per-shard worklist capacity is the MAX over device
        slices — one heavy contiguous slice makes every device pay its
        worklist. Queries are dealt in descending tile-pair cost, each
        round handing the heaviest remaining queries to the least-loaded
        devices (capacity-constrained LPT: every device gets exactly
        npad/ndev), so the capacity tracks the batch mean instead.
        Returns (stq reordered device-major, perm); results are
        unpermuted with ``out[perm] = res``."""
        ndev = self.ndev
        if ndev == 1:
            return stq, np.arange(stq.shape[1])
        tc = self._tile_cnt_np
        c = tc[stq[0]].astype(np.int64) * tc[stq[1]]
        order = np.argsort(-c, kind="stable")
        b = stq.shape[1] // ndev
        load = np.zeros(ndev, np.int64)
        perm = np.empty(stq.shape[1], np.int64)
        cs = c[order].reshape(b, ndev)
        ob = order.reshape(b, ndev)
        for blk in range(b):
            dst = np.argsort(load, kind="stable")
            perm[dst * b + blk] = ob[blk]
            load[dst] += cs[blk]
        return stq[:, perm], perm

    def _balanced_worklist_len(self, stq) -> int:
        """Per-shard worklist capacity for a BALANCED flush: slice totals
        sit near the batch mean, so capacity rounds to the next
        512-multiple (not the next power of two — doubling a balanced
        slice's capacity would hand every device back the pad waste the
        balancing just removed)."""
        b = stq.shape[1] // self.ndev
        tc = self._tile_cnt_np
        tot = max(int(tc[stq[0, k * b:(k + 1) * b]].astype(np.int64)
                      @ tc[stq[1, k * b:(k + 1) * b]])
                  for k in range(self.ndev))
        return ceil_to(max(tot, 1), 512)

    def _gather_plan(self, stq, worklist_len: int):
        """Host-side gather plan for the row-sharded arena: per device, the
        sorted DISTINCT arena tiles its batch slice can name — the union of
        the slice vertices' tile ranges, NOT the worklist (a hub-heavy row
        joined by a thousand queries still contributes its tiles once).
        Rows are padded to a static capacity G with the last real tile id
        (keeps the array sorted for the device-side binary search); G is
        rounded up to a 256-multiple so the compiled-shape count stays
        small. O(B + tiles named) numpy, the same order of host work as
        `ragged_worklist_len`."""
        ndev = self.ndev
        b = stq.shape[1] // ndev
        tb, tc = self._tile_base_np, self._tile_cnt_np
        uniqs = []
        for k in range(ndev):
            v = np.unique(np.concatenate([stq[0, k * b:(k + 1) * b],
                                          stq[1, k * b:(k + 1) * b]]))
            cnt = tc[v].astype(np.int64)
            # expand the [tb[v], tb[v] + tc[v]) ranges vectorized
            ends = np.cumsum(cnt)
            idx = np.arange(int(ends[-1]))
            own = np.searchsorted(ends, idx, side="right")
            uniqs.append(np.unique(
                tb[v][own] + (idx - (ends[own] - cnt[own]))).astype(np.int32))
        G = ceil_to(max(len(u) for u in uniqs), 256)
        uniq = np.full((ndev, G), self._num_tiles_np - 1, dtype=np.int32)
        for k, u in enumerate(uniqs):
            uniq[k, :len(u)] = u
        return uniq, G

    def _query_ragged_async(self, s, t, w_level) -> PendingResult:
        n = len(s)
        stq = self._stage_ragged(s, t, w_level)
        if self.mode == "sharded_labels":
            stq, perm = self._balance_ragged(stq)
            wl_len = self._balanced_worklist_len(stq)
            uniq, G = self._gather_plan(stq, wl_len)
            fn = self._ragged_fn(wl_len, profile=False, gather_cap=G)
            res = fn(*self._arena, self._put_staged(stq),
                     self._put_staged(uniq))

            def finalize():
                out = np.empty(stq.shape[1], dtype=np.int32)
                out[perm] = np.asarray(res)
                return out[:n]

            return PendingResult(finalize, deps=(res,))
        fn = self._ragged_fn(self._shard_worklist_len(stq), profile=False)
        res = fn(*self._arena, self._put_staged(stq))
        return PendingResult(lambda: np.asarray(res)[:n], deps=(res,))

    def _ragged_fn(self, worklist_len: int, profile: bool,
                   gather_cap: int | None = None):
        """Jitted shard_map over the ragged megakernel path.

        Replicated mode: the arena on every device, the staged batch split
        over the batch axes, each shard emitting + launching its own
        slice's worklist — one kernel launch per device per flush.

        Sharded-labels mode: the [T, lane] trio is tile-row-sharded, the
        staged batch load-balanced on host (`_balance_ragged`) and
        replicated alongside the host `_gather_plan` — per device, the
        sorted DISTINCT tiles its batch slice can name. ONE fused
        reduce-scatter (`ragged_tile_gather`) hands device k exactly
        those tiles, each crossing the interconnect once however many
        worklist entries name it (a hub-heavy row can be joined by
        thousands of queries in a flush). Each device then emits only its
        OWN slice's worklist (`emit_ragged_worklist`, no cross-device
        work), relabels it into the gathered buffer by binary search, and
        the same ragged launch joins it against the batch slice. A flush
        is one kernel launch per device plus one collective, with
        `use_pallas` / `interpret` routing through `kernels.ops` exactly
        as in replicated mode."""
        key = ("csr-ragged", self.mode, profile, worklist_len, gather_cap)
        if key in self._fns:
            return self._fns[key]
        P, q = self._P, self._qspec
        use_pallas, interpret = self.use_pallas, self.interpret
        compressed = self.compressed
        W = self.num_levels

        if self.mode == "replicated":
            if profile:
                def local(hub, dist, wlev, lo, hi, tbase, tcnt, stq):
                    return ragged_profile_batch(
                        hub, dist, wlev, lo, hi, tbase, tcnt, stq,
                        worklist_len=worklist_len, num_levels=W,
                        interpret=interpret, use_kernel=use_pallas,
                        compressed=compressed)
            else:
                def local(hub, dist, wlev, lo, hi, tbase, tcnt, stq):
                    return ragged_query_batch(
                        hub, dist, wlev, lo, hi, tbase, tcnt, stq,
                        worklist_len=worklist_len,
                        interpret=interpret, use_kernel=use_pallas,
                        compressed=compressed)

            in_specs = (P(None, None),) * 3 + (P(None),) * 4 \
                + (P(None, self.batch_axes),)
        else:
            axes, ndev = self.batch_axes, self.ndev
            tiles_per, WL = self._tiles_per, worklist_len

            def local(hub, dist, wlev, lo, hi, tbase, tcnt, stq, uniq):
                from ..distributed.collectives import (axis_linear_index,
                                                       ragged_tile_gather)
                from ..kernels import ops as kops
                b = stq.shape[1] // ndev
                # one fused reduce-scatter routes each device's
                # host-planned DISTINCT tile list to it, in linear device
                # order — each tile crosses the interconnect once
                gh, gd, gw = ragged_tile_gather(
                    (hub, dist, wlev), uniq.reshape(-1), axes, tiles_per)
                me = axis_linear_index(axes)

                def mine(a):
                    return jax.lax.dynamic_slice_in_dim(a, me * b, b)

                qidx, stile, ttile, first = emit_ragged_worklist(
                    tbase, tcnt, mine(stq[0]), mine(stq[1]),
                    worklist_len=WL)
                # relabel worklist tiles into the gathered buffer: the
                # plan rows are sorted (fill = last real tile id), so a
                # binary search lands every real entry; worklist pads
                # name tile 0, whose probe row is trash-routed anyway
                uniq_me = jax.lax.dynamic_index_in_dim(
                    uniq, me, axis=0, keepdims=False)
                sloc = jnp.searchsorted(uniq_me, stile).astype(jnp.int32)
                tloc = jnp.searchsorted(uniq_me, ttile).astype(jnp.int32)
                args = (gh, gd, gw, lo[uniq_me], hi[uniq_me], qidx,
                        sloc, tloc, first)
                if profile:
                    op = (kops.wcsd_profile_ragged_compressed if compressed
                          else kops.wcsd_profile_ragged)
                    out = op(*args, num_rows=b + 1, num_levels=W,
                             interpret=interpret, use_kernel=use_pallas)
                else:
                    wq = jnp.concatenate([
                        mine(stq[2]), jnp.full((1,), 1 << 20, jnp.int32)])
                    op = (kops.wcsd_query_ragged_compressed if compressed
                          else kops.wcsd_query_ragged)
                    out = op(*args, wq,
                             interpret=interpret, use_kernel=use_pallas)
                return out[:b]

            in_specs = (P(self.batch_axes, None),) * 3 + (P(None),) * 4 \
                + (P(None, None), P(None, None))
        fn = jax.jit(shard_map_compat(local, self.mesh, in_specs, q))
        self._fns[key] = fn
        return fn

    def _segmented_fn(self):
        key = ("csr", self.mode)
        if key in self._fns:
            return self._fns[key]
        P, q = self._P, self._qspec
        if self.mode == "replicated":
            use_pallas, interpret = self.use_pallas, self.interpret

            def local(hs, ds, ws, ht, dt, wt, stq):
                from ..kernels import ops as kops
                return kops.wcsd_query_segmented_staged(
                    hs, ds, ws, ht, dt, wt, stq,
                    interpret=interpret, use_kernel=use_pallas)

            tile = P(None, None)
            qspec = P(None, self.batch_axes)
        else:
            axes, ndev = self.batch_axes, self.ndev

            def local(hs, ds, ws, ht, dt, wt, stq):
                # replicated row ids + reduce-scatter, as in the padded
                # sharded-labels path; tiles are row-sharded per bucket
                from ..distributed.collectives import (
                    batch_slice, row_gather_psum_scatter)
                srow, trow, wq = stq[0], stq[1], stq[2]
                wq_loc = batch_slice(wq, axes, srow.shape[0] // ndev)

                def side(h, d, w, rows):
                    per = h.shape[0]  # local row-block height
                    hg = row_gather_psum_scatter(h, rows, axes, per)
                    dg = row_gather_psum_scatter(d, rows, axes, per)
                    wg = row_gather_psum_scatter(w, rows, axes, per)
                    # store pads carry wlev = -1: one compare masks both
                    # out-of-row and infeasible entries
                    return hg, jnp.where(wg >= wq_loc[:, None],
                                         jnp.minimum(dg, DEV_INF), DEV_INF)

                hs2, ds2 = side(hs, ds, ws, srow)
                ht2, dt2 = side(ht, dt, wt, trow)
                eq = hs2[:, :, None] == ht2[:, None, :]
                best = jnp.where(eq, ds2[:, :, None] + dt2[:, None, :],
                                 DEV_INF).min(axis=(1, 2))
                return jnp.where(best >= DEV_INF, INF_DIST,
                                 best).astype(jnp.int32)

            tile = P(self.batch_axes, None)
            qspec = P(None, None)
        in_specs = (tile,) * 6 + (qspec,)
        fn = jax.jit(shard_map_compat(local, self.mesh, in_specs, q))
        self._fns[key] = fn
        return fn

    # ------------------------------------------------------------- profiles
    def query_profile(self, s, t) -> np.ndarray:
        """[B, W + 1] staircases, bit-identical to `DeviceQueryEngine.
        query_profile` on the same index (same per-query integer min-scan,
        only the batch placement differs)."""
        return self.query_profile_async(s, t).wait()

    def query_profile_async(self, s, t) -> PendingResult:
        s = np.asarray(s, np.int32)
        t = np.asarray(t, np.int32)
        if self.layout == "csr":
            if self.dispatch == "ragged":
                return self._profile_ragged_async(s, t)
            fn = self._profile_segmented_fn()

            def dispatch(sub, stq):
                hs, ds, ws = self._tiles[sub.bucket_s]
                ht, dt, wt = self._tiles[sub.bucket_t]
                return fn(hs, ds, ws, ht, dt, wt, self._put_staged(stq))

            return self._plan_profile(s, t, self._batch_pad, dispatch)
        res, n = self._dispatch_padded_profile(s, t)
        return PendingResult(lambda: np.asarray(res)[:n], deps=(res,))

    def _profile_ragged_async(self, s, t) -> PendingResult:
        n = len(s)
        stq = self._stage_ragged(s, t)
        if self.mode == "sharded_labels":
            stq, perm = self._balance_ragged(stq)
            wl_len = self._balanced_worklist_len(stq)
            uniq, G = self._gather_plan(stq, wl_len)
            fn = self._ragged_fn(wl_len, profile=True, gather_cap=G)
            res = fn(*self._arena, self._put_staged(stq),
                     self._put_staged(uniq))

            def finalize():
                r = np.asarray(res)
                out = np.empty_like(r)
                out[perm] = r
                return out[:n]

            return PendingResult(finalize, deps=(res,))
        fn = self._ragged_fn(self._shard_worklist_len(stq), profile=True)
        res = fn(*self._arena, self._put_staged(stq))
        return PendingResult(lambda: np.asarray(res)[:n], deps=(res,))

    def _dispatch_padded_profile(self, s, t):
        n = len(s)
        npad = self._batch_pad(n)
        sp = np.zeros(npad, dtype=np.int32)
        tp = np.zeros(npad, dtype=np.int32)
        sp[:n], tp[:n] = s, t
        fn = self._padded_profile_fn()
        return fn(self.hub, self.dist, self.wlev, self.count,
                  *self._put_queries(sp, tp)), n

    def _padded_profile_fn(self):
        key = ("padded-profile", self.mode)
        if key in self._fns:
            return self._fns[key]
        P, q = self._P, self._qspec
        W = self.num_levels
        if self.mode == "replicated":
            def local(hub, dist, wlev, count, s, t):
                return profile_batch_jnp(hub, dist, wlev, count, s, t,
                                         num_levels=W)

            in_specs = (P(None, None),) * 3 + (P(None),) + (q,) * 2
        else:
            axes, rows_per = self.batch_axes, self._rows_per

            def local(hub, dist, wlev, count, s, t):
                # replicated row ids, as in the single-level fallback, but
                # ONE fused reduce-scatter per side carries (hub, dist,
                # wlev, count) together — the profile gathers a row exactly
                # once, so the collective launch is paid once too
                from ..distributed.collectives import (
                    multi_row_gather_psum_scatter)

                def side(v):
                    h, dd, ww, cc = multi_row_gather_psum_scatter(
                        (hub, dist, wlev, count[:, None]), v, axes, rows_per)
                    col = jnp.arange(h.shape[1])
                    m = col[None, :] < cc[:, 0][:, None]
                    d = jnp.where(m, jnp.minimum(dd, DEV_INF), DEV_INF)
                    w = jnp.where(m, ww, -1)
                    return h, d, w

                return _staircase_from_rows(*side(s), *side(t), W)

            in_specs = (P(self.batch_axes, None),) * 3 \
                + (P(self.batch_axes),) + (P(None),) * 2
        fn = jax.jit(shard_map_compat(local, self.mesh, in_specs, q))
        self._fns[key] = fn
        return fn

    def _profile_segmented_fn(self):
        key = ("csr-profile", self.mode)
        if key in self._fns:
            return self._fns[key]
        P, q = self._P, self._qspec
        W = self.num_levels
        if self.mode == "replicated":
            use_pallas, interpret = self.use_pallas, self.interpret

            def local(hs, ds, ws, ht, dt, wt, stq):
                from ..kernels import ops as kops
                return kops.wcsd_profile_segmented_staged(
                    hs, ds, ws, ht, dt, wt, stq, num_levels=W,
                    interpret=interpret, use_kernel=use_pallas)

            tile = P(None, None)
            qspec = P(None, self.batch_axes)
        else:
            axes = self.batch_axes

            def local(hs, ds, ws, ht, dt, wt, stq):
                # row-sharded bucket tiles: one fused reduce-scatter per
                # side gathers (hub, dist, wlev) rows; store pads carry
                # wlev = -1 and fall below every staircase bucket
                from ..distributed.collectives import (
                    multi_row_gather_psum_scatter)
                srow, trow = stq[0], stq[1]

                def side(h, d, w, rows):
                    hg, dg, wg = multi_row_gather_psum_scatter(
                        (h, d, w), rows, axes, h.shape[0])
                    return hg, jnp.minimum(dg, DEV_INF), wg

                return _staircase_from_rows(*side(hs, ds, ws, srow),
                                            *side(ht, dt, wt, trow), W)

            tile = P(self.batch_axes, None)
            qspec = P(None, None)
        in_specs = (tile,) * 6 + (qspec,)
        fn = jax.jit(shard_map_compat(local, self.mesh, in_specs, q))
        self._fns[key] = fn
        return fn

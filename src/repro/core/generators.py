"""Synthetic graph generators mirroring the paper's two dataset families:
road-like (grid, large diameter, near-constant degree) and scale-free social
(Barabási–Albert / configuration-model-ish). Qualities are drawn from |w|
distinct levels, matching Tables III/IV (|w| in {3, 5, 9, 20})."""
from __future__ import annotations

import numpy as np

from .graph import Graph


def _assign_qualities(num_edges: int, num_levels: int, rng: np.random.Generator,
                      skew: float = 0.0) -> np.ndarray:
    """Draw per-edge qualities from ``num_levels`` distinct values.

    skew=0 -> uniform over levels; skew>0 -> zipf-ish bias to low levels
    (most edges low quality, matching e.g. bandwidth distributions)."""
    vals = np.arange(1.0, num_levels + 1.0)  # quality values 1..W
    if skew <= 0:
        probs = np.full(num_levels, 1.0 / num_levels)
    else:
        probs = 1.0 / (np.arange(1, num_levels + 1) ** skew)
        probs /= probs.sum()
    return rng.choice(vals, size=num_edges, p=probs)


def road_grid(rows: int, cols: int, num_levels: int = 5, diag_prob: float = 0.05,
              seed: int = 0) -> Graph:
    """Road-network-like graph: rows×cols grid + sparse diagonal shortcuts."""
    rng = np.random.default_rng(seed)
    idx = np.arange(rows * cols).reshape(rows, cols)
    us, vs = [], []
    us.append(idx[:, :-1].ravel()); vs.append(idx[:, 1:].ravel())   # horizontal
    us.append(idx[:-1, :].ravel()); vs.append(idx[1:, :].ravel())   # vertical
    if diag_prob > 0:
        du, dv = idx[:-1, :-1].ravel(), idx[1:, 1:].ravel()
        m = rng.random(len(du)) < diag_prob
        us.append(du[m]); vs.append(dv[m])
    u = np.concatenate(us); v = np.concatenate(vs)
    qual = _assign_qualities(len(u), num_levels, rng)
    return Graph.from_edges(rows * cols, u, v, qual)


def scale_free(num_nodes: int, m: int = 4, num_levels: int = 3,
               seed: int = 0, skew: float = 0.8) -> Graph:
    """Barabási–Albert scale-free graph (social-network-like)."""
    import networkx as nx
    g = nx.barabasi_albert_graph(num_nodes, m, seed=seed)
    e = np.array(g.edges(), dtype=np.int32)
    rng = np.random.default_rng(seed + 1)
    qual = _assign_qualities(len(e), num_levels, rng, skew=skew)
    return Graph.from_edges(num_nodes, e[:, 0], e[:, 1], qual)


def erdos_renyi(num_nodes: int, avg_degree: float = 6.0, num_levels: int = 5,
                seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    num_edges = int(num_nodes * avg_degree / 2)
    u = rng.integers(0, num_nodes, size=num_edges)
    v = rng.integers(0, num_nodes, size=num_edges)
    keep = u != v
    u, v = u[keep], v[keep]
    qual = _assign_qualities(len(u), num_levels, rng)
    return Graph.from_edges(num_nodes, u, v, qual)


def random_queries(g: Graph, n: int, seed: int = 0
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(s, t, w_level) triples with w_level in [0, num_levels)."""
    rng = np.random.default_rng(seed)
    s = rng.integers(0, g.num_nodes, size=n).astype(np.int32)
    t = rng.integers(0, g.num_nodes, size=n).astype(np.int32)
    wl = rng.integers(0, max(g.num_levels, 1), size=n).astype(np.int32)
    return s, t, wl

"""WCSD serving engine: request batching over the device query engine.

Mirrors the paper's query-serving scenario (10k random queries, µs/query):
requests accumulate into fixed-size (power-of-two) batches to avoid
recompilation, are answered by one fused device call, and per-request
results are handed back. A tiny LRU memo short-circuits repeated hot
queries (social-network workloads are heavy-tailed)."""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional

import numpy as np

from .query import DeviceQueryEngine
from .wc_index import PackedWCIndex, WCIndex, round_to_pow2


@dataclasses.dataclass
class ServeStats:
    requests: int = 0
    batches: int = 0
    memo_hits: int = 0
    flush_time_s: float = 0.0
    max_batch: int = 0


class WCSDServer:
    def __init__(self, idx: WCIndex | PackedWCIndex, max_batch: int = 1024,
                 use_pallas: bool = False, memo_capacity: int = 65536,
                 layout: str = "padded", undirected: bool = True):
        # layout="csr" serves from the CSR-packed bucket tiles: each flush
        # is planned by bucket pair and routed to the segmented kernel.
        # A PackedWCIndex (device-resident batched builder output) is served
        # as-is under layout="csr" — no repack between build and serve.
        # undirected=False disables the symmetric (s <= t) memo
        # canonicalization for indices over directed graphs, where
        # d(s, t) != d(t, s) and the swap would alias distinct answers.
        self.engine = DeviceQueryEngine(idx, use_pallas=use_pallas,
                                        layout=layout)
        self.max_batch = int(max_batch)
        self.undirected = bool(undirected)
        self.memo: collections.OrderedDict[tuple, int] = collections.OrderedDict()
        self.memo_capacity = memo_capacity
        self.pending: list[tuple[int, int, int, int]] = []  # (rid, s, t, wl)
        self._pending_rids: set[int] = set()  # O(1) result() membership
        self.results: dict[int, int] = {}
        self._next_rid = 0
        self.stats = ServeStats()

    def _memo_key(self, s: int, t: int, w_level: int) -> tuple:
        if self.undirected and s > t:
            return (t, s, w_level)
        return (s, t, w_level)

    # ------------------------------------------------------------- requests
    def submit(self, s: int, t: int, w_level: int) -> int:
        """Queue one request; returns a request id."""
        rid = self._next_rid
        self._next_rid += 1
        key = self._memo_key(s, t, w_level)
        self.stats.requests += 1
        if key in self.memo:
            self.memo.move_to_end(key)
            self.results[rid] = self.memo[key]
            self.stats.memo_hits += 1
        else:
            self.pending.append((rid, s, t, w_level))
            self._pending_rids.add(rid)
            if len(self.pending) >= self.max_batch:
                self.flush()
        return rid

    def flush(self) -> None:
        if not self.pending:
            return
        t0 = time.perf_counter()
        batch = self.pending
        self.pending = []
        self._pending_rids.clear()
        n = len(batch)
        # pad to the next power of two (bounded recompiles); the csr engine
        # pads each planned sub-batch itself, so padding here would only add
        # dummy queries that the segmented kernels compute and discard
        padded = n if self.engine.layout == "csr" else round_to_pow2(n)
        rid = np.array([b[0] for b in batch], dtype=np.int64)
        s = np.zeros(padded, dtype=np.int32)
        t = np.zeros(padded, dtype=np.int32)
        wl = np.zeros(padded, dtype=np.int32)
        s[:n] = [b[1] for b in batch]
        t[:n] = [b[2] for b in batch]
        wl[:n] = [b[3] for b in batch]
        out = np.asarray(self.engine.query(s, t, wl))[:n]
        for r, (ss, tt, ww), d in zip(rid, [(b[1], b[2], b[3]) for b in batch],
                                      out):
            self.results[int(r)] = int(d)
            key = self._memo_key(ss, tt, ww)
            self.memo[key] = int(d)
            if len(self.memo) > self.memo_capacity:
                self.memo.popitem(last=False)
        self.stats.batches += 1
        self.stats.max_batch = max(self.stats.max_batch, n)
        self.stats.flush_time_s += time.perf_counter() - t0

    def result(self, rid: int) -> Optional[int]:
        # membership via the pending-rid set: O(1) per lookup instead of an
        # O(pending) scan of the request list
        if rid not in self.results and rid in self._pending_rids:
            self.flush()
        return self.results.get(rid)

    # convenience: synchronous bulk API
    def query_many(self, s, t, w_level) -> np.ndarray:
        rids = [self.submit(int(a), int(b), int(c))
                for a, b, c in zip(s, t, w_level)]
        self.flush()
        return np.array([self.results[r] for r in rids], dtype=np.int32)

"""WCSD serving engine: request batching over the device query engines.

Mirrors the paper's query-serving scenario (10k random queries, µs/query):
requests accumulate into fixed-size (power-of-two) batches to avoid
recompilation, are answered by one fused device call, and per-request
results are handed back. A tiny LRU memo short-circuits repeated hot
queries (social-network workloads are heavy-tailed).

Production shape:

  * pluggable engine backend — ``backend="device"`` (single-device
    `DeviceQueryEngine`), ``backend="sharded"`` (`ShardedQueryEngine` over
    a mesh), or a prebuilt engine object via ``engine=``; ``layout`` /
    ``use_pallas`` / ``interpret`` are plumbed through, so serving can
    reach the *compiled* kernels instead of being pinned to interpret mode.
  * double-buffered async flush — an auto-flush (hitting ``max_batch``)
    only *dispatches* the batch (`engine.query_async`); while the device
    executes batch k, the host keeps accepting submissions for batch k+1.
    On the default ragged dispatch the batch PLAN itself is computed on
    device (`emit_ragged_worklist`), so a flush is host-plan-free; the
    bucket-pair dispatch still plans on host (`plan_query_batch`). At most
    one batch is in flight; launching the next one (or any
    result()/flush()) drains it.
  * continuous batching — with ``max_wait_us`` set, a flush no longer
    waits for ``max_batch``: once ``min_batch`` requests are queued, the
    batch dispatches as soon as the in-flight slot is free (or its device
    work is done — `PendingResult.ready` probes without blocking), and a
    trickle that never fills ``min_batch``-sized bursts is bounded by the
    ``max_wait_us`` deadline on the OLDEST queued request (checked on
    every submit and on `poll`). Per-request enqueue→deliver latency is
    recorded (`latency_summary` reports p50/p99 µs) and host flush time
    is split into dispatch vs drain-wait (`ServeStats`), so SLO math sees
    launch overhead and device wait separately.
  * read-once results — `result(rid)` pops the delivered answer, so a
    long-running server's result dict stays bounded by what is queued or
    in flight instead of growing one entry per request forever. Callers
    needing an answer twice re-submit (the memo makes that free).
  * profile (staircase) queries — `submit_profile(s, t)` /
    `query_profile_many` answer EVERY constraint level of a pair in one
    label sweep (`engine.query_profile`), riding the same double-buffered
    flush; a cached profile also short-circuits any single-level submit
    of its pair (see docs/profile-queries.md).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional

import numpy as np

from .query import DeviceQueryEngine, PendingResult, ShardedQueryEngine
from .resilience import (FlushRetryExhausted, RetryPolicy,
                         UnknownRequestError, WALReplayError,
                         build_fallback_ladder)
from .wc_index import (DynamicWCIndex, PackedWCIndex, WCIndex,
                       round_to_pow2)


@dataclasses.dataclass
class ServeStats:
    requests: int = 0
    profile_requests: int = 0
    batches: int = 0
    memo_hits: int = 0
    dispatch_time_s: float = 0.0  # host time launching batches (flush_async)
    drain_wait_s: float = 0.0     # host time blocked on device results
    max_batch: int = 0
    deadline_flushes: int = 0     # flushes fired by the max_wait_us deadline
    opportunistic_flushes: int = 0  # flushes fired by a free in-flight slot
    # flush watchdog (docs/resilience.md): per-cause retry counters
    timeout_retries: int = 0      # handle missed its deadline, re-dispatched
    error_retries: int = 0        # dispatch/wait raised, re-dispatched
    exhausted: int = 0            # a retry budget ran out (demote or raise)
    demotions: int = 0            # fallback-ladder steps down
    promotions: int = 0           # healthy probe windows stepping back up
    wal_appends: int = 0          # update batches logged to the WAL

    @property
    def flush_time_s(self) -> float:
        # the pre-split lump (launch + drain), kept for bench-schema
        # compatibility; SLO math should use the two components — drain
        # wait is device time the host merely observes, dispatch time is
        # host overhead a faster frontend could shrink
        return self.dispatch_time_s + self.drain_wait_s


class WCSDServer:
    def __init__(self, idx: WCIndex | PackedWCIndex | None = None,
                 max_batch: int = 1024, use_pallas: bool = False,
                 memo_capacity: int = 65536, layout: str = "padded",
                 undirected: bool = True, interpret: bool | None = None,
                 backend: str = "device", engine=None, mesh=None,
                 device_budget_bytes: int | None = None,
                 multi_pod: bool = False, dispatch: str = "ragged",
                 compressed: bool = False, graph=None,
                 compact_threshold: float | None = 0.25,
                 compact_kwargs: dict | None = None,
                 max_wait_us: float | None = None, min_batch: int = 1,
                 flush_timeout_ms: float | None = None,
                 max_retries: int = 3, backoff_base_ms: float = 1.0,
                 backoff_factor: float = 2.0, jitter: float = 0.5,
                 probe_interval: int = 8, retry_seed: int = 0,
                 wal_path: str | None = None, wal_fsync: bool = True,
                 engine_wrapper=None):
        # layout="csr" serves from the CSR-packed store; dispatch="ragged"
        # (default) answers each flush with ONE megakernel launch over the
        # lane-tiled arena — flush_async is plan-free on host — while
        # dispatch="bucket_pair" keeps the per-bucket-pair dispatch loop
        # (the differential oracle). compressed=True (csr + ragged only)
        # serves from the bf16/delta-coded arena (`CompressedArena`) —
        # ~2.4x the rows per device, hub ids exact, distances within the
        # documented bound.
        # A PackedWCIndex (device-resident batched builder output) is served
        # as-is under layout="csr" — no repack between build and serve.
        # undirected=False disables the symmetric (s <= t) memo
        # canonicalization for indices over directed graphs, where
        # d(s, t) != d(t, s) and the swap would alias distinct answers.
        # interpret=None resolves via kernels.ops.resolve_interpret —
        # compiled kernels on TPU, interpret emulation elsewhere.
        # graph= turns the server dynamic: idx wraps into a `DynamicWCIndex`
        # and `apply_updates` / `compact` become available; every answer is
        # stamped with the graph version it was computed against, and
        # `result_with_staleness` exposes the stamp (docs/dynamic-index.md).
        # compact_threshold triggers `compact()` when the delta grows past
        # that fraction of the base store (None disables auto-compaction).
        # max_wait_us/min_batch turn on continuous batching: once
        # min_batch requests are queued a flush fires when the in-flight
        # slot is free/finished (opportunistic) or when the oldest queued
        # request has waited max_wait_us (deadline) — max_batch remains
        # the hard cap. max_wait_us=None keeps the epoch-flush behavior.
        # flush_timeout_ms/max_retries/backoff_*/jitter arm the flush
        # watchdog: a flush that exceeds the deadline or raises is
        # cancelled and the SAME batch re-dispatched with exponential
        # backoff; an exhausted budget demotes the server one rung down
        # its fallback ladder (see `mode`), and probe_interval healthy
        # flushes re-promote it. wal_path= turns on the crash-safe update
        # WAL (every apply_updates batch is logged before the index is
        # touched; `replay_wal` warm-starts a replica). engine_wrapper=
        # wraps every engine the server builds (chaos fault injection —
        # checkpoint/fault.py `FaultyEngine`); it survives rebuilds.
        self.index = None
        self.compact_threshold = compact_threshold
        self._compact_kwargs = dict(compact_kwargs or {})
        self.retry_policy = RetryPolicy(
            flush_timeout_ms=flush_timeout_ms, max_retries=int(max_retries),
            backoff_base_ms=float(backoff_base_ms),
            backoff_factor=float(backoff_factor), jitter=float(jitter),
            probe_interval=int(probe_interval))
        self._retry_rng = np.random.default_rng(retry_seed)
        self._engine_wrapper = engine_wrapper
        self._ladder = None          # injected engines have no fallback
        self.mode_index = 0
        self._healthy = 0            # consecutive retry-free drains
        self._retry_snapshot = 0     # retry-event total at last drain
        self._retrying = False       # a drain is mid-retry: poll() backs off
        if engine is not None:
            if graph is not None:
                raise ValueError("graph= (dynamic serving) cannot be "
                                 "combined with an injected engine= — the "
                                 "server must be able to rebuild the engine "
                                 "after an update")
            self.engine = engine
        elif idx is None:
            raise ValueError("WCSDServer needs an index (idx=) or a "
                             "prebuilt engine (engine=)")
        else:
            if graph is not None and not isinstance(idx, DynamicWCIndex):
                idx = DynamicWCIndex(idx, graph)
            self.index = idx
            self._engine_config = dict(
                backend=backend, use_pallas=use_pallas, interpret=interpret,
                layout=layout, dispatch=dispatch, compressed=compressed,
                mesh=mesh, device_budget_bytes=device_budget_bytes,
                multi_pod=multi_pod)
            self._ladder = build_fallback_ladder(self._engine_config)
            self.engine = self._make_engine()
        self.wal = None
        if wal_path is not None:
            from ..checkpoint.ckpt import UpdateWAL
            self.wal = UpdateWAL(wal_path, base_version=self.graph_version,
                                 fsync=wal_fsync)
        self.max_batch = int(max_batch)
        self.max_wait_us = None if max_wait_us is None else float(max_wait_us)
        self.min_batch = max(1, int(min_batch))
        self.undirected = bool(undirected)
        self.memo: collections.OrderedDict[tuple, int] = collections.OrderedDict()
        self.memo_capacity = memo_capacity
        self.pending: list[tuple[int, int, int, int]] = []  # (rid, s, t, wl)
        self._pending_rids: set[int] = set()  # O(1) result() membership
        # pending-batch dedup: key -> position in self.pending, plus the
        # piggyback rids riding that position (mirrors _inflight_extra) —
        # a hot key submitted twice before a flush must occupy ONE device
        # slot, not two
        self._pending_pos: dict[tuple, int] = {}
        self._pending_extra: list[tuple[int, int]] = []
        self.results: dict[int, int] = {}
        # the (single) in-flight batch: (handle, rids, keys) or None
        self._inflight: Optional[tuple[PendingResult, list, list]] = None
        self._inflight_rids: set[int] = set()
        self._inflight_pos: dict[tuple, int] = {}   # key -> batch position
        self._inflight_extra: list[tuple[int, int]] = []  # (rid, position)
        # profile (staircase) requests ride the same double-buffered flush:
        # a flush dispatches one scalar batch AND one profile batch, the
        # pair forming the single in-flight slot
        self.profile_memo: collections.OrderedDict[tuple, np.ndarray] = \
            collections.OrderedDict()
        self.pending_profiles: list[tuple[int, int, int]] = []  # (rid, s, t)
        self._pending_prof_rids: set[int] = set()
        self._pending_prof_pos: dict[tuple, int] = {}
        self._pending_prof_extra: list[tuple[int, int]] = []
        self.profile_results: dict[int, np.ndarray] = {}
        self._inflight_prof: Optional[tuple[PendingResult, list, list]] = None
        self._inflight_prof_rids: set[int] = set()
        self._inflight_prof_pos: dict[tuple, int] = {}
        self._inflight_prof_extra: list[tuple[int, int]] = []
        self._next_rid = 0
        # graph version each delivered answer was computed against
        # (popped together with the answer; backs the staleness flags)
        self.result_versions: dict[int, int] = {}
        self.profile_result_versions: dict[int, int] = {}
        # fallback-ladder mode each answer was computed under ("memo" for
        # cache hits); popped with the answer, read via result_with_mode
        self.result_modes: dict[int, str] = {}
        self.profile_result_modes: dict[int, str] = {}
        # the in-flight batches' raw request tuples + dispatch closures:
        # what the watchdog re-dispatches on a retry and re-queues on a
        # terminal failure (requests are never dropped)
        self._inflight_batch: list | None = None
        self._inflight_dispatch = None
        self._inflight_prof_batch: list | None = None
        self._inflight_prof_dispatch = None
        # enqueue→deliver latency: stamped per rid at submit, recorded
        # (µs) the moment the answer lands in the result dict
        self._enqueue_t: dict[int, float] = {}
        self.latencies_us: list[float] = []
        self._pending_since: float | None = None  # oldest queued enqueue
        self.stats = ServeStats()

    # ------------------------------------------------------------- dynamic
    def _make_engine(self):
        cfg = (self._ladder[self.mode_index][1]
               if self._ladder is not None else self._engine_config)
        eng = self._build_engine(cfg)
        if self._engine_wrapper is not None:
            eng = self._engine_wrapper(eng)
        return eng

    def _build_engine(self, cfg):
        if cfg["backend"] == "device":
            return DeviceQueryEngine(
                self.index, use_pallas=cfg["use_pallas"],
                interpret=cfg["interpret"], layout=cfg["layout"],
                dispatch=cfg["dispatch"], compressed=cfg["compressed"])
        if cfg["backend"] == "sharded":
            return ShardedQueryEngine(
                self.index, mesh=cfg["mesh"], use_pallas=cfg["use_pallas"],
                interpret=cfg["interpret"], layout=cfg["layout"],
                device_budget_bytes=cfg["device_budget_bytes"],
                multi_pod=cfg["multi_pod"], dispatch=cfg["dispatch"],
                compressed=cfg["compressed"])
        raise ValueError(f"unknown backend: {cfg['backend']!r} "
                         "(expected 'device' or 'sharded')")

    @property
    def graph_version(self) -> int:
        return int(getattr(self.index, "graph_version", 0))

    # ---------------------------------------------------------- resilience
    @property
    def mode(self) -> str:
        """The fallback-ladder rung currently serving ("primary" when
        healthy; "injected" for engine= servers, which have no ladder).
        Every delivered answer is stamped with the mode that produced it
        (`result_with_mode`)."""
        if self._ladder is None:
            return "injected"
        return self._ladder[self.mode_index][0]

    def _demote(self) -> bool:
        """Step one rung down the fallback ladder (rebuilding the engine
        in place) after an exhausted retry budget. False at the bottom —
        nothing left to fall back to. The memos survive: every rung
        serves the same index, so answers are mode-independent."""
        if self._ladder is None or self.mode_index >= len(self._ladder) - 1:
            return False
        self.mode_index += 1
        self.stats.demotions += 1
        self._healthy = 0
        self.engine = self._make_engine()
        return True

    def _stamp_deadline(self, handle) -> None:
        p = self.retry_policy
        if p.flush_timeout_ms is not None:
            try:
                handle.deadline = (time.monotonic()
                                   + p.flush_timeout_ms / 1e3)
            except AttributeError:
                pass  # foreign handle type without the attribute

    def _dispatch_with_retry(self, dispatch):
        """Run a zero-arg dispatch closure under the watchdog: a raise is
        retried with exponential backoff + jitter up to ``max_retries``;
        an exhausted budget demotes one rung (resetting the budget) or —
        at the bottom of the ladder — re-raises as `FlushRetryExhausted`
        with the pending queue intact. The closure reads ``self.engine``
        at call time, so a retry after a demotion uses the new engine."""
        p = self.retry_policy
        attempt = 0
        while True:
            try:
                handle = dispatch()
            except Exception as err:
                attempt += 1
                if attempt > p.max_retries:
                    self.stats.exhausted += 1
                    if self._demote():
                        attempt = 0
                    else:
                        raise FlushRetryExhausted(
                            f"dispatch failed after {p.max_retries} "
                            f"retries at mode {self.mode!r} (bottom of "
                            "the fallback ladder); the requests are "
                            "still queued") from err
                else:
                    self.stats.error_retries += 1
                time.sleep(p.backoff_s(max(attempt, 1), self._retry_rng))
                continue
            self._stamp_deadline(handle)
            return handle

    def _await_handle(self, handle, redispatch):
        """`handle.wait()` under the watchdog. A handle past its deadline
        that still is not ready is abandoned (device work is not
        interruptible — its result is simply never read) and the SAME
        batch re-dispatched via ``redispatch``; a raising wait() retries
        the same way. Exhaustion demotes one rung and resets the budget;
        at the bottom it raises `FlushRetryExhausted` (the caller
        re-queues the batch — nothing is dropped)."""
        p = self.retry_policy
        attempt = 0
        while True:
            timed_out, err = False, None
            deadline = getattr(handle, "deadline", None)
            if deadline is not None:
                while not handle.ready():
                    if time.monotonic() > deadline:
                        timed_out = True
                        break
                    time.sleep(1e-4)
            if not timed_out:
                try:
                    return handle.wait()
                except Exception as e:
                    err = e
            attempt += 1
            if attempt > p.max_retries:
                self.stats.exhausted += 1
                if self._demote():
                    attempt = 0
                else:
                    raise FlushRetryExhausted(
                        f"flush failed after {p.max_retries} retries at "
                        f"mode {self.mode!r} (bottom of the fallback "
                        "ladder); the batch has been re-queued") from err
            elif timed_out:
                self.stats.timeout_retries += 1
            else:
                self.stats.error_retries += 1
            time.sleep(p.backoff_s(max(attempt, 1), self._retry_rng))
            handle = redispatch()

    def apply_updates(self, inserts=(), deletes=()) -> dict:
        """Mutate the served graph and fold the label corrections into the
        delta store (`DynamicWCIndex.apply_updates`). In-flight and pending
        requests are flushed FIRST: their answers stay valid for the graph
        version they were stamped with, and read back as stale. The scalar
        and profile memos are dropped (their entries answer the old graph)
        and the engine is rebuilt over the delta-extended store. Crossing
        ``compact_threshold`` triggers `compact` before returning.

        With a WAL attached (``wal_path=``), the mutation batch is logged
        — checksummed and fsynced — BEFORE the index is touched: a crash
        anywhere after the append loses nothing, because a replica
        warm-starting from the last checkpoint replays the tail
        (`replay_wal`) and converges to the pre-crash graph version."""
        if not isinstance(self.index, DynamicWCIndex):
            raise ValueError("apply_updates requires a dynamic server — "
                             "construct WCSDServer(idx, graph=g, ...)")
        self.flush()
        inserts = [(int(u), int(v), float(q)) for u, v, q in inserts]
        deletes = [(int(u), int(v)) for u, v in deletes]
        if self.wal is not None:
            self.wal.append(inserts, deletes,
                            graph_version=self.graph_version + 1)
            self.stats.wal_appends += 1
        stats = self.index.apply_updates(inserts=inserts, deletes=deletes)
        self.memo.clear()
        self.profile_memo.clear()
        self.engine = self._make_engine()
        stats["compacted"] = False
        if (self.compact_threshold is not None
                and self.index.delta_ratio() >= self.compact_threshold):
            self.compact()
            stats["compacted"] = True
        return stats

    def compact(self, **build_kwargs) -> dict:
        """Fold the delta into a fresh immutable base store (fused Pareto
        pass + arena re-pack; byte-identical to a from-scratch build on the
        current graph) and rebuild the engine over it. Answers are unchanged
        by construction, so the memos survive compaction."""
        if not isinstance(self.index, DynamicWCIndex):
            raise ValueError("compact requires a dynamic server — "
                             "construct WCSDServer(idx, graph=g, ...)")
        self.flush()
        kw = dict(self._compact_kwargs)
        kw.update(build_kwargs)
        stats = self.index.compact(**kw)
        self.engine = self._make_engine()
        if self.wal is not None:
            # the compacted base now embodies every logged record: restart
            # the log at the current version (atomic header rewrite)
            self.wal.truncate(self.graph_version)
        return stats

    def replay_wal(self) -> int:
        """Warm start: re-apply the WAL tail past the server's current
        graph version, in order, converging to the pre-crash state.
        Returns the number of records applied. Raises `WALReplayError`
        when the log does not reach back to this server's version (it was
        compacted past the checkpoint this replica loaded). Replayed
        batches are NOT re-appended to the log — they are already in it."""
        if self.wal is None:
            raise ValueError("replay_wal requires a WAL-backed server — "
                             "construct WCSDServer(..., wal_path=...)")
        if not isinstance(self.index, DynamicWCIndex):
            raise ValueError("replay_wal requires a dynamic server — "
                             "construct WCSDServer(idx, graph=g, ...)")
        n = 0
        for rec in self.wal.replay(self.graph_version):
            if rec["graph_version"] != self.graph_version + 1:
                raise WALReplayError(
                    f"WAL record jumps to graph version "
                    f"{rec['graph_version']} but the server is at "
                    f"{self.graph_version}")
            self.flush()
            self.index.apply_updates(
                inserts=[(int(u), int(v), float(q))
                         for u, v, q in rec["inserts"]],
                deletes=[(int(u), int(v)) for u, v in rec["deletes"]])
            n += 1
        if n:
            self.memo.clear()
            self.profile_memo.clear()
            self.engine = self._make_engine()
        return n

    def _memo_key(self, s: int, t: int, w_level: int) -> tuple:
        if self.undirected and s > t:
            return (t, s, w_level)
        return (s, t, w_level)

    def _profile_key(self, s: int, t: int) -> tuple:
        # per-level distances are symmetric exactly when single-level ones
        # are, so the profile key follows the same directed gate
        if self.undirected and s > t:
            return (t, s)
        return (s, t)

    # ------------------------------------------------------------- requests
    def _deliver(self, rid: int) -> None:
        """Record the enqueue→deliver latency of a rid whose answer just
        landed in the result dict."""
        t0 = self._enqueue_t.pop(rid, None)
        if t0 is not None:
            self.latencies_us.append((time.perf_counter() - t0) * 1e6)

    def submit(self, s: int, t: int, w_level: int) -> int:
        """Queue one request; returns a request id."""
        rid = self._next_rid
        self._next_rid += 1
        key = self._memo_key(s, t, w_level)
        pkey = self._profile_key(s, t)
        self.stats.requests += 1
        self._enqueue_t[rid] = time.perf_counter()
        if key in self.memo:
            self.memo.move_to_end(key)
            self.results[rid] = self.memo[key]
            self.result_versions[rid] = self.graph_version
            self.result_modes[rid] = "memo"
            self.stats.memo_hits += 1
            self._deliver(rid)
        elif (pkey in self.profile_memo
              and 0 <= w_level <= getattr(self.engine, "num_levels", -1)):
            # a cached profile answers EVERY level of its pair: read the
            # staircase instead of queueing device work, and promote the
            # level into the scalar memo so exact repeats stay O(1)
            self.profile_memo.move_to_end(pkey)
            self.results[rid] = int(self.profile_memo[pkey][w_level])
            self.result_versions[rid] = self.graph_version
            self.result_modes[rid] = "memo"
            self._memo_put(key, self.results[rid])
            self.stats.memo_hits += 1
            self._deliver(rid)
        elif key in self._inflight_pos:
            # the answer is already being computed in the in-flight batch:
            # piggyback on it instead of re-queueing the hot key (counted
            # as a memo hit — no extra device work happens)
            self._inflight_extra.append((rid, self._inflight_pos[key]))
            self._inflight_rids.add(rid)
            self.stats.memo_hits += 1
        elif key in self._pending_pos:
            # already queued but not yet dispatched: ride the queued
            # request's batch slot instead of occupying a second one
            self._pending_extra.append((rid, self._pending_pos[key]))
            self._pending_rids.add(rid)
            self.stats.memo_hits += 1
        else:
            if not self.pending and not self.pending_profiles:
                self._pending_since = time.perf_counter()
            self._pending_pos[key] = len(self.pending)
            self.pending.append((rid, s, t, w_level))
            self._pending_rids.add(rid)
            self._maybe_flush()
        return rid

    def submit_profile(self, s: int, t: int) -> int:
        """Queue one profile request — the full ``dist(s, t, w)`` staircase
        for every level 0..num_levels, answered by ONE label sweep (see
        `DeviceQueryEngine.query_profile`). Returns a request id for
        `profile_result`."""
        rid = self._next_rid
        self._next_rid += 1
        key = self._profile_key(s, t)
        self.stats.profile_requests += 1
        self._enqueue_t[rid] = time.perf_counter()
        if key in self.profile_memo:
            self.profile_memo.move_to_end(key)
            self.profile_results[rid] = self.profile_memo[key].copy()
            self.profile_result_versions[rid] = self.graph_version
            self.profile_result_modes[rid] = "memo"
            self.stats.memo_hits += 1
            self._deliver(rid)
        elif key in self._inflight_prof_pos:
            self._inflight_prof_extra.append(
                (rid, self._inflight_prof_pos[key]))
            self._inflight_prof_rids.add(rid)
            self.stats.memo_hits += 1
        elif key in self._pending_prof_pos:
            self._pending_prof_extra.append(
                (rid, self._pending_prof_pos[key]))
            self._pending_prof_rids.add(rid)
            self.stats.memo_hits += 1
        else:
            if not self.pending and not self.pending_profiles:
                self._pending_since = time.perf_counter()
            self._pending_prof_pos[key] = len(self.pending_profiles)
            self.pending_profiles.append((rid, s, t))
            self._pending_prof_rids.add(rid)
            self._maybe_flush()
        return rid

    def _slot_done(self) -> bool:
        """True iff a batch is in flight AND its device work has finished
        (a drain would not block)."""
        if self._inflight is None and self._inflight_prof is None:
            return False
        return ((self._inflight is None or self._inflight[0].ready())
                and (self._inflight_prof is None
                     or self._inflight_prof[0].ready()))

    def _maybe_flush(self) -> None:
        """Continuous-batching admission: fire a flush when the hard cap
        is hit, or — with ``max_wait_us`` enabled and at least
        ``min_batch`` queued — when the in-flight slot is free/finished
        (opportunistic) or the oldest queued request has aged past the
        deadline. No-op while a retry is in progress: dispatching a new
        batch mid-retry would race the half-retried slot."""
        if self._retrying:
            return
        npend = len(self.pending) + len(self.pending_profiles)
        if npend >= self.max_batch:
            # async: dispatch only — the device chews on this batch
            # while the host accepts and plans the next one
            self.flush_async()
            return
        if self.max_wait_us is None or npend < self.min_batch:
            return
        if self._inflight is None and self._inflight_prof is None \
                or self._slot_done():
            self.stats.opportunistic_flushes += 1
            self.flush_async()
        elif (self._pending_since is not None
              and (time.perf_counter() - self._pending_since) * 1e6
              >= self.max_wait_us):
            self.stats.deadline_flushes += 1
            self.flush_async()

    def poll(self) -> None:
        """Deadline tick for continuous batching: harvest the in-flight
        batch if its device work is done (delivering its results without
        blocking) and re-check the flush triggers. Callers with gaps
        between submissions call this to bound queueing delay; `submit`
        runs the same checks on every enqueue.

        Re-entrancy guard: while the watchdog is mid-retry (a drain
        re-dispatched a timed-out or raising batch and is waiting on the
        replacement handle), the in-flight slot is half-retried state —
        harvesting it, or dispatching a new batch over it, would deliver
        from the abandoned handle or race two batches on one engine.
        `poll` during a retry is a no-op; the retrying drain delivers."""
        if self._retrying:
            return
        if self._slot_done():
            self._drain()
        self._maybe_flush()

    def latency_summary(self) -> dict:
        """p50/p99 (µs) of enqueue→deliver latency over every delivered
        request so far (memo hits included — they deliver at enqueue).
        Before anything has completed the percentiles are zeros with
        ``n == count == 0`` — never an exception."""
        if not self.latencies_us:
            return {"count": 0, "n": 0, "p50_us": 0.0, "p99_us": 0.0}
        arr = np.asarray(self.latencies_us)
        return {"count": int(arr.size), "n": int(arr.size),
                "p50_us": float(np.percentile(arr, 50)),
                "p99_us": float(np.percentile(arr, 99))}

    def _memo_put(self, key: tuple, value: int) -> None:
        self.memo[key] = value
        if len(self.memo) > self.memo_capacity:
            self.memo.popitem(last=False)

    def flush_async(self) -> None:
        """Dispatch the pending batch without waiting for its results.

        Double-buffered: at most one batch is in flight, so dispatching
        batch k+1 first drains batch k (by then typically long finished).
        A flush dispatches the pending scalar batch AND the pending profile
        batch (either may be empty); together they form the in-flight slot.

        Failure semantics (docs/resilience.md): the pending queue is
        cleared only AFTER its dispatch returns, and the dispatch itself
        runs under the flush watchdog — an engine raise (sharded gather
        OOM, a poisoned compile cache, an injected chaos fault, ...) is
        retried with backoff, then absorbed by a fallback-ladder demotion;
        only at the bottom of the ladder does `FlushRetryExhausted`
        propagate, with every queued request still pending — a later
        flush retries the same batch and `result(rid)` still
        blocks-and-answers instead of failing forever.
        """
        if not self.pending and not self.pending_profiles:
            return
        self._drain()
        t0 = time.perf_counter()
        # pad to the next power of two (bounded recompiles); the csr engine
        # pads each planned sub-batch itself, and the sharded engine pads to
        # its own device multiple, so padding here would only add dummy
        # queries that the kernels compute and discard
        pad_here = (getattr(self.engine, "layout", "padded") == "padded"
                    and not isinstance(self.engine, ShardedQueryEngine))
        if self.pending:
            batch = self.pending
            n = len(batch)
            padded = round_to_pow2(n) if pad_here else n
            s = np.zeros(padded, dtype=np.int32)
            t = np.zeros(padded, dtype=np.int32)
            wl = np.zeros(padded, dtype=np.int32)
            s[:n] = [b[1] for b in batch]
            t[:n] = [b[2] for b in batch]
            wl[:n] = [b[3] for b in batch]

            def dispatch(s=s, t=t, wl=wl):
                # reads self.engine at call time, so a retry after a
                # fallback-ladder demotion dispatches to the new engine
                qa = getattr(self.engine, "query_async", None)
                if qa is not None:
                    return qa(s, t, wl)
                # engine exposes only a blocking query (tests stub this)
                res = self.engine.query(s, t, wl)
                return PendingResult(lambda: res)

            # dispatch BEFORE the queue is cleared (see docstring)
            handle = self._dispatch_with_retry(dispatch)
            keys = [self._memo_key(b[1], b[2], b[3]) for b in batch]
            self._inflight = (handle, [b[0] for b in batch], keys)
            self._inflight_batch = batch
            self._inflight_dispatch = dispatch
            # pending piggybacks ride over: positions are batch positions
            self._inflight_rids = ({b[0] for b in batch}
                                   | {r for r, _ in self._pending_extra})
            self._inflight_pos = {k: i for i, k in enumerate(keys)}
            self._inflight_extra = list(self._pending_extra)
            self.pending = []
            self._pending_rids = set()
            self._pending_pos = {}
            self._pending_extra = []
            self.stats.max_batch = max(self.stats.max_batch, n)
        if self.pending_profiles:
            batch = self.pending_profiles
            n = len(batch)
            padded = round_to_pow2(n) if pad_here else n
            s = np.zeros(padded, dtype=np.int32)
            t = np.zeros(padded, dtype=np.int32)
            s[:n] = [b[1] for b in batch]
            t[:n] = [b[2] for b in batch]

            def prof_dispatch(s=s, t=t):
                qa = getattr(self.engine, "query_profile_async", None)
                if qa is not None:
                    return qa(s, t)
                res = self.engine.query_profile(s, t)
                return PendingResult(lambda: res)

            handle = self._dispatch_with_retry(prof_dispatch)
            keys = [self._profile_key(b[1], b[2]) for b in batch]
            self._inflight_prof = (handle, [b[0] for b in batch], keys)
            self._inflight_prof_batch = batch
            self._inflight_prof_dispatch = prof_dispatch
            self._inflight_prof_rids = ({b[0] for b in batch}
                                        | {r for r, _ in
                                           self._pending_prof_extra})
            self._inflight_prof_pos = {k: i for i, k in enumerate(keys)}
            self._inflight_prof_extra = list(self._pending_prof_extra)
            self.pending_profiles = []
            self._pending_prof_rids = set()
            self._pending_prof_pos = {}
            self._pending_prof_extra = []
            self.stats.max_batch = max(self.stats.max_batch, n)
        self._pending_since = None
        self.stats.batches += 1
        self.stats.dispatch_time_s += time.perf_counter() - t0

    def _requeue_scalar(self, batch, extra) -> None:
        """Put a terminally-failed in-flight batch back at the FRONT of
        the pending queue (nothing is dropped): existing pending
        positions and piggyback slots shift by the batch length; the
        failed batch's own piggybacks keep their 0-based positions."""
        n = len(batch)
        self.pending = list(batch) + self.pending
        shifted = {k: p + n for k, p in self._pending_pos.items()}
        for i, b in enumerate(batch):
            # on a duplicate key the queued copy wins (it already carries
            # piggybacks pointing at its shifted position)
            shifted.setdefault(self._memo_key(b[1], b[2], b[3]), i)
        self._pending_pos = shifted
        self._pending_extra = ([(r, p) for r, p in extra]
                               + [(r, p + n) for r, p in self._pending_extra])
        self._pending_rids |= {b[0] for b in batch} | {r for r, _ in extra}
        if self._pending_since is None:
            self._pending_since = time.perf_counter()

    def _requeue_profile(self, batch, extra) -> None:
        n = len(batch)
        self.pending_profiles = list(batch) + self.pending_profiles
        shifted = {k: p + n for k, p in self._pending_prof_pos.items()}
        for i, b in enumerate(batch):
            shifted.setdefault(self._profile_key(b[1], b[2]), i)
        self._pending_prof_pos = shifted
        self._pending_prof_extra = (
            [(r, p) for r, p in extra]
            + [(r, p + n) for r, p in self._pending_prof_extra])
        self._pending_prof_rids |= ({b[0] for b in batch}
                                    | {r for r, _ in extra})
        if self._pending_since is None:
            self._pending_since = time.perf_counter()

    def _drain(self) -> None:
        """Materialize the in-flight batch into results + memos.

        Runs under the flush watchdog: a timed-out or raising handle is
        re-dispatched with backoff (`_await_handle`); a terminal failure
        re-queues the batch and propagates. The ``_retrying`` guard makes
        the drain non-reentrant — `poll()` (including one issued
        re-entrantly by a retried engine) must not harvest the
        half-retried slot."""
        if self._retrying:
            return
        if self._inflight is None and self._inflight_prof is None:
            return
        t0 = time.perf_counter()
        ver = self.graph_version
        self._retrying = True
        try:
            if self._inflight is not None:
                handle, rids, keys = self._inflight
                extra = self._inflight_extra
                batch = self._inflight_batch
                dispatch = self._inflight_dispatch
                self._inflight = None
                self._inflight_rids = set()
                self._inflight_pos = {}
                self._inflight_extra = []
                self._inflight_batch = None
                self._inflight_dispatch = None
                try:
                    out = self._await_handle(
                        handle,
                        lambda: self._dispatch_with_retry(dispatch))
                except Exception:
                    self._requeue_scalar(batch, extra)
                    raise
                out = out[:len(rids)]
                mode = self.mode
                for rid, key, d in zip(rids, keys, out):
                    self.results[rid] = int(d)
                    self.result_versions[rid] = ver
                    self.result_modes[rid] = mode
                    self._memo_put(key, int(d))
                    self._deliver(rid)
                for rid, pos in extra:  # duplicates submitted in flight
                    self.results[rid] = int(out[pos])
                    self.result_versions[rid] = ver
                    self.result_modes[rid] = mode
                    self._deliver(rid)
            if self._inflight_prof is not None:
                handle, rids, keys = self._inflight_prof
                extra = self._inflight_prof_extra
                batch = self._inflight_prof_batch
                dispatch = self._inflight_prof_dispatch
                self._inflight_prof = None
                self._inflight_prof_rids = set()
                self._inflight_prof_pos = {}
                self._inflight_prof_extra = []
                self._inflight_prof_batch = None
                self._inflight_prof_dispatch = None
                try:
                    out = self._await_handle(
                        handle,
                        lambda: self._dispatch_with_retry(dispatch))
                except Exception:
                    self._requeue_profile(batch, extra)
                    raise
                out = np.asarray(out)[:len(rids)]
                mode = self.mode
                for rid, key, prof in zip(rids, keys, out):
                    # np.array COPIES: the memo must own its staircase,
                    # not a row view pinning the whole flushed batch
                    # buffer (and aliasing what profile_result hands out
                    # as caller-owned)
                    arr = np.array(prof, dtype=np.int32)
                    self.profile_results[rid] = arr.copy()
                    self.profile_result_versions[rid] = ver
                    self.profile_result_modes[rid] = mode
                    self.profile_memo[key] = arr
                    if len(self.profile_memo) > self.memo_capacity:
                        self.profile_memo.popitem(last=False)
                    self._deliver(rid)
                for rid, pos in extra:
                    self.profile_results[rid] = np.array(out[pos],
                                                         dtype=np.int32)
                    self.profile_result_versions[rid] = ver
                    self.profile_result_modes[rid] = mode
                    self._deliver(rid)
        finally:
            self._retrying = False
        self.stats.drain_wait_s += time.perf_counter() - t0
        # health accounting: a drain that completed with no new retry
        # events is a healthy flush; probe_interval of them in a row
        # re-promotes a degraded server one rung up the ladder
        events = (self.stats.timeout_retries + self.stats.error_retries
                  + self.stats.exhausted)
        if events == self._retry_snapshot:
            self._healthy += 1
        else:
            self._healthy = 0
        self._retry_snapshot = events
        if (self._ladder is not None and self.mode_index > 0
                and self._healthy >= self.retry_policy.probe_interval):
            self.mode_index -= 1
            self.stats.promotions += 1
            self._healthy = 0
            self.engine = self._make_engine()

    def flush(self) -> None:
        """Synchronous flush: dispatch anything pending and drain."""
        self.flush_async()
        self._drain()

    def result(self, rid: int) -> int:
        """Deliver (and evict) the answer for ``rid``.

        Read-once contract: a delivered rid is popped from the result dict,
        so per-request state cannot accumulate across a server's lifetime.
        An unknown — or already-delivered — rid raises the typed
        `UnknownRequestError` without disturbing the pending queue."""
        return self._pop_result(rid)[0]

    def _pop_result(self, rid: int):
        if rid not in self.results:
            if rid in self._inflight_rids:
                self._drain()
            elif rid in self._pending_rids:
                self.flush()
        if rid in self.results:
            return (self.results.pop(rid),
                    self.result_versions.pop(rid, self.graph_version),
                    self.result_modes.pop(rid, self.mode))
        raise UnknownRequestError(rid)

    def result_with_staleness(self, rid: int):
        """`result`, plus whether the answer predates the served graph:
        ``(value, stale)`` where ``stale`` is True iff the answer was
        computed against an earlier graph version than the server now
        holds (it was in flight or pending when `apply_updates` ran).
        Unknown rids raise `UnknownRequestError`."""
        value, ver, _mode = self._pop_result(rid)
        return value, ver < self.graph_version

    def result_with_mode(self, rid: int):
        """`result`, plus the fallback-ladder mode that computed the
        answer: ``(value, mode)`` where mode is a ladder rung name
        ("primary", "uncompressed", ..., "oracle") or "memo" for a cache
        hit. A degraded server keeps answering — correctly, from a
        simpler engine — and this is how callers see it happened."""
        value, _ver, mode = self._pop_result(rid)
        return value, mode

    def result_full(self, rid: int):
        """``(value, graph_version, mode)`` — the answer plus everything
        stamped on it (the chaos harness checks each answer against the
        oracle for exactly the graph version that produced it)."""
        return self._pop_result(rid)

    def profile_result(self, rid: int) -> np.ndarray:
        """Deliver (and evict) the ``[num_levels + 1]`` staircase for a
        `submit_profile` rid — the same read-once contract (and typed
        `UnknownRequestError`) as `result`. The delivered array is the
        caller's to keep (the memo holds its own copy)."""
        return self._pop_profile_result(rid)[0]

    def _pop_profile_result(self, rid: int):
        if rid not in self.profile_results:
            if rid in self._inflight_prof_rids:
                self._drain()
            elif rid in self._pending_prof_rids:
                self.flush()
        if rid in self.profile_results:
            return (self.profile_results.pop(rid),
                    self.profile_result_versions.pop(rid,
                                                     self.graph_version),
                    self.profile_result_modes.pop(rid, self.mode))
        raise UnknownRequestError(rid)

    def profile_result_with_staleness(self, rid: int):
        """`profile_result` + the staleness flag (see
        `result_with_staleness`)."""
        value, ver, _mode = self._pop_profile_result(rid)
        return value, ver < self.graph_version

    def profile_result_with_mode(self, rid: int):
        """`profile_result` + the producing mode (see
        `result_with_mode`)."""
        value, _ver, mode = self._pop_profile_result(rid)
        return value, mode

    def profile_result_full(self, rid: int):
        """``(staircase, graph_version, mode)`` (see `result_full`)."""
        return self._pop_profile_result(rid)

    # convenience: synchronous bulk APIs
    def query_many(self, s, t, w_level) -> np.ndarray:
        rids = [self.submit(int(a), int(b), int(c))
                for a, b, c in zip(s, t, w_level)]
        self.flush()
        return np.array([self.result(r) for r in rids], dtype=np.int32)

    def query_profile_many(self, s, t) -> np.ndarray:
        """[n, num_levels + 1] staircases for n (s, t) pairs."""
        rids = [self.submit_profile(int(a), int(b)) for a, b in zip(s, t)]
        self.flush()
        out = [self.profile_result(r) for r in rids]
        W1 = self.engine.num_levels + 1
        if not out:
            return np.zeros((0, W1), dtype=np.int32)
        return np.stack(out).astype(np.int32)

    def query_profile(self, s: int, t: int) -> np.ndarray:
        """Synchronous single-pair staircase."""
        return self.query_profile_many([s], [t])[0]

"""WCSD serving engine: request batching over the device query engines.

Mirrors the paper's query-serving scenario (10k random queries, µs/query):
requests accumulate into fixed-size (power-of-two) batches to avoid
recompilation, are answered by one fused device call, and per-request
results are handed back. A tiny LRU memo short-circuits repeated hot
queries (social-network workloads are heavy-tailed).

Production shape:

  * pluggable engine backend — ``backend="device"`` (single-device
    `DeviceQueryEngine`), ``backend="sharded"`` (`ShardedQueryEngine` over
    a mesh), or a prebuilt engine object via ``engine=``; ``layout`` /
    ``use_pallas`` / ``interpret`` are plumbed through, so serving can
    reach the *compiled* kernels instead of being pinned to interpret mode.
  * double-buffered async flush — an auto-flush (hitting ``max_batch``)
    only *dispatches* the batch (`engine.query_async`); while the device
    executes batch k, the host keeps accepting submissions for batch k+1.
    On the default ragged dispatch the batch PLAN itself is computed on
    device (`emit_ragged_worklist`), so a flush is host-plan-free; the
    bucket-pair dispatch still plans on host (`plan_query_batch`). At most
    one batch is in flight; launching the next one (or any
    result()/flush()) drains it.
  * continuous batching — with ``max_wait_us`` set, a flush no longer
    waits for ``max_batch``: once ``min_batch`` requests are queued, the
    batch dispatches as soon as the in-flight slot is free (or its device
    work is done — `PendingResult.ready` probes without blocking), and a
    trickle that never fills ``min_batch``-sized bursts is bounded by the
    ``max_wait_us`` deadline on the OLDEST queued request (checked on
    every submit and on `poll`). Per-request enqueue→deliver latency is
    recorded (`latency_summary` reports p50/p99 µs) and host flush time
    is split into dispatch vs drain-wait (`ServeStats`), so SLO math sees
    launch overhead and device wait separately.
  * read-once results — `result(rid)` pops the delivered answer, so a
    long-running server's result dict stays bounded by what is queued or
    in flight instead of growing one entry per request forever. Callers
    needing an answer twice re-submit (the memo makes that free).
  * profile (staircase) queries — `submit_profile(s, t)` /
    `query_profile_many` answer EVERY constraint level of a pair in one
    label sweep (`engine.query_profile`), riding the same double-buffered
    flush; a cached profile also short-circuits any single-level submit
    of its pair (see docs/profile-queries.md).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional

import numpy as np

from .query import DeviceQueryEngine, PendingResult, ShardedQueryEngine
from .wc_index import (DynamicWCIndex, PackedWCIndex, WCIndex,
                       round_to_pow2)


@dataclasses.dataclass
class ServeStats:
    requests: int = 0
    profile_requests: int = 0
    batches: int = 0
    memo_hits: int = 0
    dispatch_time_s: float = 0.0  # host time launching batches (flush_async)
    drain_wait_s: float = 0.0     # host time blocked on device results
    max_batch: int = 0
    deadline_flushes: int = 0     # flushes fired by the max_wait_us deadline
    opportunistic_flushes: int = 0  # flushes fired by a free in-flight slot

    @property
    def flush_time_s(self) -> float:
        # the pre-split lump (launch + drain), kept for bench-schema
        # compatibility; SLO math should use the two components — drain
        # wait is device time the host merely observes, dispatch time is
        # host overhead a faster frontend could shrink
        return self.dispatch_time_s + self.drain_wait_s


class WCSDServer:
    def __init__(self, idx: WCIndex | PackedWCIndex | None = None,
                 max_batch: int = 1024, use_pallas: bool = False,
                 memo_capacity: int = 65536, layout: str = "padded",
                 undirected: bool = True, interpret: bool | None = None,
                 backend: str = "device", engine=None, mesh=None,
                 device_budget_bytes: int | None = None,
                 multi_pod: bool = False, dispatch: str = "ragged",
                 compressed: bool = False, graph=None,
                 compact_threshold: float | None = 0.25,
                 compact_kwargs: dict | None = None,
                 max_wait_us: float | None = None, min_batch: int = 1):
        # layout="csr" serves from the CSR-packed store; dispatch="ragged"
        # (default) answers each flush with ONE megakernel launch over the
        # lane-tiled arena — flush_async is plan-free on host — while
        # dispatch="bucket_pair" keeps the per-bucket-pair dispatch loop
        # (the differential oracle). compressed=True (csr + ragged only)
        # serves from the bf16/delta-coded arena (`CompressedArena`) —
        # ~2.4x the rows per device, hub ids exact, distances within the
        # documented bound.
        # A PackedWCIndex (device-resident batched builder output) is served
        # as-is under layout="csr" — no repack between build and serve.
        # undirected=False disables the symmetric (s <= t) memo
        # canonicalization for indices over directed graphs, where
        # d(s, t) != d(t, s) and the swap would alias distinct answers.
        # interpret=None resolves via kernels.ops.resolve_interpret —
        # compiled kernels on TPU, interpret emulation elsewhere.
        # graph= turns the server dynamic: idx wraps into a `DynamicWCIndex`
        # and `apply_updates` / `compact` become available; every answer is
        # stamped with the graph version it was computed against, and
        # `result_with_staleness` exposes the stamp (docs/dynamic-index.md).
        # compact_threshold triggers `compact()` when the delta grows past
        # that fraction of the base store (None disables auto-compaction).
        # max_wait_us/min_batch turn on continuous batching: once
        # min_batch requests are queued a flush fires when the in-flight
        # slot is free/finished (opportunistic) or when the oldest queued
        # request has waited max_wait_us (deadline) — max_batch remains
        # the hard cap. max_wait_us=None keeps the epoch-flush behavior.
        self.index = None
        self.compact_threshold = compact_threshold
        self._compact_kwargs = dict(compact_kwargs or {})
        if engine is not None:
            if graph is not None:
                raise ValueError("graph= (dynamic serving) cannot be "
                                 "combined with an injected engine= — the "
                                 "server must be able to rebuild the engine "
                                 "after an update")
            self.engine = engine
        elif idx is None:
            raise ValueError("WCSDServer needs an index (idx=) or a "
                             "prebuilt engine (engine=)")
        else:
            if graph is not None and not isinstance(idx, DynamicWCIndex):
                idx = DynamicWCIndex(idx, graph)
            self.index = idx
            self._engine_config = dict(
                backend=backend, use_pallas=use_pallas, interpret=interpret,
                layout=layout, dispatch=dispatch, compressed=compressed,
                mesh=mesh, device_budget_bytes=device_budget_bytes,
                multi_pod=multi_pod)
            self.engine = self._make_engine()
        self.max_batch = int(max_batch)
        self.max_wait_us = None if max_wait_us is None else float(max_wait_us)
        self.min_batch = max(1, int(min_batch))
        self.undirected = bool(undirected)
        self.memo: collections.OrderedDict[tuple, int] = collections.OrderedDict()
        self.memo_capacity = memo_capacity
        self.pending: list[tuple[int, int, int, int]] = []  # (rid, s, t, wl)
        self._pending_rids: set[int] = set()  # O(1) result() membership
        # pending-batch dedup: key -> position in self.pending, plus the
        # piggyback rids riding that position (mirrors _inflight_extra) —
        # a hot key submitted twice before a flush must occupy ONE device
        # slot, not two
        self._pending_pos: dict[tuple, int] = {}
        self._pending_extra: list[tuple[int, int]] = []
        self.results: dict[int, int] = {}
        # the (single) in-flight batch: (handle, rids, keys) or None
        self._inflight: Optional[tuple[PendingResult, list, list]] = None
        self._inflight_rids: set[int] = set()
        self._inflight_pos: dict[tuple, int] = {}   # key -> batch position
        self._inflight_extra: list[tuple[int, int]] = []  # (rid, position)
        # profile (staircase) requests ride the same double-buffered flush:
        # a flush dispatches one scalar batch AND one profile batch, the
        # pair forming the single in-flight slot
        self.profile_memo: collections.OrderedDict[tuple, np.ndarray] = \
            collections.OrderedDict()
        self.pending_profiles: list[tuple[int, int, int]] = []  # (rid, s, t)
        self._pending_prof_rids: set[int] = set()
        self._pending_prof_pos: dict[tuple, int] = {}
        self._pending_prof_extra: list[tuple[int, int]] = []
        self.profile_results: dict[int, np.ndarray] = {}
        self._inflight_prof: Optional[tuple[PendingResult, list, list]] = None
        self._inflight_prof_rids: set[int] = set()
        self._inflight_prof_pos: dict[tuple, int] = {}
        self._inflight_prof_extra: list[tuple[int, int]] = []
        self._next_rid = 0
        # graph version each delivered answer was computed against
        # (popped together with the answer; backs the staleness flags)
        self.result_versions: dict[int, int] = {}
        self.profile_result_versions: dict[int, int] = {}
        # enqueue→deliver latency: stamped per rid at submit, recorded
        # (µs) the moment the answer lands in the result dict
        self._enqueue_t: dict[int, float] = {}
        self.latencies_us: list[float] = []
        self._pending_since: float | None = None  # oldest queued enqueue
        self.stats = ServeStats()

    # ------------------------------------------------------------- dynamic
    def _make_engine(self):
        cfg = self._engine_config
        if cfg["backend"] == "device":
            return DeviceQueryEngine(
                self.index, use_pallas=cfg["use_pallas"],
                interpret=cfg["interpret"], layout=cfg["layout"],
                dispatch=cfg["dispatch"], compressed=cfg["compressed"])
        if cfg["backend"] == "sharded":
            return ShardedQueryEngine(
                self.index, mesh=cfg["mesh"], use_pallas=cfg["use_pallas"],
                interpret=cfg["interpret"], layout=cfg["layout"],
                device_budget_bytes=cfg["device_budget_bytes"],
                multi_pod=cfg["multi_pod"], dispatch=cfg["dispatch"],
                compressed=cfg["compressed"])
        raise ValueError(f"unknown backend: {cfg['backend']!r} "
                         "(expected 'device' or 'sharded')")

    @property
    def graph_version(self) -> int:
        return int(getattr(self.index, "graph_version", 0))

    def apply_updates(self, inserts=(), deletes=()) -> dict:
        """Mutate the served graph and fold the label corrections into the
        delta store (`DynamicWCIndex.apply_updates`). In-flight and pending
        requests are flushed FIRST: their answers stay valid for the graph
        version they were stamped with, and read back as stale. The scalar
        and profile memos are dropped (their entries answer the old graph)
        and the engine is rebuilt over the delta-extended store. Crossing
        ``compact_threshold`` triggers `compact` before returning."""
        if not isinstance(self.index, DynamicWCIndex):
            raise ValueError("apply_updates requires a dynamic server — "
                             "construct WCSDServer(idx, graph=g, ...)")
        self.flush()
        stats = self.index.apply_updates(inserts=inserts, deletes=deletes)
        self.memo.clear()
        self.profile_memo.clear()
        self.engine = self._make_engine()
        stats["compacted"] = False
        if (self.compact_threshold is not None
                and self.index.delta_ratio() >= self.compact_threshold):
            self.compact()
            stats["compacted"] = True
        return stats

    def compact(self, **build_kwargs) -> dict:
        """Fold the delta into a fresh immutable base store (fused Pareto
        pass + arena re-pack; byte-identical to a from-scratch build on the
        current graph) and rebuild the engine over it. Answers are unchanged
        by construction, so the memos survive compaction."""
        if not isinstance(self.index, DynamicWCIndex):
            raise ValueError("compact requires a dynamic server — "
                             "construct WCSDServer(idx, graph=g, ...)")
        self.flush()
        kw = dict(self._compact_kwargs)
        kw.update(build_kwargs)
        stats = self.index.compact(**kw)
        self.engine = self._make_engine()
        return stats

    def _memo_key(self, s: int, t: int, w_level: int) -> tuple:
        if self.undirected and s > t:
            return (t, s, w_level)
        return (s, t, w_level)

    def _profile_key(self, s: int, t: int) -> tuple:
        # per-level distances are symmetric exactly when single-level ones
        # are, so the profile key follows the same directed gate
        if self.undirected and s > t:
            return (t, s)
        return (s, t)

    # ------------------------------------------------------------- requests
    def _deliver(self, rid: int) -> None:
        """Record the enqueue→deliver latency of a rid whose answer just
        landed in the result dict."""
        t0 = self._enqueue_t.pop(rid, None)
        if t0 is not None:
            self.latencies_us.append((time.perf_counter() - t0) * 1e6)

    def submit(self, s: int, t: int, w_level: int) -> int:
        """Queue one request; returns a request id."""
        rid = self._next_rid
        self._next_rid += 1
        key = self._memo_key(s, t, w_level)
        pkey = self._profile_key(s, t)
        self.stats.requests += 1
        self._enqueue_t[rid] = time.perf_counter()
        if key in self.memo:
            self.memo.move_to_end(key)
            self.results[rid] = self.memo[key]
            self.result_versions[rid] = self.graph_version
            self.stats.memo_hits += 1
            self._deliver(rid)
        elif (pkey in self.profile_memo
              and 0 <= w_level <= getattr(self.engine, "num_levels", -1)):
            # a cached profile answers EVERY level of its pair: read the
            # staircase instead of queueing device work, and promote the
            # level into the scalar memo so exact repeats stay O(1)
            self.profile_memo.move_to_end(pkey)
            self.results[rid] = int(self.profile_memo[pkey][w_level])
            self.result_versions[rid] = self.graph_version
            self._memo_put(key, self.results[rid])
            self.stats.memo_hits += 1
            self._deliver(rid)
        elif key in self._inflight_pos:
            # the answer is already being computed in the in-flight batch:
            # piggyback on it instead of re-queueing the hot key (counted
            # as a memo hit — no extra device work happens)
            self._inflight_extra.append((rid, self._inflight_pos[key]))
            self._inflight_rids.add(rid)
            self.stats.memo_hits += 1
        elif key in self._pending_pos:
            # already queued but not yet dispatched: ride the queued
            # request's batch slot instead of occupying a second one
            self._pending_extra.append((rid, self._pending_pos[key]))
            self._pending_rids.add(rid)
            self.stats.memo_hits += 1
        else:
            if not self.pending and not self.pending_profiles:
                self._pending_since = time.perf_counter()
            self._pending_pos[key] = len(self.pending)
            self.pending.append((rid, s, t, w_level))
            self._pending_rids.add(rid)
            self._maybe_flush()
        return rid

    def submit_profile(self, s: int, t: int) -> int:
        """Queue one profile request — the full ``dist(s, t, w)`` staircase
        for every level 0..num_levels, answered by ONE label sweep (see
        `DeviceQueryEngine.query_profile`). Returns a request id for
        `profile_result`."""
        rid = self._next_rid
        self._next_rid += 1
        key = self._profile_key(s, t)
        self.stats.profile_requests += 1
        self._enqueue_t[rid] = time.perf_counter()
        if key in self.profile_memo:
            self.profile_memo.move_to_end(key)
            self.profile_results[rid] = self.profile_memo[key].copy()
            self.profile_result_versions[rid] = self.graph_version
            self.stats.memo_hits += 1
            self._deliver(rid)
        elif key in self._inflight_prof_pos:
            self._inflight_prof_extra.append(
                (rid, self._inflight_prof_pos[key]))
            self._inflight_prof_rids.add(rid)
            self.stats.memo_hits += 1
        elif key in self._pending_prof_pos:
            self._pending_prof_extra.append(
                (rid, self._pending_prof_pos[key]))
            self._pending_prof_rids.add(rid)
            self.stats.memo_hits += 1
        else:
            if not self.pending and not self.pending_profiles:
                self._pending_since = time.perf_counter()
            self._pending_prof_pos[key] = len(self.pending_profiles)
            self.pending_profiles.append((rid, s, t))
            self._pending_prof_rids.add(rid)
            self._maybe_flush()
        return rid

    def _slot_done(self) -> bool:
        """True iff a batch is in flight AND its device work has finished
        (a drain would not block)."""
        if self._inflight is None and self._inflight_prof is None:
            return False
        return ((self._inflight is None or self._inflight[0].ready())
                and (self._inflight_prof is None
                     or self._inflight_prof[0].ready()))

    def _maybe_flush(self) -> None:
        """Continuous-batching admission: fire a flush when the hard cap
        is hit, or — with ``max_wait_us`` enabled and at least
        ``min_batch`` queued — when the in-flight slot is free/finished
        (opportunistic) or the oldest queued request has aged past the
        deadline."""
        npend = len(self.pending) + len(self.pending_profiles)
        if npend >= self.max_batch:
            # async: dispatch only — the device chews on this batch
            # while the host accepts and plans the next one
            self.flush_async()
            return
        if self.max_wait_us is None or npend < self.min_batch:
            return
        if self._inflight is None and self._inflight_prof is None \
                or self._slot_done():
            self.stats.opportunistic_flushes += 1
            self.flush_async()
        elif (self._pending_since is not None
              and (time.perf_counter() - self._pending_since) * 1e6
              >= self.max_wait_us):
            self.stats.deadline_flushes += 1
            self.flush_async()

    def poll(self) -> None:
        """Deadline tick for continuous batching: harvest the in-flight
        batch if its device work is done (delivering its results without
        blocking) and re-check the flush triggers. Callers with gaps
        between submissions call this to bound queueing delay; `submit`
        runs the same checks on every enqueue."""
        if self._slot_done():
            self._drain()
        self._maybe_flush()

    def latency_summary(self) -> dict:
        """p50/p99 (µs) of enqueue→deliver latency over every delivered
        request so far (memo hits included — they deliver at enqueue)."""
        if not self.latencies_us:
            return {"count": 0, "p50_us": 0.0, "p99_us": 0.0}
        arr = np.asarray(self.latencies_us)
        return {"count": int(arr.size),
                "p50_us": float(np.percentile(arr, 50)),
                "p99_us": float(np.percentile(arr, 99))}

    def _memo_put(self, key: tuple, value: int) -> None:
        self.memo[key] = value
        if len(self.memo) > self.memo_capacity:
            self.memo.popitem(last=False)

    def flush_async(self) -> None:
        """Dispatch the pending batch without waiting for its results.

        Double-buffered: at most one batch is in flight, so dispatching
        batch k+1 first drains batch k (by then typically long finished).
        A flush dispatches the pending scalar batch AND the pending profile
        batch (either may be empty); together they form the in-flight slot.

        Failure semantics: the pending queue is cleared only AFTER its
        dispatch returns — if the engine raises (sharded gather OOM, a
        poisoned compile cache, ...), every queued request stays pending
        and the exception propagates; a later flush retries the same
        batch and `result(rid)` still blocks-and-answers instead of
        returning None forever.
        """
        if not self.pending and not self.pending_profiles:
            return
        self._drain()
        t0 = time.perf_counter()
        # pad to the next power of two (bounded recompiles); the csr engine
        # pads each planned sub-batch itself, and the sharded engine pads to
        # its own device multiple, so padding here would only add dummy
        # queries that the kernels compute and discard
        pad_here = (getattr(self.engine, "layout", "padded") == "padded"
                    and not isinstance(self.engine, ShardedQueryEngine))
        if self.pending:
            batch = self.pending
            n = len(batch)
            padded = round_to_pow2(n) if pad_here else n
            s = np.zeros(padded, dtype=np.int32)
            t = np.zeros(padded, dtype=np.int32)
            wl = np.zeros(padded, dtype=np.int32)
            s[:n] = [b[1] for b in batch]
            t[:n] = [b[2] for b in batch]
            wl[:n] = [b[3] for b in batch]
            qa = getattr(self.engine, "query_async", None)
            # dispatch BEFORE the queue is cleared (see docstring)
            if qa is not None:
                handle = qa(s, t, wl)
            else:  # engine exposes only a blocking query (tests stub this)
                res = self.engine.query(s, t, wl)
                handle = PendingResult(lambda: res)
            keys = [self._memo_key(b[1], b[2], b[3]) for b in batch]
            self._inflight = (handle, [b[0] for b in batch], keys)
            # pending piggybacks ride over: positions are batch positions
            self._inflight_rids = ({b[0] for b in batch}
                                   | {r for r, _ in self._pending_extra})
            self._inflight_pos = {k: i for i, k in enumerate(keys)}
            self._inflight_extra = list(self._pending_extra)
            self.pending = []
            self._pending_rids = set()
            self._pending_pos = {}
            self._pending_extra = []
            self.stats.max_batch = max(self.stats.max_batch, n)
        if self.pending_profiles:
            batch = self.pending_profiles
            n = len(batch)
            padded = round_to_pow2(n) if pad_here else n
            s = np.zeros(padded, dtype=np.int32)
            t = np.zeros(padded, dtype=np.int32)
            s[:n] = [b[1] for b in batch]
            t[:n] = [b[2] for b in batch]
            qa = getattr(self.engine, "query_profile_async", None)
            if qa is not None:
                handle = qa(s, t)
            else:
                res = self.engine.query_profile(s, t)
                handle = PendingResult(lambda: res)
            keys = [self._profile_key(b[1], b[2]) for b in batch]
            self._inflight_prof = (handle, [b[0] for b in batch], keys)
            self._inflight_prof_rids = ({b[0] for b in batch}
                                        | {r for r, _ in
                                           self._pending_prof_extra})
            self._inflight_prof_pos = {k: i for i, k in enumerate(keys)}
            self._inflight_prof_extra = list(self._pending_prof_extra)
            self.pending_profiles = []
            self._pending_prof_rids = set()
            self._pending_prof_pos = {}
            self._pending_prof_extra = []
            self.stats.max_batch = max(self.stats.max_batch, n)
        self._pending_since = None
        self.stats.batches += 1
        self.stats.dispatch_time_s += time.perf_counter() - t0

    def _drain(self) -> None:
        """Materialize the in-flight batch into results + memos."""
        if self._inflight is None and self._inflight_prof is None:
            return
        t0 = time.perf_counter()
        ver = self.graph_version
        if self._inflight is not None:
            handle, rids, keys = self._inflight
            extra = self._inflight_extra
            self._inflight = None
            self._inflight_rids = set()
            self._inflight_pos = {}
            self._inflight_extra = []
            out = handle.wait()[:len(rids)]
            for rid, key, d in zip(rids, keys, out):
                self.results[rid] = int(d)
                self.result_versions[rid] = ver
                self._memo_put(key, int(d))
                self._deliver(rid)
            for rid, pos in extra:   # duplicates submitted while in flight
                self.results[rid] = int(out[pos])
                self.result_versions[rid] = ver
                self._deliver(rid)
        if self._inflight_prof is not None:
            handle, rids, keys = self._inflight_prof
            extra = self._inflight_prof_extra
            self._inflight_prof = None
            self._inflight_prof_rids = set()
            self._inflight_prof_pos = {}
            self._inflight_prof_extra = []
            out = np.asarray(handle.wait())[:len(rids)]
            for rid, key, prof in zip(rids, keys, out):
                # np.array COPIES: the memo must own its staircase, not a
                # row view pinning the whole flushed batch buffer (and
                # aliasing what profile_result hands out as caller-owned)
                arr = np.array(prof, dtype=np.int32)
                self.profile_results[rid] = arr.copy()
                self.profile_result_versions[rid] = ver
                self.profile_memo[key] = arr
                if len(self.profile_memo) > self.memo_capacity:
                    self.profile_memo.popitem(last=False)
                self._deliver(rid)
            for rid, pos in extra:
                self.profile_results[rid] = np.array(out[pos],
                                                     dtype=np.int32)
                self.profile_result_versions[rid] = ver
                self._deliver(rid)
        self.stats.drain_wait_s += time.perf_counter() - t0

    def flush(self) -> None:
        """Synchronous flush: dispatch anything pending and drain."""
        self.flush_async()
        self._drain()

    def result(self, rid: int) -> Optional[int]:
        """Deliver (and evict) the answer for ``rid``.

        Read-once contract: a delivered rid is popped from the result dict,
        so per-request state cannot accumulate across a server's lifetime.
        Unknown (or already-delivered) rids return None without disturbing
        the pending queue."""
        return self._pop_result(rid)[0]

    def _pop_result(self, rid: int):
        if rid not in self.results:
            if rid in self._inflight_rids:
                self._drain()
            elif rid in self._pending_rids:
                self.flush()
        if rid in self.results:
            return (self.results.pop(rid),
                    self.result_versions.pop(rid, self.graph_version))
        return None, None

    def result_with_staleness(self, rid: int):
        """`result`, plus whether the answer predates the served graph:
        ``(value, stale)`` where ``stale`` is True iff the answer was
        computed against an earlier graph version than the server now
        holds (it was in flight or pending when `apply_updates` ran).
        Unknown rids return ``(None, False)``."""
        value, ver = self._pop_result(rid)
        if value is None:
            return None, False
        return value, ver < self.graph_version

    def profile_result(self, rid: int) -> Optional[np.ndarray]:
        """Deliver (and evict) the ``[num_levels + 1]`` staircase for a
        `submit_profile` rid — the same read-once contract as `result`.
        The delivered array is the caller's to keep (the memo holds its
        own copy)."""
        return self._pop_profile_result(rid)[0]

    def _pop_profile_result(self, rid: int):
        if rid not in self.profile_results:
            if rid in self._inflight_prof_rids:
                self._drain()
            elif rid in self._pending_prof_rids:
                self.flush()
        if rid in self.profile_results:
            return (self.profile_results.pop(rid),
                    self.profile_result_versions.pop(rid, self.graph_version))
        return None, None

    def profile_result_with_staleness(self, rid: int):
        """`profile_result` + the staleness flag (see
        `result_with_staleness`)."""
        value, ver = self._pop_profile_result(rid)
        if value is None:
            return None, False
        return value, ver < self.graph_version

    # convenience: synchronous bulk APIs
    def query_many(self, s, t, w_level) -> np.ndarray:
        rids = [self.submit(int(a), int(b), int(c))
                for a, b, c in zip(s, t, w_level)]
        self.flush()
        return np.array([self.result(r) for r in rids], dtype=np.int32)

    def query_profile_many(self, s, t) -> np.ndarray:
        """[n, num_levels + 1] staircases for n (s, t) pairs."""
        rids = [self.submit_profile(int(a), int(b)) for a, b in zip(s, t)]
        self.flush()
        out = [self.profile_result(r) for r in rids]
        W1 = self.engine.num_levels + 1
        if not out:
            return np.zeros((0, W1), dtype=np.int32)
        return np.stack(out).astype(np.int32)

    def query_profile(self, s: int, t: int) -> np.ndarray:
        """Synchronous single-pair staircase."""
        return self.query_profile_many([s], [t])[0]

"""WC-INDEX: the paper's single 2-hop labeling answering arbitrary-w WCSD
queries (paper §IV, Algorithm 3).

Faithful construction = per-root pruned constrained BFS in *distance order*
(rounds) then *quality order* (the R array keeps only the best bottleneck
quality per vertex per round), pruned by querying the partially built index
(query-efficient form, §IV-C: per-root hub table T + Thm. 3 monotonicity).

All per-round work is vectorized numpy (no per-edge python loops); the same
relaxation is exposed as a jittable step for the JAX rank-batched builder
(`wc_index_batched.py`) and the Pallas `frontier` kernel.

Label entry layout (padded arrays, per vertex):
  hub_rank[v, i]  rank of the hub. Roots are processed in rank order and only
                  reach higher-ranked vertices, so entries arrive grouped and
                  ascending by hub; the self entry (rank[v], 0, "inf") is
                  appended last and keeps the order.
  dist[v, i]      w-constrained distance to the hub
  wlev[v, i]      quality *level* of the minimal path; ``num_levels`` encodes
                  the infinite quality of self entries.
Within one (vertex, hub) group both dist and wlev are strictly increasing
(Thm. 3) — this is what makes O(|L(s)|+|L(t)|) querying possible.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np

from .graph import Graph, INF_DIST, expand_frontier_csr
from .ordering import make_order
from .resilience import IndexIntegrityError


def _verify_blob_crcs(owner: str, checksums: dict, expected: dict) -> None:
    """Compare live blob CRC32s against a recorded baseline; any drift is
    corruption (bit rot, an injected flip, a torn copy) and must surface
    as a typed error — never as a wrong distance."""
    bad = sorted(name for name, crc in expected.items()
                 if checksums.get(name) != crc)
    if bad:
        raise IndexIntegrityError(
            f"{owner}: blob checksum mismatch in {bad} — the live arrays "
            "no longer match their recorded CRC32 baseline; refusing to "
            "serve")


def _concat_ranges(lengths: np.ndarray) -> np.ndarray:
    """[0..l0), [0..l1), ... concatenated, vectorized."""
    lengths = np.asarray(lengths, dtype=np.int64)
    total = int(lengths.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    cum = np.concatenate([[0], np.cumsum(lengths)[:-1]])
    return np.arange(total, dtype=np.int64) - np.repeat(cum, lengths)


def merge_query_rows(hs, ds, ws, ht, dt, wt, w_level: int) -> int:
    """Sort-merge over two hub-sorted label rows (paper Algorithm 5).

    Thm. 3: within a (vertex, hub) group dist & wlev are both ascending, so
    the FIRST entry with wlev >= w carries the minimal feasible distance.
    Shared by `WCIndex.query_one` (padded rows) and
    `PackedWCIndex.query_one` (CSR rows)."""
    cs, ct = len(hs), len(ht)
    best = int(INF_DIST)
    i = j = 0
    while i < cs and j < ct:
        if hs[i] < ht[j]:
            i += 1
        elif hs[i] > ht[j]:
            j += 1
        else:
            hub = hs[i]
            di = dj = -1
            while i < cs and hs[i] == hub:
                if di < 0 and ws[i] >= w_level:
                    di = int(ds[i])
                i += 1
            while j < ct and ht[j] == hub:
                if dj < 0 and wt[j] >= w_level:
                    dj = int(dt[j])
                j += 1
            if di >= 0 and dj >= 0 and di + dj < best:
                best = di + dj
    return best


@dataclasses.dataclass
class WCIndex:
    order: np.ndarray      # [V] rank -> vertex
    rank: np.ndarray       # [V] vertex -> rank
    levels: np.ndarray     # [W] quality values
    hub_rank: np.ndarray   # [V, cap]
    dist: np.ndarray       # [V, cap]
    wlev: np.ndarray       # [V, cap]
    count: np.ndarray      # [V]

    @property
    def num_levels(self) -> int:
        return int(len(self.levels))

    @property
    def num_nodes(self) -> int:
        return int(len(self.order))

    @property
    def label_capacity(self) -> int:
        return int(self.hub_rank.shape[1])

    def size_entries(self) -> int:
        return int(self.count.sum())

    def memory_bytes(self) -> int:
        # 3 int32 per entry + count array (logical size, not padded capacity)
        return int(self.size_entries() * 12 + self.count.nbytes)

    def labels_of(self, v: int) -> np.ndarray:
        """[(hub_vertex, dist, wlev)] rows, for inspection/tests."""
        c = int(self.count[v])
        return np.stack([self.order[self.hub_rank[v, :c]],
                         self.dist[v, :c], self.wlev[v, :c]], axis=1)

    def level_of(self, w: float) -> int:
        return int(np.searchsorted(self.levels, w, side="left"))

    # ------------------------------------------------------------- queries
    def query_one(self, s: int, t: int, w_level: int) -> int:
        """Single query: sort-merge over the two hub-sorted label lists
        (query-efficient implementation, paper Algorithm 5)."""
        cs, ct = int(self.count[s]), int(self.count[t])
        return merge_query_rows(self.hub_rank[s, :cs], self.dist[s, :cs],
                                self.wlev[s, :cs], self.hub_rank[t, :ct],
                                self.dist[t, :ct], self.wlev[t, :ct],
                                w_level)

    def query_batch(self, s: np.ndarray, t: np.ndarray, w_level: np.ndarray
                    ) -> np.ndarray:
        """Vectorized batched queries via masked outer join over padded labels
        (numpy mirror of the `wcsd_query` Pallas kernel)."""
        s = np.asarray(s); t = np.asarray(t); w_level = np.asarray(w_level)
        cap = self.hub_rank.shape[1]
        col = np.arange(cap)
        ms = (col[None, :] < self.count[s, None]) & \
             (self.wlev[s] >= w_level[:, None])
        mt = (col[None, :] < self.count[t, None]) & \
             (self.wlev[t] >= w_level[:, None])
        hub_eq = self.hub_rank[s][:, :, None] == self.hub_rank[t][:, None, :]
        ok = hub_eq & ms[:, :, None] & mt[:, None, :]
        dsum = self.dist[s][:, :, None].astype(np.int64) + \
            self.dist[t][:, None, :]
        dsum = np.where(ok, dsum, INF_DIST)
        return np.minimum(dsum.min(axis=(1, 2)), INF_DIST).astype(np.int32)

    def packed(self, lane: int = 128) -> "PackedLabels":
        """CSR-packed view of the labels (see `PackedLabels`)."""
        return PackedLabels.from_index(self, lane=lane)

    # ------------------------------------------------------- device mirrors
    def padded_device_arrays(self, cap: int | None = None):
        """(hub_rank, dist, wlev, count) trimmed/padded to ``cap`` columns,
        ready to ship to device for the Pallas query kernel.

        Trimming keeps the first ``cap - 1`` (hub-sorted, lowest-rank = most
        central) entries of an overlong row PLUS its trailing self entry
        ``(rank[v], 0, inf)`` — dropping the self entry would answer every
        ``s == t`` (and self-hub meet) query wrongly. The returned count is
        clamped to ``cap`` to match the physical rows."""
        c = int(cap if cap is not None else max(int(self.count.max()), 1))
        V = self.num_nodes
        def fit(a, fill):
            out = np.full((V, c), fill, dtype=np.int32)
            k = min(c, a.shape[1])
            out[:, :k] = a[:, :k]
            return out
        hub, dist, wlev = (fit(self.hub_rank, -1), fit(self.dist, INF_DIST),
                           fit(self.wlev, -1))
        over = np.flatnonzero(self.count > c)
        if len(over):
            last = self.count[over].astype(np.int64) - 1  # the self entry
            hub[over, c - 1] = self.hub_rank[over, last]
            dist[over, c - 1] = self.dist[over, last]
            wlev[over, c - 1] = self.wlev[over, last]
        return hub, dist, wlev, np.minimum(self.count, c).astype(np.int32)


LANE = 128  # TPU lane width; bucket tile widths are multiples of this


def round_to_lane(n: int, lane: int = LANE) -> int:
    """Smallest multiple of ``lane`` >= max(n, 1) — the width the dense
    device path actually ships a label row at."""
    return max(lane, -(-int(n) // lane) * lane)


def round_to_pow2(n: int) -> int:
    """Smallest power of two >= max(n, 1). Batch / scatter lengths are
    padded to powers of two so the count of compiled shapes stays
    logarithmic in the workload size."""
    return 1 << (max(int(n), 1) - 1).bit_length()


def ceil_to(n: int, m: int) -> int:
    """Smallest multiple of ``m`` >= ``n``."""
    return -(-int(n) // m) * m


@dataclasses.dataclass
class PackedLabels:
    """CSR-packed label store: the canonical compact format.

    The padded `[V, cap]` arrays on `WCIndex` are sized by the single worst
    vertex — on scale-free graphs one hub-heavy vertex inflates memory and
    query compare volume for *every* vertex. This store keeps exactly
    `size_entries()` label entries:

      hub_rank/dist/wlev : [E] flat arrays, vertex-major; within a vertex the
                           entries keep the hub-sorted Thm.-3 order.
      offsets            : [V+1] CSR row pointers; row v is
                           ``flat[offsets[v]:offsets[v+1]]``.

    For the device query path, vertices are additionally *length-bucketed*:
    bucket b holds every vertex whose label length fits in ``bucket_widths[b]``
    (lane-multiple widths in geometric progression: 128, 256, 512, ... so the
    number of compiled kernel variants stays logarithmic in the max label
    length). `bucket_tiles(b)` materializes bucket b as padded
    ``[n_b, bucket_widths[b]]`` tiles with the query-kernel pad contract
    (hub = -1, dist = INF_DIST, wlev = -1); total tile memory is
    ``sum_b n_b * W_b`` entries instead of ``V * cap``.
    """

    hub_rank: np.ndarray       # [E] int32
    dist: np.ndarray           # [E] int32
    wlev: np.ndarray           # [E] int32
    offsets: np.ndarray        # [V+1] int64
    bucket_widths: np.ndarray  # [NB] int32 padded widths, ascending
    bucket_of: np.ndarray      # [V] int32 bucket id per vertex
    slot_of: np.ndarray        # [V] int32 row of the vertex inside its bucket
    bucket_vertices: list      # [NB] arrays: bucket slot -> vertex id

    # ----------------------------------------------------------- construction
    @staticmethod
    def from_flat(hub: np.ndarray, dist: np.ndarray, wlev: np.ndarray,
                  offsets: np.ndarray, lane: int = LANE) -> "PackedLabels":
        """Wrap already-flat CSR label arrays (vertex-major, hub-sorted rows)
        and derive the length-bucketed device routing tables."""
        offsets = np.asarray(offsets, dtype=np.int64)
        V = len(offsets) - 1
        count = offsets[1:] - offsets[:-1]
        # geometric lane-multiple buckets: width = lane * 2^b
        need = np.maximum(count, 1)
        blog = np.ceil(np.log2(np.maximum(np.ceil(need / lane), 1))
                       ).astype(np.int64)
        widths_all = lane * (1 << blog)                      # [V]
        uniq = np.unique(widths_all)
        bucket_of = np.searchsorted(uniq, widths_all).astype(np.int32)
        slot_of = np.zeros(V, dtype=np.int32)
        bucket_vertices = []
        for b in range(len(uniq)):
            members = np.flatnonzero(bucket_of == b).astype(np.int32)
            slot_of[members] = np.arange(len(members), dtype=np.int32)
            bucket_vertices.append(members)
        return PackedLabels(hub_rank=np.ascontiguousarray(hub, dtype=np.int32),
                            dist=np.ascontiguousarray(dist, dtype=np.int32),
                            wlev=np.ascontiguousarray(wlev, dtype=np.int32),
                            offsets=offsets,
                            bucket_widths=uniq.astype(np.int32),
                            bucket_of=bucket_of, slot_of=slot_of,
                            bucket_vertices=bucket_vertices)

    @staticmethod
    def from_index(idx: "WCIndex", lane: int = LANE) -> "PackedLabels":
        V = idx.num_nodes
        count = idx.count.astype(np.int64)
        offsets = np.zeros(V + 1, dtype=np.int64)
        np.cumsum(count, out=offsets[1:])
        E = int(offsets[-1])
        # flatten the padded rows: entry j of vertex v -> offsets[v] + j
        rows = np.repeat(np.arange(V, dtype=np.int64), count)
        cols = _concat_ranges(count)
        hub = np.ascontiguousarray(idx.hub_rank[rows, cols])
        assert hub.shape == (E,)
        return PackedLabels.from_flat(hub, idx.dist[rows, cols],
                                      idx.wlev[rows, cols], offsets,
                                      lane=lane)

    # ------------------------------------------------------------------ props
    @property
    def num_nodes(self) -> int:
        return int(len(self.offsets) - 1)

    @property
    def num_buckets(self) -> int:
        return int(len(self.bucket_widths))

    def size_entries(self) -> int:
        return int(len(self.hub_rank))

    def row(self, v: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        s, e = int(self.offsets[v]), int(self.offsets[v + 1])
        return self.hub_rank[s:e], self.dist[s:e], self.wlev[s:e]

    def memory_bytes(self) -> int:
        """Flat CSR store: 3 int32 per entry + the offset array."""
        return int(self.hub_rank.nbytes + self.dist.nbytes + self.wlev.nbytes
                   + self.offsets.nbytes)

    def tile_memory_bytes(self) -> int:
        """Device-resident bucket tiles: sum_b n_b * W_b entries * 3 int32."""
        n_b = np.array([len(m) for m in self.bucket_vertices], dtype=np.int64)
        return int((n_b * self.bucket_widths.astype(np.int64)).sum() * 12)

    def arena(self, lane: int = LANE) -> "LabelArena":
        """The lane-tiled flat arena view of this store (cached per lane) —
        the single-buffer layout the ragged query megakernel reads; see
        `LabelArena`."""
        cache = self.__dict__.setdefault("_arena_cache", {})
        if lane not in cache:
            cache[lane] = LabelArena.from_packed(self, lane=lane)
        return cache[lane]

    def compressed_arena(self, lane: int = LANE,
                         dtype: str = "bfloat16") -> "CompressedArena":
        """Compressed view of `arena` (cached per (lane, dtype)); see
        `CompressedArena` and docs/index-format.md §6."""
        cache = self.__dict__.setdefault("_carena_cache", {})
        key = (lane, dtype)
        if key not in cache:
            cache[key] = CompressedArena.from_arena(self.arena(lane=lane),
                                                    dtype=dtype)
        return cache[key]

    # ------------------------------------------------------------ conversions
    def bucket_tiles(self, b: int):
        """Bucket b as padded [n_b, W_b] (hub, dist, wlev) tiles.

        Pad contract (see kernels/wcsd_query.py): hub = -1, dist = INF_DIST,
        wlev = -1 — a pad cell never passes the ``wlev >= w`` feasibility
        mask, so its distance is replaced by DEV_INF before the reduction.
        """
        members = self.bucket_vertices[b]
        W = int(self.bucket_widths[b])
        n = len(members)
        hub = np.full((n, W), -1, dtype=np.int32)
        dist = np.full((n, W), INF_DIST, dtype=np.int32)
        wlev = np.full((n, W), -1, dtype=np.int32)
        lens = (self.offsets[members + 1] - self.offsets[members])
        rows = np.repeat(np.arange(n, dtype=np.int64), lens)
        cols = _concat_ranges(lens)
        flat = np.repeat(self.offsets[members], lens) + cols
        hub[rows, cols] = self.hub_rank[flat]
        dist[rows, cols] = self.dist[flat]
        wlev[rows, cols] = self.wlev[flat]
        return hub, dist, wlev

    def to_padded(self, cap: int | None = None):
        """Round-trip back to padded `[V, cap]` arrays (numpy reference
        path): returns (hub_rank, dist, wlev, count) with the same fill
        values and the same trim rule as `WCIndex.padded_device_arrays` —
        a trimmed row keeps its first ``cap - 1`` entries plus the trailing
        self entry, and count is clamped to ``cap``."""
        V = self.num_nodes
        count = (self.offsets[1:] - self.offsets[:-1]).astype(np.int32)
        c = int(cap if cap is not None else max(int(count.max()), 1))
        hub = np.full((V, c), -1, dtype=np.int32)
        dist = np.full((V, c), INF_DIST, dtype=np.int32)
        wlev = np.full((V, c), -1, dtype=np.int32)
        lens = np.minimum(count.astype(np.int64), c)
        rows = np.repeat(np.arange(V, dtype=np.int64), lens)
        cols = _concat_ranges(lens)
        flat = np.repeat(self.offsets[:-1], lens) + cols
        hub[rows, cols] = self.hub_rank[flat]
        dist[rows, cols] = self.dist[flat]
        wlev[rows, cols] = self.wlev[flat]
        over = np.flatnonzero(count > c)
        if len(over):
            last = self.offsets[over + 1] - 1        # the self entry
            hub[over, c - 1] = self.hub_rank[last]
            dist[over, c - 1] = self.dist[last]
            wlev[over, c - 1] = self.wlev[last]
        return hub, dist, wlev, np.minimum(count, c).astype(np.int32)


@dataclasses.dataclass
class LabelArena:
    """Lane-tiled flat label arena: the single-store layout behind the
    ragged query megakernel (docs/query-engine.md).

    Every CSR row is re-packed starting at a lane-aligned offset, so ANY
    label row — whatever its length — is addressable as ``tile_cnt[v]``
    whole ``[lane]`` tiles beginning at tile ``tile_base[v]``. One arena
    replaces the per-bucket tile arrays: a batch of queries over arbitrary
    bucket mixes becomes a flat ``(query, s_tile, t_tile)`` worklist over
    these tiles and runs as ONE kernel launch (`kernels.wcsd_query.
    wcsd_query_ragged`) instead of one launch per bucket pair.

      hub/dist/wlev : [T, lane] int32 tiles, vertex rows back to back; the
                      in-row pad cells (beyond the row length, inside its
                      last tile) carry the §3 sentinel contract of
                      docs/index-format.md: hub -1, dist INF_DIST, wlev -1.
      tile_base     : [V] int32 — first tile of vertex v's row
      tile_cnt      : [V] int32 — ``ceil(len(v) / lane)`` (>= 1)
      tile_lo/hi    : [T] int32 — min/max real hub rank inside each tile.
                      Rows are hub-sorted (invariant I1), so a tile's hub
                      span is an interval; two tiles whose intervals are
                      disjoint cannot meet and the kernel skips their
                      O(lane^2) join (tile_lo = first cell; pads are -1 and
                      sit at the row tail, so tile_hi = max over cells).
    """

    hub: np.ndarray        # [T, lane] int32
    dist: np.ndarray       # [T, lane] int32
    wlev: np.ndarray       # [T, lane] int32
    tile_base: np.ndarray  # [V] int32
    tile_cnt: np.ndarray   # [V] int32
    tile_lo: np.ndarray    # [T] int32
    tile_hi: np.ndarray    # [T] int32

    @property
    def num_tiles(self) -> int:
        return int(self.hub.shape[0])

    @property
    def lane(self) -> int:
        return int(self.hub.shape[1])

    def memory_bytes(self) -> int:
        """Device-resident footprint: 3 int32 per arena cell + the per-row
        and per-tile index tables."""
        return int(self.hub.nbytes + self.dist.nbytes + self.wlev.nbytes
                   + self.tile_base.nbytes + self.tile_cnt.nbytes
                   + self.tile_lo.nbytes + self.tile_hi.nbytes)

    @staticmethod
    def from_packed(packed: "PackedLabels", lane: int = LANE) -> "LabelArena":
        offsets = packed.offsets
        V = packed.num_nodes
        count = offsets[1:] - offsets[:-1]                     # [V] int64
        tile_cnt = np.maximum(-(-count // lane), 1).astype(np.int64)
        tile_base = np.zeros(V, dtype=np.int64)
        np.cumsum(tile_cnt[:-1], out=tile_base[1:])
        T = int(tile_cnt.sum())
        hub = np.full((T, lane), -1, dtype=np.int32)
        dist = np.full((T, lane), INF_DIST, dtype=np.int32)
        wlev = np.full((T, lane), -1, dtype=np.int32)
        pos = np.repeat(tile_base * lane, count) + _concat_ranges(count)
        hub.reshape(-1)[pos] = packed.hub_rank
        dist.reshape(-1)[pos] = packed.dist
        wlev.reshape(-1)[pos] = packed.wlev
        # hub-sorted rows + tail pads of -1: lo is the first cell, hi the max
        tile_lo = hub[:, 0].copy()
        tile_hi = hub.max(axis=1).astype(np.int32)
        return LabelArena(hub=hub, dist=dist, wlev=wlev,
                          tile_base=tile_base.astype(np.int32),
                          tile_cnt=tile_cnt.astype(np.int32),
                          tile_lo=tile_lo, tile_hi=tile_hi)

    # ---------------------------------------------------------- integrity
    def checksums(self) -> dict:
        """CRC32 of every arena blob (docs/resilience.md §integrity)."""
        return {name: zlib.crc32(np.ascontiguousarray(
                    getattr(self, name)).tobytes())
                for name in ("hub", "dist", "wlev", "tile_base",
                             "tile_cnt", "tile_lo", "tile_hi")}

    def verify_integrity(self, expected: dict | None = None) -> dict:
        """Re-hash the live tiles against a recorded baseline and raise
        `IndexIntegrityError` on any mismatch. The first call with no
        ``expected`` stamps the current checksums as the baseline (the
        arena is immutable in serving; any later drift is corruption).
        Returns the checksums that passed."""
        sums = self.checksums()
        baseline = expected or getattr(self, "_expected_crc", None)
        if baseline is None:
            object.__setattr__(self, "_expected_crc", sums)
            return sums
        _verify_blob_crcs("LabelArena", sums, baseline)
        return sums


# the arena's device infinity (kernels/wcsd_query.py DEV_INF): any stored
# distance at or above this is "no path" and decodes back to INF_DIST
_DEV_INF = 1 << 29
_I16_MAX = np.int32(np.iinfo(np.int16).max)   # 32767: hub-delta ceiling
_I8_MAX = np.int32(np.iinfo(np.int8).max)     # 127:   wlev ceiling
_F16_MAX_DIST = 65000                         # fp16 finite headroom


@dataclasses.dataclass
class CompressedArena:
    """Compressed lane-tiled arena: same tile geometry as `LabelArena`,
    ~2.4x fewer bytes per cell, decoded inside the ragged kernels.

    Per-cell encoding (docs/index-format.md §6):

      hub_delta : [T, lane] int16 — ``hub - tile_lo[t]`` for real cells
                  (rows are hub-sorted, so deltas are non-negative and
                  bounded by the tile's hub span); pad cells keep the -1
                  sentinel directly (``tile_lo + delta`` never reaches -1
                  for a real cell, so the sign IS the pad flag).
      dist      : [T, lane] bfloat16 (default) or float16 — real distances
                  rounded to the float format; INF_DIST pads and any
                  "no path" value >= DEV_INF encode as +inf, which the
                  decoder clamps back to the integer infinity.
      wlev      : [T, lane] int8 — quality levels (< 128 in practice);
                  pad sentinel -1 survives as-is.

    Tiles the narrow encoding cannot hold losslessly-enough — a hub span
    wider than int16, a quality level past int8, or (fp16 only) a finite
    distance past the format's range — are FLAGGED in ``overflow`` and
    kept verbatim in the int32 side tables (``side_*``, one row per
    overflowed tile, indexed by ``side_slot``). `decode` restores them
    exactly; the query engines refuse to serve a flagged store compressed
    and fall back to the uncompressed arena instead (never silent
    corruption — see tests/test_compressed_arena.py).

    Distance precision (the documented bound, asserted in the tests):
    bfloat16 has an 8-bit significand, so distances <= 256 round-trip
    exactly and larger ones carry relative error <= 2^-8; float16 is
    exact up to 2048 with relative error <= 2^-11 beyond.
    """

    hub_delta: np.ndarray  # [T, lane] int16
    dist: np.ndarray       # [T, lane] bfloat16 | float16
    wlev: np.ndarray       # [T, lane] int8
    tile_base: np.ndarray  # [V] int32
    tile_cnt: np.ndarray   # [V] int32
    tile_lo: np.ndarray    # [T] int32
    tile_hi: np.ndarray    # [T] int32
    overflow: np.ndarray   # [T] bool — tile lives in the side tables
    side_slot: np.ndarray  # [T] int32 — row in side_* (0 where not flagged)
    side_hub: np.ndarray   # [n_overflow, lane] int32
    side_dist: np.ndarray  # [n_overflow, lane] int32
    side_wlev: np.ndarray  # [n_overflow, lane] int32

    @property
    def num_tiles(self) -> int:
        return int(self.hub_delta.shape[0])

    @property
    def lane(self) -> int:
        return int(self.hub_delta.shape[1])

    @property
    def num_overflow_tiles(self) -> int:
        return int(self.side_hub.shape[0])

    def memory_bytes(self) -> int:
        """Device-resident footprint: compressed cells + index tables +
        whatever side tables the overflowed tiles forced."""
        return int(self.hub_delta.nbytes + self.dist.nbytes
                   + self.wlev.nbytes + self.tile_base.nbytes
                   + self.tile_cnt.nbytes + self.tile_lo.nbytes
                   + self.tile_hi.nbytes + self.overflow.nbytes
                   + self.side_slot.nbytes + self.side_hub.nbytes
                   + self.side_dist.nbytes + self.side_wlev.nbytes)

    @staticmethod
    def from_arena(ar: "LabelArena",
                   dtype: str = "bfloat16") -> "CompressedArena":
        if dtype not in ("bfloat16", "float16"):
            raise ValueError(f"unsupported compressed dist dtype: {dtype!r}")
        if dtype == "bfloat16":
            import ml_dtypes
            fdt = np.dtype(ml_dtypes.bfloat16)
        else:
            fdt = np.dtype(np.float16)
        hub, dist, wlev = ar.hub, ar.dist, ar.wlev
        pad = hub < 0
        real = ~pad
        delta = hub.astype(np.int64) - ar.tile_lo[:, None].astype(np.int64)
        no_path = dist >= _DEV_INF
        ovf = ((real & (delta > int(_I16_MAX))).any(axis=1)
               | (real & (wlev > int(_I8_MAX))).any(axis=1))
        if fdt == np.float16:
            ovf |= (real & ~no_path & (dist > _F16_MAX_DIST)).any(axis=1)
        hub_delta = np.where(pad, -1,
                             np.clip(delta, 0, int(_I16_MAX))
                             ).astype(np.int16)
        with np.errstate(over="ignore"):   # fp16: overflowed tiles are
            dist_c = np.where(no_path, np.inf,  # flagged + side-tabled
                              dist.astype(np.float64)).astype(fdt)
        wlev_c = np.clip(wlev, -1, int(_I8_MAX)).astype(np.int8)
        slots = np.flatnonzero(ovf)
        side_slot = np.zeros(hub.shape[0], dtype=np.int32)
        side_slot[slots] = np.arange(len(slots), dtype=np.int32)
        return CompressedArena(
            hub_delta=hub_delta, dist=dist_c, wlev=wlev_c,
            tile_base=ar.tile_base, tile_cnt=ar.tile_cnt,
            tile_lo=ar.tile_lo, tile_hi=ar.tile_hi,
            overflow=ovf, side_slot=side_slot,
            side_hub=hub[slots].copy(), side_dist=dist[slots].copy(),
            side_wlev=wlev[slots].copy())

    def decode(self) -> "LabelArena":
        """Exact inverse of the tile geometry (hub ids and wlev are always
        bit-exact; distances round-trip within the documented float bound,
        and overflowed tiles verbatim from the side tables)."""
        d16 = self.hub_delta.astype(np.int32)
        hub = np.where(d16 >= 0, self.tile_lo[:, None] + d16,
                       -1).astype(np.int32)
        df = self.dist.astype(np.float64)
        inf = ~np.isfinite(df) | (df >= float(_DEV_INF))
        dist = np.where(inf, INF_DIST,
                        np.rint(np.where(inf, 0.0, df))).astype(np.int32)
        wlev = self.wlev.astype(np.int32)
        if self.overflow.any():
            rows = np.flatnonzero(self.overflow)
            slot = self.side_slot[rows]
            hub[rows] = self.side_hub[slot]
            dist[rows] = self.side_dist[slot]
            wlev[rows] = self.side_wlev[slot]
        return LabelArena(hub=hub, dist=dist, wlev=wlev,
                          tile_base=self.tile_base, tile_cnt=self.tile_cnt,
                          tile_lo=self.tile_lo, tile_hi=self.tile_hi)


class PackedLabelsBuilder:
    """Incremental-append producer of a `PackedLabels` store.

    The rank-batched device builder emits labels one root-batch at a time;
    each batch covers an ascending slice of hub ranks, so per vertex the
    batches arrive already hub-sorted relative to each other. The builder
    keeps the raw per-batch chunks (flat arrays, no [V, cap] padding) and
    `finalize` performs the fused Pareto post-pass + one stable vertex-major
    counting sort + self-entry append, emitting the CSR arrays directly.

    append_batch contract: within a batch, entries sorted by (vertex, hub
    ascending, dist ascending), and every hub rank strictly exceeds all hub
    ranks previously appended for that vertex (rank-batch arrival order).
    """

    def __init__(self, num_nodes: int, lane: int = LANE):
        self.num_nodes = int(num_nodes)
        self.lane = int(lane)
        self._chunks: list[tuple[np.ndarray, np.ndarray, np.ndarray,
                                 np.ndarray]] = []
        self._total = 0

    def append_batch(self, v: np.ndarray, hub: np.ndarray, dist: np.ndarray,
                     wlev: np.ndarray) -> None:
        if len(v) == 0:
            return
        self._chunks.append((np.asarray(v, dtype=np.int32).copy(),
                             np.asarray(hub, dtype=np.int32).copy(),
                             np.asarray(dist, dtype=np.int32).copy(),
                             np.asarray(wlev, dtype=np.int32).copy()))
        self._total += len(v)

    def size_entries(self) -> int:
        return self._total

    def finalize(self, rank: np.ndarray, num_levels: int,
                 minimalize: bool = True) -> tuple["PackedLabels", int]:
        """Emit the CSR store: Pareto-filter per (vertex, hub), scatter into
        vertex-major flat arrays, append one self entry per vertex. Returns
        (store, dominated_entries_removed)."""
        from .dominance import pareto_csr_emit

        V, W = self.num_nodes, int(num_levels)
        if self._chunks:
            v_all = np.concatenate([c[0] for c in self._chunks])
            h_all = np.concatenate([c[1] for c in self._chunks])
            d_all = np.concatenate([c[2] for c in self._chunks])
            w_all = np.concatenate([c[3] for c in self._chunks])
        else:
            v_all = h_all = d_all = w_all = np.zeros(0, dtype=np.int32)
        removed = 0
        if minimalize:
            order, keep = pareto_csr_emit(v_all, h_all, d_all, w_all, V)
            order = order[keep]
            removed = int(len(keep) - keep.sum())
        else:
            order = np.lexsort((d_all, h_all, v_all))
        v_all, h_all = v_all[order], h_all[order]
        d_all, w_all = d_all[order], w_all[order]
        count = np.bincount(v_all, minlength=V).astype(np.int64) + 1
        offsets = np.zeros(V + 1, dtype=np.int64)
        np.cumsum(count, out=offsets[1:])
        E = int(offsets[-1])
        hub = np.empty(E, dtype=np.int32)
        dist = np.empty(E, dtype=np.int32)
        wlev = np.empty(E, dtype=np.int32)
        pos = np.repeat(offsets[:-1], count - 1) + _concat_ranges(count - 1)
        hub[pos], dist[pos], wlev[pos] = h_all, d_all, w_all
        # self entries close each row; rank[v] exceeds every stored hub rank
        self_pos = offsets[1:] - 1
        hub[self_pos] = np.asarray(rank, dtype=np.int32)
        dist[self_pos] = 0
        wlev[self_pos] = W
        store = PackedLabels.from_flat(hub, dist, wlev, offsets,
                                       lane=self.lane)
        return store, removed


@dataclasses.dataclass
class PackedWCIndex:
    """A WC-Index whose labels live only in the CSR-packed store — the
    output of the device-resident batched builder (`core/wc_index_batched.
    build_wc_index_batched_packed`). Serving consumes `labels` directly
    (`DeviceQueryEngine` duck-types `packed()` / `padded_device_arrays`),
    so a freshly built index reaches the query path with no repack step."""

    order: np.ndarray        # [V] rank -> vertex
    rank: np.ndarray         # [V] vertex -> rank
    levels: np.ndarray       # [W] quality values
    labels: "PackedLabels"

    @property
    def num_levels(self) -> int:
        return int(len(self.levels))

    @property
    def num_nodes(self) -> int:
        return int(len(self.order))

    def size_entries(self) -> int:
        return self.labels.size_entries()

    def memory_bytes(self) -> int:
        return self.labels.memory_bytes()

    def level_of(self, w: float) -> int:
        return int(np.searchsorted(self.levels, w, side="left"))

    # ------------------------------------------------------------- queries
    def query_one(self, s: int, t: int, w_level: int) -> int:
        """Host sort-merge (Alg. 5) straight over the CSR rows."""
        return merge_query_rows(*self.labels.row(s), *self.labels.row(t),
                                w_level)

    def query_batch(self, s, t, w_level) -> np.ndarray:
        """Numpy oracle via the padded mirror (tests/small workloads)."""
        return self.to_index().query_batch(s, t, w_level)

    # --------------------------------------------------- engine interface
    def packed(self, lane: int = LANE) -> "PackedLabels":
        """The store itself — already packed, no re-pack. A non-default
        ``lane`` re-buckets the flat arrays (the flat CSR part is reused
        as-is; only the routing tables are rebuilt)."""
        if lane != LANE:
            return PackedLabels.from_flat(self.labels.hub_rank,
                                          self.labels.dist, self.labels.wlev,
                                          self.labels.offsets, lane=lane)
        return self.labels

    def padded_device_arrays(self, cap: int | None = None):
        return self.labels.to_padded(cap)

    def to_index(self) -> "WCIndex":
        """Padded-array round trip (reference paths and tests)."""
        hub, dist, wlev, count = self.labels.to_padded()
        return WCIndex(order=self.order, rank=self.rank, levels=self.levels,
                       hub_rank=hub, dist=dist, wlev=wlev, count=count)

    # ------------------------------------------------------------ integrity
    def checksums(self) -> dict:
        """CRC32 per blob, byte-identical to the table `save_packed_index`
        writes (same names, same dtype normalization), so checksums taken
        from a loaded file, a live index, and a saved one all compare."""
        from ..checkpoint.ckpt import _wcx_arrays
        return {name: zlib.crc32(a.tobytes())
                for name, a in _wcx_arrays(self).items()}

    def verify_integrity(self, expected: dict | None = None) -> dict:
        """Re-hash every blob against a baseline — ``expected``, else the
        `_expected_crc` stamped by `load_packed_index` (format v2), else
        the current state (stamped as the new baseline). Mismatch raises
        `IndexIntegrityError`; returns the passing checksums."""
        sums = self.checksums()
        baseline = expected or getattr(self, "_expected_crc", None)
        if baseline is None:
            self._expected_crc = sums
            return sums
        _verify_blob_crcs("PackedWCIndex", sums, baseline)
        return sums


def as_packed_index(idx: "WCIndex | PackedWCIndex") -> "PackedWCIndex":
    """Canonicalize either index flavor to the CSR-packed form (the base
    format the dynamic layer maintains)."""
    if isinstance(idx, PackedWCIndex):
        return idx
    return PackedWCIndex(order=idx.order, rank=idx.rank, levels=idx.levels,
                         labels=idx.packed())


def _row_key(hub: np.ndarray, dist: np.ndarray, wlev: np.ndarray) -> set:
    """Hashable entry set of one label row (diff/tombstone accounting)."""
    return set(zip(hub.tolist(), dist.tolist(), wlev.tolist()))


@dataclasses.dataclass
class DeltaLabelStore:
    """Correction layer over an immutable base `PackedLabels` store
    (docs/dynamic-index.md; the delta-file + explicit-staleness design of
    the JN Index template in SNIPPETS.md).

    ``rows`` maps a touched vertex to its full corrected label row
    (hub-sorted, self-entry-terminated — the same row invariants I1-I3 as
    the base store). A corrected row REPLACES the vertex's base row at
    serve time, which realizes ``min(main_arena, delta_arena)``: surviving
    base entries are carried into the corrected row, invalidated base
    entries are simply absent from it. The base store is never written —
    its entries for touched vertices become *tombstoned* behind
    ``graph_version``: still physically present in the main arena, no
    longer referenced by any tile pointer, reclaimed at the next
    compaction.

    ``tombstoned`` / ``corrections`` count base entries invalidated and
    delta entries added since the last compaction; their ratio against the
    base size is the compaction trigger (`DynamicWCIndex.delta_ratio`).
    """

    graph_version: int = 0
    rows: dict = dataclasses.field(default_factory=dict)
    tombstoned: int = 0
    corrections: int = 0

    def is_empty(self) -> bool:
        return not self.rows

    def delta_entries(self) -> int:
        """Total entries resident in the delta arena (full corrected rows,
        self entries included)."""
        return int(sum(len(h) for h, _, _ in self.rows.values()))

    def record(self, base: "PackedLabels", new_rows: dict) -> None:
        """Fold freshly recomputed rows in: rows identical to the BASE row
        drop out of the delta (nothing to correct any more); the counters
        track the symmetric difference against the base store."""
        for v, (h, d, w) in new_rows.items():
            bh, bd, bw = base.row(v)
            if (len(bh) == len(h) and np.array_equal(bh, h)
                    and np.array_equal(bd, d) and np.array_equal(bw, w)):
                self.rows.pop(v, None)
                continue
            self.rows[v] = (np.ascontiguousarray(h, dtype=np.int32),
                            np.ascontiguousarray(d, dtype=np.int32),
                            np.ascontiguousarray(w, dtype=np.int32))
        self.tombstoned = 0
        self.corrections = 0
        for v, (h, d, w) in self.rows.items():
            bset = _row_key(*base.row(v))
            nset = _row_key(h, d, w)
            self.tombstoned += len(bset - nset)
            self.corrections += len(nset - bset)

    def reset(self) -> None:
        """Drop every correction (post-compaction: the new base absorbs
        them). ``graph_version`` survives — it counts graph mutations, not
        delta generations."""
        self.rows.clear()
        self.tombstoned = 0
        self.corrections = 0

    # -------------------------------------------------------- serving views
    def extend_arena(self, base_arena: "LabelArena",
                     lane: int | None = None) -> "LabelArena":
        """The dual-arena serving layout: the base arena's tiles verbatim
        (byte-identical — tombstoned tiles just lose their pointers), with
        one lane-tiled DELTA REGION appended past them holding every
        corrected row; touched vertices' ``tile_base`` redirect into it.
        The ragged worklist thus covers both arenas in ONE flat tile
        space — a flush over main + delta stays a single `pallas_call`
        (delta tiles are ordinary worklist items; locked by
        tests/test_ragged.py)."""
        lane = base_arena.lane if lane is None else int(lane)
        assert lane == base_arena.lane
        if not self.rows:
            return base_arena
        touched = sorted(self.rows)
        cnts = np.array([max(-(-len(self.rows[v][0]) // lane), 1)
                         for v in touched], dtype=np.int64)
        Td = int(cnts.sum())
        dh = np.full((Td, lane), -1, dtype=np.int32)
        dd = np.full((Td, lane), INF_DIST, dtype=np.int32)
        dw = np.full((Td, lane), -1, dtype=np.int32)
        tile_base = base_arena.tile_base.copy()
        tile_cnt = base_arena.tile_cnt.copy()
        T0 = base_arena.num_tiles
        at = 0
        for v, c in zip(touched, cnts):
            h, d, w = self.rows[v]
            n = len(h)
            flat = dh[at:at + c].reshape(-1)
            flat[:n] = h
            dd[at:at + c].reshape(-1)[:n] = d
            dw[at:at + c].reshape(-1)[:n] = w
            tile_base[v] = T0 + at
            tile_cnt[v] = int(c)
            at += int(c)
        tile_lo = dh[:, 0].copy()
        tile_hi = dh.max(axis=1).astype(np.int32)
        return LabelArena(
            hub=np.concatenate([base_arena.hub, dh]),
            dist=np.concatenate([base_arena.dist, dd]),
            wlev=np.concatenate([base_arena.wlev, dw]),
            tile_base=tile_base, tile_cnt=tile_cnt,
            tile_lo=np.concatenate([base_arena.tile_lo, tile_lo]),
            tile_hi=np.concatenate([base_arena.tile_hi, tile_hi]))

    def merged_flat(self, base: "PackedLabels"):
        """Merged flat CSR arrays (hub, dist, wlev, offsets): base rows for
        untouched vertices, corrected rows for touched ones — the store the
        bucket-pair / padded serving paths and the host oracles read."""
        V = base.num_nodes
        count = (base.offsets[1:] - base.offsets[:-1]).astype(np.int64)
        for v, (h, _, _) in self.rows.items():
            count[v] = len(h)
        offsets = np.zeros(V + 1, dtype=np.int64)
        np.cumsum(count, out=offsets[1:])
        E = int(offsets[-1])
        hub = np.empty(E, dtype=np.int32)
        dist = np.empty(E, dtype=np.int32)
        wlev = np.empty(E, dtype=np.int32)
        untouched = np.ones(V, dtype=bool)
        if self.rows:
            untouched[np.fromiter(self.rows, dtype=np.int64,
                                  count=len(self.rows))] = False
        uv = np.flatnonzero(untouched)
        lens = count[uv]
        pos = np.repeat(offsets[uv], lens) + _concat_ranges(lens)
        src = np.repeat(base.offsets[uv], lens) + _concat_ranges(lens)
        hub[pos] = base.hub_rank[src]
        dist[pos] = base.dist[src]
        wlev[pos] = base.wlev[src]
        for v, (h, d, w) in self.rows.items():
            o = int(offsets[v])
            hub[o:o + len(h)] = h
            dist[o:o + len(h)] = d
            wlev[o:o + len(h)] = w
        return hub, dist, wlev, offsets


class DynamicWCIndex:
    """A WC-Index that follows a mutating graph: an immutable base
    `PackedWCIndex` plus a `DeltaLabelStore` of corrected rows, re-derived
    per update by re-running the pruned rank-ordered BFS rounds for the
    affected roots only (`wc_index_batched.rebuild_affected_rows`).

    Duck-types the engine interface (``packed()`` /
    ``padded_device_arrays()`` / ``num_levels``), so `DeviceQueryEngine`,
    `ShardedQueryEngine` and `WCSDServer` serve it like any static index —
    under the ragged dispatch the arena it hands out is the base tile
    arena with the delta region appended (`DeltaLabelStore.extend_arena`),
    so every flush stays one kernel launch.

    `compact()` re-runs the fused Pareto build
    (`build_wc_index_batched_packed`) on the current graph and re-packs a
    fresh base arena — byte-identical to building from scratch on the
    mutated graph (locked by tests/test_dynamic.py).
    """

    def __init__(self, base: "WCIndex | PackedWCIndex", graph):
        self.base = as_packed_index(base)
        self.graph = graph
        self.delta = DeltaLabelStore(graph_version=int(
            getattr(graph, "version", 0)))
        self._packed_cache: dict = {}

    # ------------------------------------------------------------- proxies
    @property
    def order(self):
        return self.base.order

    @property
    def rank(self):
        return self.base.rank

    @property
    def levels(self):
        return self.base.levels

    @property
    def num_levels(self) -> int:
        return self.base.num_levels

    @property
    def num_nodes(self) -> int:
        return self.base.num_nodes

    @property
    def graph_version(self) -> int:
        return self.delta.graph_version

    def level_of(self, w: float) -> int:
        return self.base.level_of(w)

    def size_entries(self) -> int:
        return self.packed().size_entries()

    def delta_ratio(self) -> float:
        """Compaction trigger: delta-resident entries (corrected rows)
        relative to the base store size."""
        return self.delta.delta_entries() / max(self.base.size_entries(), 1)

    # ------------------------------------------------------------- updates
    def apply_updates(self, inserts=(), deletes=()) -> dict:
        """Mutate the graph and fold the label corrections into the delta
        store. Exact: serving equals a from-scratch rebuild on the mutated
        graph, for every level (differential-locked). Returns stats."""
        from .graph import mutate_edges
        from .wc_index_batched import affected_vertices, rebuild_affected_rows

        g_old = self.graph
        g_new = mutate_edges(g_old, inserts=inserts, deletes=deletes)
        endpoints = sorted({int(x) for e in inserts for x in e[:2]}
                          | {int(x) for e in deletes for x in e[:2]})
        affected = affected_vertices(g_old, g_new, endpoints)
        new_rows = rebuild_affected_rows(
            g_new, self.base.order, self.base.rank,
            num_levels=self.num_levels,
            merged_flat=self.delta.merged_flat(self.base.labels),
            affected=affected)
        self.delta.record(self.base.labels, new_rows)
        self.delta.graph_version += 1
        self.graph = g_new
        self._packed_cache.clear()
        return {"affected_roots": int(len(affected)),
                "touched_rows": int(len(new_rows)),
                "delta_rows": int(len(self.delta.rows)),
                "delta_entries": self.delta.delta_entries(),
                "tombstoned": int(self.delta.tombstoned),
                "corrections": int(self.delta.corrections),
                "graph_version": self.graph_version}

    def compact(self, **build_kwargs) -> dict:
        """Re-run the fused Pareto build + CSR re-pack on the current
        graph; the delta folds into a fresh immutable base. Byte-identical
        to `build_wc_index_batched_packed` on the mutated graph."""
        from .wc_index_batched import build_wc_index_batched_packed
        idx, stats = build_wc_index_batched_packed(self.graph, **build_kwargs)
        self.base = idx
        self.delta.reset()
        self._packed_cache.clear()
        return stats

    # ----------------------------------------------------- engine interface
    def packed(self, lane: int = LANE) -> "PackedLabels":
        """The merged serving store. With an empty delta this is the base
        store itself; otherwise a merged `PackedLabels` whose ragged arena
        view is the base arena + appended delta region (NOT a repack of
        the base tiles — see `DeltaLabelStore.extend_arena`)."""
        if self.delta.is_empty():
            return self.base.packed(lane=lane)
        if lane not in self._packed_cache:
            merged = PackedLabels.from_flat(
                *self.delta.merged_flat(self.base.labels), lane=lane)
            base_packed = self.base.packed(lane=lane)
            merged.__dict__["_arena_cache"] = {
                lane: self.delta.extend_arena(base_packed.arena(lane=lane),
                                              lane=lane)}
            self._packed_cache[lane] = merged
        return self._packed_cache[lane]

    def padded_device_arrays(self, cap: int | None = None):
        return self.packed().to_padded(cap)

    def to_index(self) -> "WCIndex":
        hub, dist, wlev, count = self.packed().to_padded()
        return WCIndex(order=self.order, rank=self.rank, levels=self.levels,
                       hub_rank=hub, dist=dist, wlev=wlev, count=count)

    # ------------------------------------------------------------- queries
    def query_one(self, s: int, t: int, w_level: int) -> int:
        store = self.packed()
        return merge_query_rows(*store.row(s), *store.row(t), w_level)

    def query_batch(self, s, t, w_level) -> np.ndarray:
        return self.to_index().query_batch(s, t, w_level)


def _ensure_capacity(idx_arrays, count, need):
    """Grow padded label arrays so every vertex in `need` fits one more."""
    hub, dist, wlev = idx_arrays
    cap = hub.shape[1]
    max_need = int((count[need] + 1).max()) if len(need) else 0
    if max_need <= cap:
        return idx_arrays
    new_cap = max(max_need, cap * 2, 4)
    V = hub.shape[0]
    def grow(a, fill):
        out = np.full((V, new_cap), fill, dtype=a.dtype)
        out[:, :cap] = a
        return out
    return grow(hub, -1), grow(dist, INF_DIST), grow(wlev, -1)


def append_self_entries(hub, dist, wlev, count, rank, W):
    """Append (rank[v], 0, inf) to every vertex, preserving hub-sorted order
    (rank[v] exceeds every stored hub rank of v by construction)."""
    V = len(count)
    allv = np.arange(V, dtype=np.int32)
    hub, dist, wlev = _ensure_capacity((hub, dist, wlev), count, allv)
    pos = count[allv]
    hub[allv, pos] = rank[allv]
    dist[allv, pos] = 0
    wlev[allv, pos] = W
    count = count + 1
    return hub, dist, wlev, count


def build_wc_index(g: Graph, order: np.ndarray | None = None,
                   ordering: str = "degree", prune: bool = True,
                   max_roots: int | None = None) -> WCIndex:
    """Faithful sequential construction (paper Algorithm 3 + §IV-C).

    prune=False disables index-based pruning (isolates what the paper's
    pruning buys; R-pruning still bounds the BFS so it terminates).
    max_roots limits the hub set (partial index; tests/benches only) —
    queries are then only sound for pairs covered by processed hubs.
    """
    V, W = g.num_nodes, g.num_levels
    if order is None:
        order = make_order(g, ordering)
    order = np.asarray(order, dtype=np.int32)
    rank = np.empty(V, dtype=np.int32)
    rank[order] = np.arange(V, dtype=np.int32)

    cap0 = 8
    hub = np.full((V, cap0), -1, dtype=np.int32)
    dist = np.full((V, cap0), INF_DIST, dtype=np.int32)
    wlev = np.full((V, cap0), -1, dtype=np.int32)
    count = np.zeros(V, dtype=np.int32)

    # Per-root hub table T[hub_rank, level] = min dist from root to that hub
    # over paths with quality level >= column. Width W+1: column W == the
    # infinite quality of self entries. Reset lazily via `touched` lists
    # (paper's Efficient Initialization — no O(V) clears per root).
    T = np.full((V, W + 1), INF_DIST, dtype=np.int32)
    touched_T: list[np.ndarray] = []
    R = np.full(V, -1, dtype=np.int32)  # best bottleneck level this root
    touched_R: list[np.ndarray] = []

    n_roots = V if max_roots is None else min(V, max_roots)
    for k in range(n_roots):
        root = int(order[k])
        # ---- build T from L(root) (+ virtual self) -------------------------
        c = int(count[root])
        if c:
            hr, dr, wr = hub[root, :c], dist[root, :c], wlev[root, :c]
            # entry (hr, d, wl) answers every query level <= wl
            reps = (wr + 1).astype(np.int64)
            rows = np.repeat(hr.astype(np.int64), reps)
            cols = _concat_ranges(reps)
            np.minimum.at(T.reshape(-1), rows * (W + 1) + cols,
                          np.repeat(dr, reps))
            touched_T.append(hr.copy())
        T[k, :] = 0  # root reaches itself at distance 0, any quality
        touched_T.append(np.array([k], dtype=np.int32))

        R[root] = W
        touched_R.append(np.array([root], dtype=np.int32))

        frontier_v = np.array([root], dtype=np.int32)
        frontier_w = np.array([W], dtype=np.int32)
        d = 0
        while len(frontier_v):
            if d > 0:
                # ---- prune via query on the partial index (Alg. 3 l.11) ----
                if prune:
                    capn = hub.shape[1]
                    col = np.arange(capn)
                    m = (col[None, :] < count[frontier_v, None]) & \
                        (wlev[frontier_v] >= frontier_w[:, None])
                    hubs = hub[frontier_v]
                    tv = T[np.clip(hubs, 0, V - 1), frontier_w[:, None]]
                    cand = np.where(
                        m, dist[frontier_v].astype(np.int64) + tv, INF_DIST)
                    survive = cand.min(axis=1) > d
                    frontier_v = frontier_v[survive]
                    frontier_w = frontier_w[survive]
                    if len(frontier_v) == 0:
                        break
                # ---- emit labels (Alg. 3 l.12; d=0 self handled later) -----
                hub, dist, wlev = _ensure_capacity((hub, dist, wlev), count,
                                                   frontier_v)
                pos = count[frontier_v]
                hub[frontier_v, pos] = k
                dist[frontier_v, pos] = d
                wlev[frontier_v, pos] = frontier_w
                count[frontier_v] += 1
            # ---- expand (Alg. 3 l.13-17) -----------------------------------
            src_pos, nbrs, lvls = expand_frontier_csr(g, frontier_v)
            w_new = np.minimum(frontier_w[src_pos], lvls)
            valid = (rank[nbrs] > k) & (w_new > R[nbrs])
            nbrs, w_new = nbrs[valid], w_new[valid]
            if len(nbrs):
                np.maximum.at(R, nbrs, w_new)
                cands = np.unique(nbrs)
                touched_R.append(cands)
                frontier_v = cands
                frontier_w = R[cands].copy()
            else:
                frontier_v = np.zeros(0, dtype=np.int32)
                frontier_w = np.zeros(0, dtype=np.int32)
            d += 1
        # ---- lazy reset of T and R ------------------------------------------
        for arr in touched_T:
            T[arr] = INF_DIST
        touched_T.clear()
        for arr in touched_R:
            R[arr] = -1
        touched_R.clear()

    hub, dist, wlev, count = append_self_entries(hub, dist, wlev, count,
                                                 rank, W)
    return WCIndex(order=order, rank=rank, levels=g.levels.copy(),
                   hub_rank=hub, dist=dist, wlev=wlev, count=count)

"""Serving-side resilience: typed failure taxonomy, the flush
retry/backoff policy, and the degraded-mode fallback ladder
(docs/resilience.md).

The paper's index only pays off under heavy continuous traffic if a
wedged collective, a corrupted arena tile, or a mid-update crash cannot
take the server down or silently serve a wrong distance. This module
holds the pieces that are pure policy — no jax, no engine imports — so
`core/serve.py` (the enforcement point), `checkpoint/ckpt.py` (the WAL
and blob checksums) and `checkpoint/fault.py` (the chaos harness) can
all share one failure vocabulary without an import cycle:

  * `UnknownRequestError` — `result(rid)` on a rid the server has never
    seen or has already delivered (read-once contract).
  * `IndexIntegrityError` — a CRC32 blob self-check failed: bit rot, a
    torn copy, an injected arena bit-flip. Detection, never a wrong
    distance.
  * `FlushRetryExhausted` — the watchdog ran out of retries at the
    BOTTOM of the fallback ladder; the batch was re-queued, nothing was
    dropped.
  * `WALError` / `WALReplayError` — the update write-ahead log cannot be
    read, or its tail does not connect to the warm-start checkpoint.
  * `RetryPolicy` — deadline / budget / exponential-backoff-with-jitter
    knobs for the flush watchdog.
  * `build_fallback_ladder` — the declared degradation sequence from a
    server's engine config down to the pure-jnp oracle.
"""
from __future__ import annotations

import dataclasses


class UnknownRequestError(KeyError):
    """`result()`/`profile_result()` on an unknown or already-consumed
    rid. Read-once delivery means a delivered rid is gone; asking again
    is a caller bug, surfaced as a typed error instead of a silent None
    (or a bare KeyError from the result dict)."""

    def __init__(self, rid):
        super().__init__(rid)
        self.rid = rid

    def __str__(self) -> str:
        return (f"request id {self.rid!r} is unknown or already "
                "delivered (results are read-once)")


class IndexIntegrityError(RuntimeError):
    """A CRC32 self-check of index/arena blobs failed — the bytes do not
    match the checksums recorded at save/load/baseline time. The store
    must not serve: corruption surfaces as this typed error, never as a
    wrong distance."""


class FlushRetryExhausted(RuntimeError):
    """The flush watchdog exhausted its retry budget on the LAST rung of
    the fallback ladder. The batch has been re-queued (requests are
    never dropped); the caller decides whether to keep retrying."""


class WALError(RuntimeError):
    """The update write-ahead log is unreadable (bad magic, torn
    header, record sequence gap before the tail)."""


class WALReplayError(WALError):
    """The WAL tail does not connect to the warm-start state: the log
    was compacted past the checkpoint's graph version, or a record's
    version does not extend the replayed sequence."""


@dataclasses.dataclass
class RetryPolicy:
    """Flush watchdog knobs (docs/resilience.md §watchdog).

    ``flush_timeout_ms=None`` disables the deadline: a flush may block
    forever on `wait()` (the pre-watchdog behavior). With a deadline
    set, an in-flight handle that is not `ready()` within the timeout
    is cancelled (abandoned — device work is not interruptible, its
    result is simply never read) and the SAME batch is re-dispatched.
    Each retry backs off exponentially with jitter; `max_retries`
    failures in a row exhaust the budget, which demotes the server one
    rung down its fallback ladder (and resets the budget). After
    ``probe_interval`` consecutive healthy flushes a degraded server
    re-promotes one rung."""

    flush_timeout_ms: float | None = None
    max_retries: int = 3
    backoff_base_ms: float = 1.0
    backoff_factor: float = 2.0
    jitter: float = 0.5            # +/- fraction of the backoff step
    probe_interval: int = 8

    def backoff_s(self, attempt: int, rng) -> float:
        """Sleep before retry ``attempt`` (1-based): exponential in the
        attempt number, +/- ``jitter`` drawn from ``rng`` so a fleet of
        replicas retrying the same wedged collective does not
        re-dispatch in lockstep."""
        base = (self.backoff_base_ms / 1e3
                * self.backoff_factor ** max(attempt - 1, 0))
        if self.jitter <= 0:
            return base
        return base * (1.0 + self.jitter * (2.0 * rng.random() - 1.0))


def build_fallback_ladder(cfg: dict) -> list[tuple[str, dict]]:
    """The declared degradation sequence for an engine config: each rung
    drops ONE capability relative to the rung above, ending at the
    pure-jnp padded oracle (`query_batch_jnp` — no Pallas, no mesh, no
    compression, no CSR planning). Rung 0 is the configured engine; a
    server demotes one rung per exhausted retry budget and re-promotes
    one rung per healthy probe window.

      compressed arena   -> uncompressed arena
      sharded_labels     -> replicated labels (same mesh)
      sharded engine     -> single-device engine
      ragged dispatch    -> bucket_pair dispatch (the differential oracle)
      anything           -> pure-jnp padded oracle

    Rungs that would not change the config (e.g. an uncompressed
    single-device server) are skipped, so the ladder is minimal."""
    ladder: list[tuple[str, dict]] = [("primary", dict(cfg))]
    cur = dict(cfg)

    def push(name, **changes):
        nonlocal cur
        nxt = dict(cur, **changes)
        if nxt != cur:
            ladder.append((name, nxt))
            cur = nxt

    if cur.get("compressed"):
        push("uncompressed", compressed=False)
    if (cur.get("backend") == "sharded"
            and cur.get("device_budget_bytes") is not None):
        push("replicated", device_budget_bytes=None)
    if cur.get("backend") == "sharded":
        push("single_device", backend="device", mesh=None,
             device_budget_bytes=None, multi_pod=False)
    if cur.get("layout") == "csr" and cur.get("dispatch") == "ragged":
        push("bucket_pair", dispatch="bucket_pair")
    push("oracle", backend="device", layout="padded", dispatch="ragged",
         use_pallas=False, compressed=False, mesh=None,
         device_budget_bytes=None, multi_pod=False, interpret=None)
    return ladder

"""Baseline solutions the paper evaluates against (§III, §VI):

  C-BFS     constrained BFS on the original graph (Algorithm 1)
  W-BFS     pre-partition the graph per quality level, BFS the partition
  Dijkstra  constrained Dijkstra (priority queue; supports weighted edges)
  Naive     |w| separate classical 2-hop (PLL) indices, one per level
  LCR-adapt label-constrained-reachability adaptation: per-level 2-hop
            *reachability* index used to short-circuit unreachable queries,
            falling back to constrained BFS for the distance.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from .graph import Graph, INF_DIST
from .ref import wcsd_bfs
from .wc_index import WCIndex, build_wc_index


# --------------------------------------------------------------------- C-BFS
def cbfs_query(g: Graph, s: int, t: int, w_level: int) -> int:
    """Constrained BFS on the original graph (paper Algorithm 1)."""
    return wcsd_bfs(g, s, t, w_level)


# --------------------------------------------------------------------- W-BFS
@dataclasses.dataclass
class WBFS:
    """Graph partitioned by quality level; query runs plain BFS on the
    partition for its level (paper baseline 'W-BFS')."""
    subgraphs: list[Graph]

    @staticmethod
    def build(g: Graph) -> "WBFS":
        return WBFS(subgraphs=[g.filtered(l) for l in range(g.num_levels)])

    def query(self, s: int, t: int, w_level: int) -> int:
        if w_level >= len(self.subgraphs):
            return 0 if s == t else int(INF_DIST)
        # plain BFS: every edge of the partition already satisfies the level
        return wcsd_bfs(self.subgraphs[w_level], s, t, 0)

    def memory_bytes(self) -> int:
        return sum(sg.memory_bytes() for sg in self.subgraphs)


# ------------------------------------------------------------------ Dijkstra
def dijkstra_query(g: Graph, s: int, t: int, w_level: int,
                   edge_len: np.ndarray | None = None) -> float:
    """Constrained Dijkstra. With edge_len=None all edges have length 1
    (mirrors the paper's unweighted comparison); pass lengths for the
    weighted-graph extension (paper §V)."""
    if s == t:
        return 0
    dist = {s: 0.0}
    pq = [(0.0, s)]
    done = set()
    while pq:
        d, u = heapq.heappop(pq)
        if u in done:
            continue
        if u == t:
            return d
        done.add(u)
        beg, end = g.indptr[u], g.indptr[u + 1]
        for i in range(beg, end):
            v, lvl = int(g.nbr[i]), int(g.nbr_level[i])
            if lvl < w_level or v in done:
                continue
            w = 1.0 if edge_len is None else float(edge_len[i])
            nd = d + w
            if nd < dist.get(v, np.inf):
                dist[v] = nd
                heapq.heappush(pq, (nd, v))
    return int(INF_DIST)


def constrained_distance_grid(g: Graph) -> np.ndarray:
    """[V, V, W+1] exact constrained distances for the FULL (s, t, w_level)
    grid, via per-level BFS from every source on the level-filtered graph.

    The differential-test oracle on small instances: one BFS sweep per
    (level, source) is W·V times cheaper than V²·W single-pair calls, and
    the implementation shares nothing with the index/query paths under
    test. Level W (above every edge quality) is included: only s == t is
    reachable there."""
    V, W = g.num_nodes, g.num_levels
    out = np.full((V, V, W + 1), INF_DIST, dtype=np.int32)
    src_all = np.repeat(np.arange(V, dtype=np.int64), np.diff(g.indptr))
    for level in range(W + 1):
        keep = g.nbr_level >= level
        deg = np.bincount(src_all[keep], minlength=V)
        indptr = np.zeros(V + 1, dtype=np.int64)
        np.cumsum(deg, out=indptr[1:])
        nbr = g.nbr[keep]
        for s in range(V):
            dist = out[s, :, level]
            dist[s] = 0
            frontier = np.array([s], dtype=np.int64)
            d = 0
            while len(frontier):
                d += 1
                nxt = np.concatenate([nbr[indptr[u]:indptr[u + 1]]
                                      for u in frontier])
                nxt = np.unique(nxt)
                nxt = nxt[dist[nxt] == INF_DIST]
                dist[nxt] = d
                frontier = nxt
    return out


# ------------------------------------------------------ Naive per-w 2-hop
def _single_level_graph(g: Graph, min_level: int) -> Graph:
    """Filtered subgraph with qualities collapsed to one level, so that
    build_wc_index degenerates to classical PLL."""
    half = g.edges_src < g.edges_dst
    keep = half & (g.edges_level >= min_level)
    u, v = g.edges_src[keep], g.edges_dst[keep]
    return Graph.from_edges(g.num_nodes, u, v, np.ones(len(u)))


@dataclasses.dataclass
class NaiveIndex:
    """|w| separate classical PLL indices (paper §III 'Naïve 2-hop')."""
    per_level: list[WCIndex]
    levels: np.ndarray

    @staticmethod
    def build(g: Graph, ordering: str = "degree") -> "NaiveIndex":
        idxs = [build_wc_index(_single_level_graph(g, l), ordering=ordering)
                for l in range(g.num_levels)]
        return NaiveIndex(per_level=idxs, levels=g.levels.copy())

    def query(self, s: int, t: int, w_level: int) -> int:
        if w_level >= len(self.per_level):
            return 0 if s == t else int(INF_DIST)
        return self.per_level[w_level].query_one(s, t, 0)

    def query_batch(self, s, t, w_level) -> np.ndarray:
        out = np.full(len(s), INF_DIST, dtype=np.int32)
        for l in range(len(self.per_level)):
            m = w_level == l
            if m.any():
                out[m] = self.per_level[l].query_batch(s[m], t[m],
                                                       np.zeros(m.sum(),
                                                                np.int32))
        m = w_level >= len(self.per_level)
        if m.any():
            out[m] = np.where(s[m] == t[m], 0, INF_DIST)
        return out

    def size_entries(self) -> int:
        return sum(i.size_entries() for i in self.per_level)

    def memory_bytes(self) -> int:
        return sum(i.memory_bytes() for i in self.per_level)


# ----------------------------------------------------------------- LCR-adapt
@dataclasses.dataclass
class LCRAdapt:
    """Label-constrained-reachability adaptation: per level, a 2-hop
    *reachability* labeling (hub sets only). A query first checks
    reachability through the hubs; unreachable -> INF immediately, else the
    distance is computed by constrained BFS. Mirrors how an LCR oracle would
    be (mis)used for WCSD — it lacks distances, which is the paper's point."""
    hubsets: list[tuple[np.ndarray, np.ndarray, np.ndarray]]  # per level CSR
    graph: Graph

    @staticmethod
    def build(g: Graph, ordering: str = "degree") -> "LCRAdapt":
        hubsets = []
        for l in range(g.num_levels):
            idx = build_wc_index(_single_level_graph(g, l), ordering=ordering)
            # compress labels to hub sets (reachability only)
            counts = idx.count
            indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
            hubs = np.empty(int(counts.sum()), dtype=np.int32)
            for v in range(idx.num_nodes):
                hubs[indptr[v]:indptr[v + 1]] = idx.hub_rank[v, :counts[v]]
            hubsets.append((indptr, hubs, idx.rank))
        return LCRAdapt(hubsets=hubsets, graph=g)

    def query(self, s: int, t: int, w_level: int) -> int:
        if s == t:
            return 0
        if w_level >= len(self.hubsets):
            return int(INF_DIST)
        indptr, hubs, _ = self.hubsets[w_level]
        hs = hubs[indptr[s]:indptr[s + 1]]
        ht = hubs[indptr[t]:indptr[t + 1]]
        if not np.intersect1d(hs, ht, assume_unique=True).size:
            return int(INF_DIST)
        return wcsd_bfs(self.graph, s, t, w_level)

    def memory_bytes(self) -> int:
        return sum(ip.nbytes + h.nbytes for ip, h, _ in self.hubsets)

"""Pure oracle for WCSD: constrained BFS, deliberately simple (deque-based)
so it is an independent check on both the index and the vectorized baselines.
"""
from __future__ import annotations

from collections import deque

import numpy as np

from .graph import Graph, INF_DIST


def wcsd_bfs(g: Graph, s: int, t: int, w_level: int) -> int:
    """w-constrained distance via textbook BFS (paper Algorithm 1)."""
    if s == t:
        return 0
    if w_level >= g.num_levels:
        return int(INF_DIST)
    visited = np.zeros(g.num_nodes, dtype=bool)
    visited[s] = True
    q = deque([s])
    dist = 0
    while q:
        dist += 1
        for _ in range(len(q)):
            u = q.popleft()
            beg, end = g.indptr[u], g.indptr[u + 1]
            for v, lvl in zip(g.nbr[beg:end], g.nbr_level[beg:end]):
                if lvl < w_level or visited[v]:
                    continue
                if v == t:
                    return dist
                visited[v] = True
                q.append(int(v))
    return int(INF_DIST)


def wcsd_all_dists(g: Graph, s: int, w_level: int) -> np.ndarray:
    """All w-constrained distances from s (vectorized frontier BFS)."""
    dist = np.full(g.num_nodes, INF_DIST, dtype=np.int32)
    dist[s] = 0
    if w_level >= g.num_levels:
        return dist
    frontier = np.array([s], dtype=np.int32)
    d = 0
    from .graph import expand_frontier_csr
    while len(frontier):
        d += 1
        _, nbrs, lvls = expand_frontier_csr(g, frontier)
        nbrs = nbrs[lvls >= w_level]
        nbrs = nbrs[dist[nbrs] == INF_DIST]
        if len(nbrs) == 0:
            break
        frontier = np.unique(nbrs)
        dist[frontier] = d
    return dist


def pareto_dists(g: Graph, s: int) -> np.ndarray:
    """[V, W] matrix: D[v, l] = l-constrained distance from s to v, for every
    level l. The per-(s,v) Pareto frontier of (distance, quality) is the set of
    (D[v,l], l) with D strictly decreasing as l decreases. Oracle for index
    completeness/minimality tests."""
    W = g.num_levels
    out = np.full((g.num_nodes, W), INF_DIST, dtype=np.int32)
    for l in range(W):
        out[:, l] = wcsd_all_dists(g, s, l)
    return out

"""Path-dominance utilities (paper Def. 4/5).

A (d, w) pair dominates (d', w') iff d <= d' and w >= w'. Per (vertex, hub)
the surviving set is a Pareto staircase: sorting by (d asc, w desc) and
keeping entries whose w strictly exceeds the running max yields the minimal
set (Thm. 3: within a hub's list, d and w are then both strictly increasing).
"""
from __future__ import annotations

import numpy as np


def pareto_filter(d: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Boolean mask of non-dominated (d, w) pairs (d min-better, w max-better).

    Ties: among equal (d, w) keeps one. O(n log n)."""
    n = len(d)
    if n == 0:
        return np.zeros(0, dtype=bool)
    order = np.lexsort((-w, d))  # d asc, then w desc
    ws = w[order]
    inc = np.maximum.accumulate(ws)
    keep_sorted = np.empty(n, dtype=bool)
    keep_sorted[0] = True
    keep_sorted[1:] = ws[1:] > inc[:-1]
    keep = np.zeros(n, dtype=bool)
    keep[order] = keep_sorted
    return keep


def pareto_csr_emit(v: np.ndarray, hub: np.ndarray, d: np.ndarray,
                    w: np.ndarray, num_nodes: int
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Fused Pareto post-pass + CSR emission order for a flat entry list.

    Input: parallel arrays (vertex, hub, d, w) in any order. Returns
    ``(order, keep)`` where ``order`` sorts the entries vertex-major with
    hub ascending inside each vertex and d ascending inside each
    (vertex, hub) group — exactly the label-row order the CSR store wants —
    and ``keep`` (aligned with ``order``) marks the entries that survive
    the per-(vertex, hub) dominance filter. One sort serves both the
    minimality sweep and the flat-store scatter, so the builder never
    materializes a padded [V, cap] intermediate between them."""
    v = np.asarray(v, dtype=np.int64)
    hub = np.asarray(hub, dtype=np.int64)
    n = len(v)
    if n == 0:
        return np.zeros(0, dtype=np.int64), np.zeros(0, dtype=bool)
    key = v * num_nodes + hub  # unique per (vertex, hub): hub rank < V
    keep_by_entry = pareto_filter_grouped(key, np.asarray(d, dtype=np.int64),
                                          np.asarray(w, dtype=np.int64))
    order = np.lexsort((d, hub, v))
    return order, keep_by_entry[order]


def pareto_filter_grouped(hub: np.ndarray, d: np.ndarray, w: np.ndarray
                          ) -> np.ndarray:
    """Per-hub Pareto filter over a flat (hub, d, w) entry list.

    Sort by (hub, d asc, w desc); an entry survives iff its w strictly exceeds
    the running per-hub max. The per-group cummax is computed with a global
    cummax over ws shifted by a large per-group offset (exact for int-like
    values), avoiding python loops over entries."""
    n = len(d)
    if n == 0:
        return np.zeros(0, dtype=bool)
    order = np.lexsort((-w, d, hub))
    h, ws = hub[order], w[order].astype(np.float64)
    new_grp = np.ones(n, dtype=bool)
    new_grp[1:] = h[1:] != h[:-1]
    grp_id = np.cumsum(new_grp) - 1
    # offset each group far above the previous so a single global cummax
    # restarts effectively at each group boundary
    span = (ws.max() - ws.min()) + 1.0
    shifted = ws + grp_id * span
    inc = np.maximum.accumulate(shifted)
    keep_sorted = np.empty(n, dtype=bool)
    keep_sorted[0] = True
    keep_sorted[1:] = shifted[1:] > inc[:-1]
    keep = np.zeros(n, dtype=bool)
    keep[order] = keep_sorted
    return keep

"""Rank-batched WC-INDEX construction in JAX (beyond-paper optimization).

The paper's Algorithm 3 is strictly sequential across roots (each root's BFS
prunes against labels of every earlier root). That serializes poorly on TPU.
Following the PSL insight (Li et al., SIGMOD'19 [37]) we process roots in
*rank batches*: within a batch, the B constrained BFS runs share one jitted
dense relaxation (segment-max over edges — the same primitive as a GNN
message-passing layer), and pruning queries see the index as of the batch
start.

Consequences (measured in benchmarks/bench_indexing.py):
  + each round is one [B, V] / [B, E] dense step — MXU/VPU friendly, and the
    host loop shrinks by ~B×;
  - intra-batch pruning is deferred, so dominated entries can slip in.
    Soundness/completeness still hold (pruning only ever removes *covered*
    entries, and we only skip prunes, never add spurious paths); minimality
    is restored per (vertex, hub) by a vectorized Pareto post-pass, and the
    residual cross-hub redundancy is reported as `size_overhead`.

Two implementations live here:

  build_wc_index_batched          the original host-orchestrated pipeline:
      every round gathers/prunes in jnp, downloads the [B, V] emission mask
      to host numpy, and appends into padded [V, cap] arrays that serving
      later has to re-pack into the CSR store.
  build_wc_index_batched_packed   the device-resident pipeline: the round
      (prune + emit + relax) runs in Pallas kernels (`kernels/frontier.py`),
      the per-root hub tables T are built on device from the device-side
      partial index, F/R and a per-(root, vertex, level) emission table E
      stay on device for the whole batch (one [B, V, W+1] download per
      batch instead of one [B, V] download per round), and the emissions
      stream into a `PackedLabelsBuilder` whose finalize fuses the Pareto
      post-pass with direct CSR emission — the padded [V, cap] final
      labels are never materialized and serving starts with no repack.

Both report `host_array_syncs` / `host_scalar_syncs` so the benchmark
(`benchmarks/bench_indexing.py`) can show the sync-count collapse.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from .dominance import pareto_filter_grouped
from .graph import Graph, INF_DIST, expand_frontier_csr
from .ordering import make_order
from .wc_index import (PackedLabelsBuilder, PackedWCIndex, WCIndex,
                       _concat_ranges, _ensure_capacity, append_self_entries,
                       round_to_pow2)

DEV_INF = 1 << 29


@functools.partial(jax.jit, static_argnames=("num_segments", "do_prune"))
def _batched_round(F, R, T, hub, dist, wlev, count, root_ranks, edges_src,
                   edges_dst, edges_lvl, rank, d, *, num_segments: int,
                   do_prune: bool):
    """One synchronized BFS round for a batch of roots.

    F: [B, V] frontier quality level (-1 = inactive), R: [B, V] best
    bottleneck level, T: [B, V, W+1] per-root hub tables, labels as padded
    [V, cap] device mirrors. Returns next frontier, R, and the emission mask.
    """
    B, V = F.shape
    active = F >= 0
    Fw = jnp.clip(F, 0, T.shape[-1] - 1)
    if do_prune:
        # query the partial index: min_i dist[v,i] + T[b, hub[v,i], F[b,v]]
        cap = hub.shape[1]
        col = jnp.arange(cap)
        valid = (col[None, :] < count[:, None]) & (hub >= 0)        # [V, cap]
        tv = T[jnp.arange(B)[:, None, None],
               jnp.clip(hub, 0, V - 1)[None, :, :],
               Fw[:, :, None]]                                      # [B,V,cap]
        qual_ok = wlev[None, :, :] >= Fw[:, :, None]
        # clamp before adding: INF + INF must not overflow int32
        ds = jnp.minimum(dist, 1 << 29)
        cand = jnp.where(valid[None] & qual_ok,
                         ds[None] + jnp.minimum(tv, 1 << 29), INF_DIST)
        q = cand.min(axis=2)
        survive = active & (q > d)
    else:
        survive = active
    emit_w = jnp.where(survive, F, -1)

    # relaxation: one fused gather -> min -> segment-max over all B roots
    wp = jnp.minimum(emit_w[:, edges_src], edges_lvl[None, :])      # [B, E2]
    ok_dst = rank[edges_dst][None, :] > root_ranks[:, None]
    wp = jnp.where(ok_dst, wp, -1)
    seg = (edges_dst[None, :] + V * jnp.arange(B)[:, None]).reshape(-1)
    newR = jax.ops.segment_max(wp.reshape(-1), seg,
                               num_segments=num_segments).reshape(B, V)
    newR = jnp.maximum(newR, -1)
    improved = newR > R
    R_next = jnp.where(improved, newR, R)
    F_next = jnp.where(improved, newR, -1)
    return F_next, R_next, emit_w


def _build_T(hub, dist, wlev, count, root_ids, root_ranks, V, W):
    """Host-side per-batch hub tables (numpy; |L(root)| is small)."""
    B = len(root_ids)
    T = np.full((B, V, W + 1), INF_DIST, dtype=np.int32)
    for b, (r, k) in enumerate(zip(root_ids, root_ranks)):
        c = int(count[r])
        if c:
            hr, dr, wr = hub[r, :c], dist[r, :c], wlev[r, :c]
            reps = (wr + 1).astype(np.int64)
            rows = np.repeat(hr.astype(np.int64), reps)
            cols = _concat_ranges(reps)
            np.minimum.at(T[b].reshape(-1), rows * (W + 1) + cols,
                          np.repeat(dr, reps))
        T[b, k, :] = 0
    return T


def build_wc_index_batched(g: Graph, order: Optional[np.ndarray] = None,
                           ordering: str = "degree", batch_size: int = 32,
                           minimalize: bool = True) -> tuple[WCIndex, dict]:
    """Rank-batched construction. Returns (index, stats)."""
    V, W = g.num_nodes, g.num_levels
    if order is None:
        order = make_order(g, ordering)
    order = np.asarray(order, dtype=np.int32)
    rank = np.empty(V, dtype=np.int32)
    rank[order] = np.arange(V, dtype=np.int32)

    B = int(batch_size)
    hub = np.full((V, 4), -1, dtype=np.int32)
    dist = np.full((V, 4), INF_DIST, dtype=np.int32)
    wlev = np.full((V, 4), -1, dtype=np.int32)
    count = np.zeros(V, dtype=np.int32)

    e_src = jnp.asarray(g.edges_src)
    e_dst = jnp.asarray(g.edges_dst)
    e_lvl = jnp.asarray(g.edges_level)
    rank_d = jnp.asarray(rank)
    n_rounds = 0
    raw_entries = 0
    array_syncs = 0
    scalar_syncs = 0

    for start in range(0, V, B):
        roots = order[start:start + B]
        nb = len(roots)
        root_ranks = np.arange(start, start + nb, dtype=np.int32)
        if nb < B:  # pad the tail batch with inert rows
            roots = np.concatenate([roots, np.zeros(B - nb, np.int32)])
            root_ranks = np.concatenate(
                [root_ranks, np.full(B - nb, V + 1, np.int32)])
        T = _build_T(hub, dist, wlev, count, roots[:nb], root_ranks[:nb], V, W)
        # device mirrors, capacity rounded up to limit re-jits
        cap = max(8, 1 << int(np.ceil(np.log2(max(int(count.max()), 1) + 1))))
        hub_d = jnp.asarray(hub[:, :cap] if hub.shape[1] >= cap else
                            np.pad(hub, ((0, 0), (0, cap - hub.shape[1])),
                                   constant_values=-1))
        dist_d = jnp.asarray(dist[:, :cap] if dist.shape[1] >= cap else
                             np.pad(dist, ((0, 0), (0, cap - dist.shape[1])),
                                    constant_values=INF_DIST))
        wlev_d = jnp.asarray(wlev[:, :cap] if wlev.shape[1] >= cap else
                             np.pad(wlev, ((0, 0), (0, cap - wlev.shape[1])),
                                    constant_values=-1))
        count_d = jnp.asarray(count)

        F = np.full((B, V), -1, dtype=np.int32)
        F[np.arange(nb), roots[:nb]] = W
        F = jnp.asarray(F)
        R = F  # at d=0, R == F (root only)
        T_d = jnp.asarray(T)

        d = 0
        emitted: list[tuple[np.ndarray, np.ndarray, np.ndarray, int]] = []
        while True:
            F, R, emit_w = _batched_round(
                F, R, T_d, hub_d, dist_d, wlev_d, count_d,
                jnp.asarray(root_ranks), e_src, e_dst, e_lvl, rank_d,
                jnp.int32(d), num_segments=B * V, do_prune=(d > 0))
            n_rounds += 1
            if d > 0:
                ew = np.asarray(emit_w)        # [B, V] download, every round
                array_syncs += 1
                bs, vs = np.nonzero(ew >= 0)
                if len(bs):
                    emitted.append((bs.astype(np.int32), vs.astype(np.int32),
                                    ew[bs, vs].astype(np.int32), d))
            d += 1
            scalar_syncs += 1
            if not bool(jnp.any(F >= 0)):
                break
        # ---- append batch emissions, grouped by vertex, hub-rank ascending
        if emitted:
            b_all = np.concatenate([e[0] for e in emitted])
            v_all = np.concatenate([e[1] for e in emitted])
            w_all = np.concatenate([e[2] for e in emitted])
            d_all = np.concatenate([np.full(len(e[0]), e[3], np.int32)
                                    for e in emitted])
            raw_entries += len(b_all)
            o = np.lexsort((d_all, b_all, v_all))
            b_all, v_all, w_all, d_all = (b_all[o], v_all[o], w_all[o],
                                          d_all[o])
            hub_new = root_ranks[b_all]
            # per-vertex contiguous runs -> vectorized append
            uniq, run_start = np.unique(v_all, return_index=True)
            run_len = np.diff(np.append(run_start, len(v_all)))
            within = _concat_ranges(run_len)
            pos = count[v_all] + within
            need = int(pos.max()) + 1
            if need > hub.shape[1]:
                new_cap = max(need, hub.shape[1] * 2)
                pad = ((0, 0), (0, new_cap - hub.shape[1]))
                hub = np.pad(hub, pad, constant_values=-1)
                dist = np.pad(dist, pad, constant_values=INF_DIST)
                wlev = np.pad(wlev, pad, constant_values=-1)
            hub[v_all, pos] = hub_new
            dist[v_all, pos] = d_all
            wlev[v_all, pos] = w_all
            count[uniq] += run_len.astype(np.int32)

    stats = {"rounds": n_rounds, "raw_entries": int(raw_entries),
             "batch_size": B, "host_array_syncs": array_syncs,
             "host_scalar_syncs": scalar_syncs}
    if minimalize:
        # vectorized per-(vertex, hub) Pareto sweep to restore minimality
        total = int(count.sum())
        v_flat = np.repeat(np.arange(V, dtype=np.int64), count)
        col = _concat_ranges(count)
        h_flat = hub[v_flat, col]
        d_flat = dist[v_flat, col]
        w_flat = wlev[v_flat, col]
        key = v_flat * V + h_flat  # group by (vertex, hub)
        keep = pareto_filter_grouped(key, d_flat.astype(np.int64),
                                     w_flat.astype(np.int64))
        removed = total - int(keep.sum())
        stats["dominated_removed"] = removed
        if removed:
            v2, h2, d2, w2 = (v_flat[keep], h_flat[keep], d_flat[keep],
                              w_flat[keep])
            count = np.bincount(v2, minlength=V).astype(np.int32)
            capn = max(int(count.max()), 1)
            hub = np.full((V, capn), -1, dtype=np.int32)
            dist = np.full((V, capn), INF_DIST, dtype=np.int32)
            wlev = np.full((V, capn), -1, dtype=np.int32)
            pos = _concat_ranges(count)
            # entries already sorted by (v, hub asc, d asc) after filtering
            o = np.lexsort((d2, h2, v2))
            hub[v2[o], pos] = h2[o]
            dist[v2[o], pos] = d2[o]
            wlev[v2[o], pos] = w2[o]
    hub, dist, wlev, count = append_self_entries(hub, dist, wlev, count,
                                                 rank, W)
    idx = WCIndex(order=order, rank=rank, levels=g.levels.copy(),
                  hub_rank=hub, dist=dist, wlev=wlev, count=count)
    stats["entries"] = idx.size_entries()
    return idx, stats


# --------------------------------------------------- device-resident builder
@functools.partial(jax.jit, static_argnames=("num_nodes", "num_levels"))
def _build_T_device(hub, dist, wlev, roots, root_ranks, *, num_nodes: int,
                    num_levels: int):
    """Per-root hub tables, built on device from the device-side partial
    index: T[b, h, f] = min dist from root b to hub-rank h over paths of
    quality level >= f (INF where unreachable; 0 on the root's own rank).
    Replaces `_build_T`'s host loop + per-batch [B, V, W+1] upload."""
    V, W1 = num_nodes, num_levels + 1
    B = roots.shape[0]
    hr = hub[roots]                                     # [B, cap] hub ranks
    dr = jnp.minimum(dist[roots], DEV_INF)
    wr = wlev[roots]
    feas = jnp.arange(W1)[None, None, :] <= wr[:, :, None]      # [B, cap, W1]
    vals = jnp.where(feas & (hr >= 0)[:, :, None], dr[:, :, None],
                     jnp.int32(INF_DIST))
    T = jnp.full((B, V, W1), INF_DIST, dtype=jnp.int32)
    T = T.at[jnp.arange(B)[:, None], jnp.clip(hr, 0, V - 1), :].min(vals)
    # the root reaches itself at distance 0 at any quality; inert pad rows
    # carry root_ranks == V + 1 and must not touch the table
    self_val = jnp.where((root_ranks < V)[:, None], 0, jnp.int32(INF_DIST))
    T = T.at[jnp.arange(B), jnp.clip(root_ranks, 0, V - 1), :].min(self_val)
    return T


@jax.jit
def _accum_emit(E, emit_w, d):
    """Fold one round's emissions into the on-device emission table:
    E[b, v, w] = the round (== distance) at which (root b, vertex v) emitted
    quality level w. Each cell is written at most once (per (b, v) the
    emitted level strictly increases across rounds), so min() is a plain
    first-write."""
    W1 = E.shape[2]
    onehot = emit_w[:, :, None] == jnp.arange(W1)[None, None, :]
    return jnp.where(onehot, jnp.minimum(E, d), E)


@jax.jit
def _scatter_append(hub, dist, wlev, v, pos, h_new, d_new, w_new):
    """Append new label entries into the device-side padded partial index
    (prune mirror). Out-of-range rows (v == V: length padding) are dropped."""
    return (hub.at[v, pos].set(h_new, mode="drop"),
            dist.at[v, pos].set(d_new, mode="drop"),
            wlev.at[v, pos].set(w_new, mode="drop"))


def build_wc_index_batched_packed(
        g: Graph, order: Optional[np.ndarray] = None,
        ordering: str = "degree", batch_size: int = 32,
        minimalize: bool = True, use_kernel: bool = True,
        interpret: bool = True) -> tuple[PackedWCIndex, dict]:
    """Device-resident rank-batched construction emitting CSR directly.

    Same label semantics as `build_wc_index_batched` (identical entry
    multiset before the Pareto pass, identical store after it — asserted by
    tests/test_differential.py), but the pipeline is restructured for the
    accelerator: the per-round prune + emit + relax run as Pallas kernels,
    per-root hub tables are built on device from the device-side partial
    index, and F/R/E state never leaves the device inside a batch. The only
    per-round host sync is the scalar termination check; emissions come
    back once per batch as the [B, V, W+1] table E and stream into a
    `PackedLabelsBuilder`, which finalizes straight into `PackedLabels` —
    no padded [V, cap] final labels, no serve-time repack.

    Returns (PackedWCIndex, stats).
    """
    from ..kernels import ops as kops

    V, W = g.num_nodes, g.num_levels
    if order is None:
        order = make_order(g, ordering)
    order = np.asarray(order, dtype=np.int32)
    rank = np.empty(V, dtype=np.int32)
    rank[order] = np.arange(V, dtype=np.int32)

    B = int(batch_size)
    nbr_np, lvl_np = g.padded_adjacency()
    nbr_d = jnp.asarray(nbr_np)
    lvl_d = jnp.asarray(lvl_np)
    rank_d = jnp.asarray(rank)

    cap = 8
    hub_d = jnp.full((V, cap), -1, dtype=jnp.int32)
    dist_d = jnp.full((V, cap), INF_DIST, dtype=jnp.int32)
    wlev_d = jnp.full((V, cap), -1, dtype=jnp.int32)
    count = np.zeros(V, dtype=np.int64)

    builder = PackedLabelsBuilder(V)
    n_rounds = 0
    raw_entries = 0
    array_syncs = 0
    scalar_syncs = 0

    for start in range(0, V, B):
        roots = order[start:start + B]
        nb = len(roots)
        root_ranks = np.arange(start, start + nb, dtype=np.int32)
        if nb < B:  # pad the tail batch with inert rows
            roots = np.concatenate([roots, np.zeros(B - nb, np.int32)])
            root_ranks = np.concatenate(
                [root_ranks, np.full(B - nb, V + 1, np.int32)])
        rr_d = jnp.asarray(root_ranks)
        T_d = _build_T_device(hub_d, dist_d, wlev_d, jnp.asarray(roots),
                              rr_d, num_nodes=V, num_levels=W)
        F = np.full((B, V), -1, dtype=np.int32)
        F[np.arange(nb), roots[:nb]] = W
        F = jnp.asarray(F)
        R = F
        E = jnp.full((B, V, W + 1), INF_DIST, dtype=jnp.int32)

        d = 0
        while True:
            emit_w = kops.wc_prune_emit(
                F, T_d, hub_d, dist_d, wlev_d, jnp.int32(d),
                do_prune=(d > 0), use_kernel=use_kernel, interpret=interpret)
            if d > 0:
                E = _accum_emit(E, emit_w, jnp.int32(d))
            F, R = kops.wc_relax_batched(
                emit_w, nbr_d, lvl_d, rank_d, rr_d, R,
                use_kernel=use_kernel, interpret=interpret)
            n_rounds += 1
            d += 1
            scalar_syncs += 1
            if not bool(jnp.any(F >= 0)):
                break

        En = np.asarray(E)                  # ONE download per batch
        array_syncs += 1
        bs, vs, ws = np.nonzero(En < int(INF_DIST))
        if len(bs) == 0:
            continue
        ds = En[bs, vs, ws]
        # per (b, v) the emitted level rises with the round, so sorting by
        # (v, b, w) is exactly (vertex, hub rank asc, dist asc)
        o = np.lexsort((ws, bs, vs))
        bs, vs, ws, ds = bs[o], vs[o], ws[o], ds[o]
        hub_new = root_ranks[bs].astype(np.int32)
        raw_entries += len(bs)
        builder.append_batch(vs, hub_new, ds, ws)

        # mirror the new entries into the device-side prune index
        uniq, run_start = np.unique(vs, return_index=True)
        run_len = np.diff(np.append(run_start, len(vs)))
        pos = count[vs] + _concat_ranges(run_len)
        need = int(pos.max()) + 1
        if need > cap:
            new_cap = max(need, cap * 2)
            pad = ((0, 0), (0, new_cap - cap))
            hub_d = jnp.pad(hub_d, pad, constant_values=-1)
            dist_d = jnp.pad(dist_d, pad, constant_values=int(INF_DIST))
            wlev_d = jnp.pad(wlev_d, pad, constant_values=-1)
            cap = new_cap
        # pad the scatter to a power-of-two length (bounded recompiles);
        # padding rows target v == V and are dropped by the scatter
        n = len(vs)
        npad = round_to_pow2(n)
        v_s = np.full(npad, V, dtype=np.int32)
        p_s = np.zeros(npad, dtype=np.int32)
        h_s = np.zeros(npad, dtype=np.int32)
        d_s = np.zeros(npad, dtype=np.int32)
        w_s = np.zeros(npad, dtype=np.int32)
        v_s[:n] = vs
        p_s[:n] = pos
        h_s[:n] = hub_new
        d_s[:n] = ds
        w_s[:n] = ws
        hub_d, dist_d, wlev_d = _scatter_append(
            hub_d, dist_d, wlev_d, jnp.asarray(v_s), jnp.asarray(p_s),
            jnp.asarray(h_s), jnp.asarray(d_s), jnp.asarray(w_s))
        count[uniq] += run_len

    labels, removed = builder.finalize(rank=rank, num_levels=W,
                                       minimalize=minimalize)
    idx = PackedWCIndex(order=order, rank=rank, levels=g.levels.copy(),
                        labels=labels)
    stats = {"rounds": n_rounds, "raw_entries": int(raw_entries),
             "batch_size": B, "host_array_syncs": array_syncs,
             "host_scalar_syncs": scalar_syncs,
             "dominated_removed": removed,
             "entries": labels.size_entries()}
    return idx, stats


def clean_index(idx: WCIndex) -> tuple[WCIndex, int]:
    """PSL-style label cleaning: drop entries that are *unnecessary* (paper's
    minimality definition) — entry (v, hub k, d, w) is removed when the query
    Q(v, order[k], w) is already answered with distance <= d through hubs of
    rank < k. Processing roots in rank order keeps witnesses valid by
    induction on hub rank. Restores sequential-construction minimality for
    the rank-batched builder."""
    V, W = idx.num_nodes, idx.num_levels
    hub, dist, wlev = (idx.hub_rank.copy(), idx.dist.copy(), idx.wlev.copy())
    count = idx.count.copy()
    cap = hub.shape[1]
    col = np.arange(cap)
    removed_total = 0
    # flat view of (entry -> vertex) per hub
    for k in range(V):
        root = int(idx.order[k])
        # vertices holding an entry with hub k (skip self entries)
        vs, cols = np.nonzero((hub == k) & (col[None, :] < count[:, None]))
        sel = vs != root
        vs, cols = vs[sel], cols[sel]
        if len(vs) == 0:
            continue
        d_e = dist[vs, cols]
        w_e = wlev[vs, cols]
        # T for root over hubs < k
        c = int(count[root])
        hr, dr, wr = hub[root, :c], dist[root, :c], wlev[root, :c]
        m = hr < k
        T = np.full((V, W + 1), INF_DIST, dtype=np.int64)
        if m.any():
            reps = (wr[m] + 1).astype(np.int64)
            rows = np.repeat(hr[m].astype(np.int64), reps)
            np.minimum.at(T.reshape(-1), rows * (W + 1) + _concat_ranges(reps),
                          np.repeat(dr[m], reps))
        # query each entry via v's hubs < k
        hv = hub[vs]
        ok = (col[None, :] < count[vs, None]) & (hv >= 0) & (hv < k) & \
             (wlev[vs] >= w_e[:, None])
        tv = T[np.clip(hv, 0, V - 1), w_e[:, None]]
        cand = np.where(ok, dist[vs].astype(np.int64) + tv, INF_DIST)
        drop = cand.min(axis=1) <= d_e
        if drop.any():
            removed_total += int(drop.sum())
            dv, dc = vs[drop], cols[drop]
            o = np.lexsort((-dc, dv))  # right-to-left per vertex: stable cols
            for v, cpos in zip(dv[o], dc[o]):
                cc = int(count[v])
                hub[v, cpos:cc - 1] = hub[v, cpos + 1:cc]
                dist[v, cpos:cc - 1] = dist[v, cpos + 1:cc]
                wlev[v, cpos:cc - 1] = wlev[v, cpos + 1:cc]
                hub[v, cc - 1] = -1
                dist[v, cc - 1] = INF_DIST
                wlev[v, cc - 1] = -1
                count[v] -= 1
    out = WCIndex(order=idx.order, rank=idx.rank, levels=idx.levels,
                  hub_rank=hub, dist=dist, wlev=wlev, count=count)
    return out, removed_total


# --------------------------------------------------------------------------
# Incremental maintenance (docs/dynamic-index.md). The delta layer of
# `core.wc_index.DynamicWCIndex` calls these two functions per update batch:
# `affected_vertices` bounds the blast radius of an edge change, and
# `rebuild_affected_rows` re-runs the pruned rank-ordered rounds for exactly
# those roots, seeded with the current serving rows.


def affected_vertices(g_old: Graph, g_new: Graph, endpoints) -> np.ndarray:
    """Vertices whose label row may change when ``g_old`` becomes ``g_new``.

    The connected-component closure of the touched ``endpoints`` at level 0
    (all edges), over the UNION of the two graphs. Conservative but provably
    sufficient: a root in a different component (in both graphs) explores an
    unchanged subgraph, seeds its hub table from labels whose hubs live in
    that unchanged component, and prunes against rows of vertices it can
    reach there — every input to its BFS is unchanged, so its emissions are
    too. Conversely every emission of an affected root targets a vertex of
    the closure, so label corrections never escape the returned set.
    """
    V = g_new.num_nodes
    seen = np.zeros(V, dtype=bool)
    f = np.unique(np.asarray(list(endpoints), dtype=np.int64))
    f = f[(f >= 0) & (f < V)]
    seen[f] = True
    f = f.astype(np.int32)
    while len(f):
        nxt = [expand_frontier_csr(g, f)[1] for g in (g_old, g_new)]
        nxt = np.unique(np.concatenate(nxt).astype(np.int64))
        nxt = nxt[~seen[nxt]]
        seen[nxt] = True
        f = nxt.astype(np.int32)
    return np.flatnonzero(seen).astype(np.int32)


def rebuild_affected_rows(g: Graph, order: np.ndarray, rank: np.ndarray,
                          num_levels: int, merged_flat, affected) -> dict:
    """Recompute the label rows of ``affected`` vertices on the mutated graph.

    Re-runs the sequential Algorithm-3 loop of `wc_index.build_wc_index` for
    the affected ROOTS only (ascending rank), seeded with the current
    serving rows (``merged_flat``: flat hub/dist/wlev + offsets) minus every
    entry whose hub is an affected root and minus the trailing self entries
    (the loop emulates the root's self entry via ``T[k, :] = 0``, exactly
    like the from-scratch build). Soundness of the seeded pruning: at root
    ``k``, unaffected seed entries with hub < k are exactly what a
    from-scratch run over ``g`` would have emitted by then (closure
    argument in `affected_vertices`), affected hubs < k were re-run earlier
    in this very loop, and entries with hub >= k are masked out of the hub
    table so pruning never consults a lower-priority witness.

    Returns ``{vertex: (hub, dist, wlev)}`` — full replacement rows
    (hub-sorted, staircase-minimal per hub group, self-entry-terminated)
    for every vertex whose row may have changed.
    """
    V, W = g.num_nodes, int(num_levels)
    order = np.asarray(order, dtype=np.int32)
    rank = np.asarray(rank, dtype=np.int32)
    fhub, fdist, fwlev, offs = merged_flat
    affected = np.asarray(affected, dtype=np.int64)
    aff_ranks = np.sort(rank[affected].astype(np.int64))
    is_aff_rank = np.zeros(V, dtype=bool)
    is_aff_rank[aff_ranks] = True

    # ---- seed padded working rows from the current serving store ----------
    lens = (offs[1:] - offs[:-1]).astype(np.int64)
    rows_of = np.repeat(np.arange(V, dtype=np.int64), lens)
    keep = ~is_aff_rank[np.clip(fhub, 0, V - 1)]
    keep[offs[1:] - 1] = False  # every row terminates with its self entry
    krows = rows_of[keep]
    count = np.bincount(krows, minlength=V).astype(np.int32)
    cap = max(int(count.max()) if V else 1, 8)
    hub = np.full((V, cap), -1, dtype=np.int32)
    dist = np.full((V, cap), INF_DIST, dtype=np.int32)
    wlev = np.full((V, cap), -1, dtype=np.int32)
    cols = _concat_ranges(count.astype(np.int64))
    hub[krows, cols] = fhub[keep]
    dist[krows, cols] = fdist[keep]
    wlev[krows, cols] = fwlev[keep]
    # rows that lost an entry are stale even if the re-run emits nothing back
    dropped = ~keep
    dropped[offs[1:] - 1] = False  # self entries are re-appended, not drops
    touched = np.zeros(V, dtype=bool)
    touched[affected] = True
    touched[rows_of[dropped]] = True

    # ---- re-run the pruned rank-ordered rounds for affected roots ---------
    T = np.full((V, W + 1), INF_DIST, dtype=np.int32)
    touched_T: list[np.ndarray] = []
    R = np.full(V, -1, dtype=np.int32)
    touched_R: list[np.ndarray] = []
    for k in aff_ranks:
        k = int(k)
        root = int(order[k])
        c = int(count[root])
        if c:
            hr, dr, wr = hub[root, :c], dist[root, :c], wlev[root, :c]
            pre = hr < k  # only hubs the from-scratch run would know by now
            hr, dr, wr = hr[pre], dr[pre], wr[pre]
            if len(hr):
                reps = (wr + 1).astype(np.int64)
                rows = np.repeat(hr.astype(np.int64), reps)
                np.minimum.at(T.reshape(-1),
                              rows * (W + 1) + _concat_ranges(reps),
                              np.repeat(dr, reps))
                touched_T.append(hr.copy())
        T[k, :] = 0
        touched_T.append(np.array([k], dtype=np.int32))
        R[root] = W
        touched_R.append(np.array([root], dtype=np.int32))

        frontier_v = np.array([root], dtype=np.int32)
        frontier_w = np.array([W], dtype=np.int32)
        d = 0
        while len(frontier_v):
            if d > 0:
                capn = hub.shape[1]
                col = np.arange(capn)
                m = (col[None, :] < count[frontier_v, None]) & \
                    (wlev[frontier_v] >= frontier_w[:, None])
                hubs = hub[frontier_v]
                # hubs >= k stay INF in T: never prune on a lower-priority
                # witness (they may not exist in the from-scratch run yet)
                tv = T[np.clip(hubs, 0, V - 1), frontier_w[:, None]]
                cand = np.where(
                    m, dist[frontier_v].astype(np.int64) + tv, INF_DIST)
                survive = cand.min(axis=1) > d
                frontier_v = frontier_v[survive]
                frontier_w = frontier_w[survive]
                if len(frontier_v) == 0:
                    break
                hub, dist, wlev = _ensure_capacity((hub, dist, wlev), count,
                                                   frontier_v)
                pos = count[frontier_v]
                hub[frontier_v, pos] = k
                dist[frontier_v, pos] = d
                wlev[frontier_v, pos] = frontier_w
                count[frontier_v] += 1
                touched[frontier_v] = True
            src_pos, nbrs, lvls = expand_frontier_csr(g, frontier_v)
            w_new = np.minimum(frontier_w[src_pos], lvls)
            valid = (rank[nbrs] > k) & (w_new > R[nbrs])
            nbrs, w_new = nbrs[valid], w_new[valid]
            if len(nbrs):
                np.maximum.at(R, nbrs, w_new)
                cands = np.unique(nbrs)
                touched_R.append(cands)
                frontier_v = cands
                frontier_w = R[cands].copy()
            else:
                frontier_v = np.zeros(0, dtype=np.int32)
                frontier_w = np.zeros(0, dtype=np.int32)
            d += 1
        for arr in touched_T:
            T[arr] = INF_DIST
        touched_T.clear()
        for arr in touched_R:
            R[arr] = -1
        touched_R.clear()

    # ---- assemble full replacement rows (hub-sorted + self entry) ---------
    out = {}
    for v in np.flatnonzero(touched):
        v = int(v)
        c = int(count[v])
        h, dd, w = hub[v, :c], dist[v, :c], wlev[v, :c]
        o = np.lexsort((dd, h))
        h, dd, w = h[o], dd[o], w[o]
        out[v] = (np.append(h, rank[v]).astype(np.int32),
                  np.append(dd, 0).astype(np.int32),
                  np.append(w, W).astype(np.int32))
    return out

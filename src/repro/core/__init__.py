"""Core of the paper reproduction: WC-INDEX and friends."""
from .graph import Graph, INF_DIST
from .wc_index import (PackedLabels, PackedLabelsBuilder, PackedWCIndex,
                       WCIndex, build_wc_index)
from .wc_index_batched import (build_wc_index_batched,
                               build_wc_index_batched_packed, clean_index)
from .ordering import make_order, degree_order, tree_decomposition_order, hybrid_order
from .query import (DeviceQueryEngine, PendingResult, QuerySubBatch,
                    ShardedQueryEngine, plan_query_batch, query_batch_jnp)
from .serve import WCSDServer

__all__ = [
    "Graph", "INF_DIST", "PackedLabels", "PackedLabelsBuilder",
    "PackedWCIndex", "WCIndex", "build_wc_index", "build_wc_index_batched",
    "build_wc_index_batched_packed", "clean_index", "make_order",
    "degree_order", "tree_decomposition_order", "hybrid_order",
    "DeviceQueryEngine", "PendingResult", "QuerySubBatch",
    "ShardedQueryEngine", "plan_query_batch", "query_batch_jnp",
    "WCSDServer",
]

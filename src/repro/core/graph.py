"""Graph data structures for quality-constrained shortest distance (WCSD).

The canonical in-memory form is numpy (host-side index construction); jnp
mirrors are produced on demand for jitted relaxation / query steps.

Qualities are canonicalized to integer *levels*: ``levels`` is the ascending
sorted array of distinct edge qualities, and each edge stores the index of its
quality in ``levels``. A query threshold ``w`` maps to the smallest level
``l`` with ``levels[l] >= w``; an edge qualifies iff ``edge_level >= l``.
This is exact (no discretization error) and makes label entries integer.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

INF_DIST = np.int32(1 << 30)


@dataclasses.dataclass
class Graph:
    """Undirected graph with edge qualities, stored as symmetric CSR.

    Attributes:
      num_nodes: |V|
      indptr: [V+1] CSR row pointers over the symmetrized edge list.
      nbr: [2E] neighbor ids, sorted by source.
      nbr_level: [2E] integer quality level of each half-edge.
      levels: [W] ascending distinct quality values (float64).
      edges_src/edges_dst/edges_level: [2E] flat symmetric edge list
        (same content as CSR, kept for segment-op style relaxation).
    """

    num_nodes: int
    indptr: np.ndarray
    nbr: np.ndarray
    nbr_level: np.ndarray
    levels: np.ndarray
    edges_src: np.ndarray
    edges_dst: np.ndarray
    edges_level: np.ndarray
    # monotone mutation counter: `mutate_edges` returns a graph with
    # version + 1, and the dynamic index / serving staleness flags (and the
    # test fixtures' session caches) key on it. A freshly built graph is
    # version 0.
    version: int = 0

    # ---------------------------------------------------------------- build
    @staticmethod
    def from_edges(num_nodes: int, u: np.ndarray, v: np.ndarray,
                   qual: np.ndarray) -> "Graph":
        """Build from an undirected edge list (each edge listed once)."""
        u = np.asarray(u, dtype=np.int32)
        v = np.asarray(v, dtype=np.int32)
        qual = np.asarray(qual, dtype=np.float64)
        if not (u.shape == v.shape == qual.shape):
            raise ValueError("edge arrays must have matching shapes")
        keep = u != v  # drop self loops
        u, v, qual = u[keep], v[keep], qual[keep]
        levels, edge_level = np.unique(qual, return_inverse=True)
        edge_level = edge_level.astype(np.int32)
        # Deduplicate parallel edges, keeping the best (max) quality level.
        key = u.astype(np.int64) * num_nodes + v
        key2 = v.astype(np.int64) * num_nodes + u
        key = np.minimum(key, key2)  # canonical undirected key
        order = np.lexsort((-edge_level, key))
        key, u, v, edge_level = key[order], u[order], v[order], edge_level[order]
        first = np.ones(len(key), dtype=bool)
        first[1:] = key[1:] != key[:-1]
        u, v, edge_level = u[first], v[first], edge_level[first]

        src = np.concatenate([u, v])
        dst = np.concatenate([v, u])
        lvl = np.concatenate([edge_level, edge_level])
        order = np.lexsort((dst, src))
        src, dst, lvl = src[order], dst[order], lvl[order]
        indptr = np.zeros(num_nodes + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        indptr = np.cumsum(indptr).astype(np.int64)
        return Graph(num_nodes=num_nodes, indptr=indptr, nbr=dst.astype(np.int32),
                     nbr_level=lvl.astype(np.int32), levels=levels,
                     edges_src=src.astype(np.int32), edges_dst=dst.astype(np.int32),
                     edges_level=lvl.astype(np.int32))

    # ---------------------------------------------------------------- props
    @property
    def num_levels(self) -> int:
        return int(len(self.levels))

    @property
    def num_edges(self) -> int:
        """Number of undirected edges."""
        return int(len(self.nbr) // 2)

    def degree(self) -> np.ndarray:
        return (self.indptr[1:] - self.indptr[:-1]).astype(np.int64)

    def level_of(self, w: float) -> int:
        """Smallest level index l with levels[l] >= w (== num_levels if none)."""
        return int(np.searchsorted(self.levels, w, side="left"))

    def neighbors(self, u: int) -> tuple[np.ndarray, np.ndarray]:
        s, e = int(self.indptr[u]), int(self.indptr[u + 1])
        return self.nbr[s:e], self.nbr_level[s:e]

    # ------------------------------------------------------------- variants
    def filtered(self, min_level: int) -> "Graph":
        """Subgraph with only edges of level >= min_level (same vertex set)."""
        half = self.edges_src < self.edges_dst
        keep = half & (self.edges_level >= min_level)
        g = Graph.from_edges(self.num_nodes, self.edges_src[keep],
                             self.edges_dst[keep],
                             self.levels[self.edges_level[keep]])
        # Preserve the global level table so level indices keep their meaning.
        if len(g.levels) != len(self.levels):
            remap = np.searchsorted(self.levels, g.levels).astype(np.int32)
            lut = remap  # local level -> global level
            g = dataclasses.replace(
                g,
                nbr_level=lut[g.nbr_level] if len(g.nbr_level) else g.nbr_level,
                edges_level=lut[g.edges_level] if len(g.edges_level) else g.edges_level,
                levels=self.levels.copy())
        return g

    def padded_adjacency(self, max_deg: Optional[int] = None,
                         pad_node: int = -1):
        """Return ([V, D] neighbor ids, [V, D] levels) padded with sentinel.

        pad neighbor id = pad_node (-1), pad level = -1 (never qualifies).
        """
        deg = self.degree()
        D = int(max_deg if max_deg is not None else (deg.max() if len(deg) else 1))
        D = max(D, 1)
        V = self.num_nodes
        nbr_pad = np.full((V, D), pad_node, dtype=np.int32)
        lvl_pad = np.full((V, D), -1, dtype=np.int32)
        for v in range(V):
            s, e = self.indptr[v], self.indptr[v + 1]
            d = min(int(e - s), D)
            nbr_pad[v, :d] = self.nbr[s:s + d]
            lvl_pad[v, :d] = self.nbr_level[s:s + d]
        return nbr_pad, lvl_pad

    def memory_bytes(self) -> int:
        return int(self.indptr.nbytes + self.nbr.nbytes + self.nbr_level.nbytes
                   + self.edges_src.nbytes + self.edges_dst.nbytes
                   + self.edges_level.nbytes + self.levels.nbytes)


def mutate_edges(g: Graph, inserts=(), deletes=()) -> Graph:
    """New `Graph` with ``deletes`` removed and ``inserts`` added/upserted.

    ``inserts`` is an iterable of ``(u, v, quality)``; ``deletes`` of
    ``(u, v)`` (orientation-insensitive). The GLOBAL level table is
    preserved verbatim — level indices keep their meaning for any index
    built over ``g`` — so an inserted quality must already be a member of
    ``g.levels`` (a genuinely new quality value changes what every stored
    ``wlev`` means and requires a full rebuild; we refuse instead of
    silently re-binning). Inserting over an existing edge replaces its
    quality (upsert). The result carries ``version = g.version + 1``.
    """
    half = g.edges_src < g.edges_dst
    u = g.edges_src[half].astype(np.int64)
    v = g.edges_dst[half].astype(np.int64)
    lvl = g.edges_level[half].copy()
    drop = set()
    for a, b in deletes:
        drop.add((min(int(a), int(b)), max(int(a), int(b))))
    ins_u, ins_v, ins_l = [], [], []
    for a, b, q in inserts:
        a, b = int(a), int(b)
        if a == b:
            raise ValueError(f"self loop ({a}, {b}) cannot be inserted")
        li = int(np.searchsorted(g.levels, q, side="left"))
        if li >= len(g.levels) or g.levels[li] != q:
            raise ValueError(
                f"inserted quality {q!r} is not in the graph's level table "
                f"{g.levels.tolist()}; a new quality value re-bins every "
                "label level — rebuild the index instead")
        drop.add((min(a, b), max(a, b)))  # upsert: replace, don't dedup-max
        ins_u.append(a)
        ins_v.append(b)
        ins_l.append(li)
    if drop:
        lo, hi = np.minimum(u, v), np.maximum(u, v)
        keys = lo * g.num_nodes + hi
        drop_keys = np.array([a * g.num_nodes + b for a, b in drop],
                             dtype=np.int64)
        keep = ~np.isin(keys, drop_keys)
        u, v, lvl = u[keep], v[keep], lvl[keep]
    u2 = np.concatenate([u, np.asarray(ins_u, dtype=np.int64)])
    v2 = np.concatenate([v, np.asarray(ins_v, dtype=np.int64)])
    l2 = np.concatenate([lvl, np.asarray(ins_l, dtype=np.int32)])
    g2 = Graph.from_edges(g.num_nodes, u2.astype(np.int32),
                          v2.astype(np.int32), g.levels[l2])
    # from_edges re-derives levels from the surviving quality multiset;
    # restore the global table (same trick as `filtered`)
    if len(g2.levels) != len(g.levels) or not np.array_equal(g2.levels,
                                                             g.levels):
        lut = np.searchsorted(g.levels, g2.levels).astype(np.int32)
        g2 = dataclasses.replace(
            g2,
            nbr_level=lut[g2.nbr_level] if len(g2.nbr_level) else g2.nbr_level,
            edges_level=(lut[g2.edges_level] if len(g2.edges_level)
                         else g2.edges_level),
            levels=g.levels.copy())
    return dataclasses.replace(g2, version=g.version + 1)


def expand_frontier_csr(g: Graph, nodes: np.ndarray
                        ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized CSR expansion: all (src_pos, nbr, level) for edges out of
    ``nodes``. src_pos indexes into ``nodes``. Pure numpy, no python loop."""
    starts = g.indptr[nodes]
    degs = (g.indptr[nodes + 1] - starts).astype(np.int64)
    total = int(degs.sum())
    if total == 0:
        z = np.zeros(0, dtype=np.int64)
        return z, z.astype(np.int32), z.astype(np.int32)
    src_pos = np.repeat(np.arange(len(nodes), dtype=np.int64), degs)
    cum = np.concatenate([[0], np.cumsum(degs)[:-1]])
    eidx = np.repeat(starts, degs) + (np.arange(total, dtype=np.int64)
                                      - np.repeat(cum, degs))
    return src_pos, g.nbr[eidx], g.nbr_level[eidx]

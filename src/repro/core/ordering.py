"""Vertex ordering strategies (paper §IV-D): degree, MDE tree-decomposition,
and the hybrid core/periphery order. Orders are returned as ``order`` arrays
(rank -> vertex id), highest-importance vertex first."""
from __future__ import annotations

import heapq

import numpy as np

from .graph import Graph


def degree_order(g: Graph) -> np.ndarray:
    """Non-ascending degree (ties broken by vertex id for determinism)."""
    deg = g.degree()
    return np.lexsort((np.arange(g.num_nodes), -deg)).astype(np.int32)


def mde_elimination(g: Graph, eliminate: np.ndarray | None = None
                    ) -> np.ndarray:
    """Minimum-degree-elimination sequence (paper Def. 8).

    Repeatedly removes the minimum-degree vertex and adds a clique over its
    neighbors in the transient graph. Returns the elimination sequence
    (first-eliminated first). ``eliminate`` optionally restricts elimination
    to a subset (used by the hybrid order); other vertices are never removed.
    Lazy-heap implementation with adjacency sets."""
    V = g.num_nodes
    adj = [set() for _ in range(V)]
    for v in range(V):
        s, e = g.indptr[v], g.indptr[v + 1]
        adj[v].update(int(x) for x in g.nbr[s:e])
    allowed = np.ones(V, dtype=bool) if eliminate is None else np.asarray(
        eliminate, dtype=bool)
    heap = [(len(adj[v]), v) for v in range(V) if allowed[v]]
    heapq.heapify(heap)
    removed = np.zeros(V, dtype=bool)
    seq = []
    while heap:
        d, v = heapq.heappop(heap)
        if removed[v] or d != len(adj[v]):
            continue  # stale heap entry
        removed[v] = True
        seq.append(v)
        nbrs = [u for u in adj[v] if not removed[u]]
        for u in nbrs:
            adj[u].discard(v)
        # clique fill over the transient neighbors
        for i, a in enumerate(nbrs):
            for b in nbrs[i + 1:]:
                if b not in adj[a]:
                    adj[a].add(b)
                    adj[b].add(a)
        for u in nbrs:
            if allowed[u] and not removed[u]:
                heapq.heappush(heap, (len(adj[u]), u))
    return np.array(seq, dtype=np.int32)


def tree_decomposition_order(g: Graph) -> np.ndarray:
    """Vertex hierarchy via MDE tree decomposition: reverse elimination order
    (the hierarchy root — eliminated last — gets the top rank)."""
    seq = mde_elimination(g)
    return seq[::-1].copy()


def hybrid_order(g: Graph, degree_threshold: int | None = None) -> np.ndarray:
    """Paper's hybrid order: high-degree *core* ranked by degree (cheap,
    effective on scale-free cores), low-degree *periphery* ranked by tree
    decomposition (effective on road-like fringes)."""
    deg = g.degree()
    if degree_threshold is None:
        # default: core = vertices above 4x average degree
        degree_threshold = max(int(4 * deg.mean()), int(np.percentile(deg, 95)))
    core = deg > degree_threshold
    core_ids = np.flatnonzero(core)
    core_sorted = core_ids[np.lexsort((core_ids, -deg[core_ids]))]
    periph_seq = mde_elimination(g, eliminate=~core)
    order = np.concatenate([core_sorted, periph_seq[::-1]]).astype(np.int32)
    assert len(order) == g.num_nodes
    return order


ORDERINGS = {
    "degree": degree_order,
    "treedec": tree_decomposition_order,
    "hybrid": hybrid_order,
}


def make_order(g: Graph, name: str = "degree") -> np.ndarray:
    return ORDERINGS[name](g)

"""GPipe-style pipeline parallelism over the "pod" mesh axis via shard_map +
collective-permute.

Each pod holds one contiguous block of layers (one *stage*); microbatches
stream through the stages with the classic (M + S - 1)-tick schedule. The
collective_permute boundary transfer is the only cross-pod traffic — the
point of running PP across pods, where ICI is replaced by slower DCN links.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def gpipe_forward(mesh, stage_weights, microbatches, n_microbatches=None,
                  stage_fn=None, axis="pod"):
    """Run microbatches through a pipeline of stages.

    stage_weights: [S, ...] — stage s's weights at index s (sharded over
      `axis`). Default stage_fn: x -> tanh(x @ w).
    microbatches: [M, b, d] — M microbatches.
    Returns [M, b, d] outputs (replicated)."""
    S = mesh.shape[axis]
    M = microbatches.shape[0]
    if stage_fn is None:
        stage_fn = lambda w, x: jnp.tanh(x @ w)

    def per_stage(w, xs):
        w = w[0]                                   # local stage weights
        stage = jax.lax.axis_index(axis)
        T = M + S - 1
        recv = jnp.zeros_like(xs[0])
        outputs = jnp.zeros_like(xs)

        def tick(t, carry):
            outputs, recv = carry
            inp = jnp.where(stage == 0, xs[jnp.clip(t, 0, M - 1)], recv)
            out = stage_fn(w, inp)
            nxt = jax.lax.ppermute(out, axis,
                                   [(i, (i + 1) % S) for i in range(S)])
            idx = t - (S - 1)
            write = (stage == S - 1) & (idx >= 0)
            updated = jax.lax.dynamic_update_index_in_dim(
                outputs, out, jnp.clip(idx, 0, M - 1), 0)
            outputs = jnp.where(write, updated, outputs)
            return outputs, nxt

        outputs, _ = jax.lax.fori_loop(0, T, tick, (outputs, recv))
        # only the last stage holds real outputs; replicate them
        outputs = jax.lax.psum(
            outputs * (stage == S - 1).astype(outputs.dtype), axis)
        return outputs

    w_spec = P(axis) if stage_weights.ndim == 1 else \
        P(*((axis,) + (None,) * (stage_weights.ndim - 1)))
    x_spec = P(*((None,) * microbatches.ndim))
    fn = jax.shard_map(per_stage, mesh=mesh,
                       in_specs=(w_spec, x_spec),
                   out_specs=x_spec, check_vma=False)
    return fn(stage_weights, microbatches)


def pipeline_bubble_fraction(n_microbatches: int, n_stages: int) -> float:
    """GPipe bubble overhead: (S-1) / (M + S - 1)."""
    return (n_stages - 1) / (n_microbatches + n_stages - 1)

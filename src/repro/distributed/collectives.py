"""Collective-schedule helpers for the multi-pod mesh.

hierarchical_psum: two-phase reduction (pod-local psum, then cross-pod) —
on a real fabric the second phase crosses DCN, so phasing keeps the slow
hop payload at 1/pod_size of a flat all-reduce over the combined axis.

axis_linear_index / row_gather_psum: the row-gather collective behind the
vertex-sharded label store in `core.query.ShardedQueryEngine` — each shard
owns a contiguous block of rows, contributes its owned rows (zeros
elsewhere) and one psum assembles the gathered result, so per query only
the touched label rows cross the interconnect instead of the whole store.

distributed_lse_decode: decode attention against a KV cache sharded along
the *sequence* axis without gathering it: each shard computes local
(max, sum, weighted-V) statistics and merges them with two tiny psums —
the log-sum-exp trick. Used by the §Perf decode hillclimb.
"""
from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp


def hierarchical_psum(x, pod_axis: str, inner_axis: str):
    """psum over (pod_axis x inner_axis) phased: inner first, then pods."""
    x = jax.lax.psum(x, inner_axis)
    return jax.lax.psum(x, pod_axis)


def axis_linear_index(axes):
    """Linear device index over one or more mesh axes, row-major in the
    given order (works on every jax that has axis_index for a single
    name, unlike the tuple form)."""
    if isinstance(axes, str):
        return jax.lax.axis_index(axes)
    idx = jax.lax.axis_index(axes[0])
    for ax in axes[1:]:
        idx = idx * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
    return idx


def batch_slice(x, axes, n_local: int):
    """This shard's contiguous slice of a REPLICATED batch-axis array,
    under the same row-major linear device order the row-gather collectives
    use: shard k owns ``x[k * n_local : (k + 1) * n_local]``. The serving
    engines' sharded-labels locals pair it with `row_gather_psum_scatter`
    (whose reduce-scatter delivers exactly that slice of the gathered
    rows), so the per-shard query levels and the gathered label rows line
    up by construction."""
    return jax.lax.dynamic_slice_in_dim(
        x, axis_linear_index(axes) * n_local, n_local)


def _owned_contribution(shard, rows, axes, rows_per_shard: int):
    """This shard's contribution to gathering global ``rows`` from an array
    block-row-sharded over ``axes``: its owned rows, zeros elsewhere.
    ``rows`` MUST be replicated — every shard scores the same row list, so
    summing contributions over shards IS the gather (each row has exactly
    one owner; out-of-range ids are nobody's and come back all-zero)."""
    start = axis_linear_index(axes) * rows_per_shard
    local = rows - start
    owned = (local >= 0) & (local < rows_per_shard)
    picked = shard[jnp.clip(local, 0, rows_per_shard - 1)]
    owned = owned.reshape(owned.shape + (1,) * (picked.ndim - owned.ndim))
    return jnp.where(owned, picked, 0)


def row_gather_psum(shard, rows, axes, rows_per_shard: int):
    """Gather global rows from a block-row-sharded array inside shard_map.

    shard: the local [rows_per_shard, ...] block of an array whose leading
    axis is sharded over ``axes`` in contiguous blocks (shard k owns rows
    ``[k * rows_per_shard, (k + 1) * rows_per_shard)`` under the row-major
    linear device order). rows: [B] int32 *global* row ids, replicated
    (see `_owned_contribution` — sharded row ids would sum unrelated
    queries). Returns the gathered [B, ...] rows replicated on every
    shard, exact for any dtype psum supports.
    """
    return jax.lax.psum(_owned_contribution(shard, rows, axes,
                                            rows_per_shard), axes)


def row_gather_psum_scatter(shard, rows, axes, rows_per_shard: int):
    """`row_gather_psum` fused with a batch split: contributions are
    combined with one reduce-scatter over the leading (row-id) dim, so the
    calling shard receives only its ``B / n_shards`` slice of the gathered
    rows — the natural form when the query batch is itself sharded over
    the same devices, at 1/n_shards the interconnect payload of the
    all-reduce gather. ``rows`` must be replicated and its length divisible
    by the total size of ``axes``."""
    contrib = _owned_contribution(shard, rows, axes, rows_per_shard)
    return jax.lax.psum_scatter(contrib, axes, scatter_dimension=0,
                                tiled=True)


def multi_row_gather_psum_scatter(shards, rows, axes, rows_per_shard: int):
    """`row_gather_psum_scatter` over several same-sharded arrays with ONE
    collective: per-array contributions are concatenated on the trailing
    axis, reduce-scattered together, and split back out — one launch and
    one fabric transfer instead of ``len(shards)`` (the hub/dist/wlev
    triplet of a label row always travels together, so the profile query
    path pays the collective latency once per side). Every array must be
    2-D `[rows_per_shard, *]` (same dtype; pass 1-D data as a ``[V, 1]``
    column) and ``rows`` replicated, as for the single-array form."""
    contribs = [_owned_contribution(sh, rows, axes, rows_per_shard)
                for sh in shards]
    widths = [c.shape[-1] for c in contribs]
    out = jax.lax.psum_scatter(jnp.concatenate(contribs, axis=-1), axes,
                               scatter_dimension=0, tiled=True)
    bounds = list(itertools.accumulate(widths[:-1]))
    return tuple(jnp.split(out, bounds, axis=-1)) if bounds else (out,)


def ragged_tile_gather(shards, rows, axes, rows_per_shard: int):
    """Worklist tile gather behind the ROW-SHARDED ragged dispatch: fetch
    the arena tiles named by a replicated per-device worklist out of
    tile-row-sharded arrays, delivering each device exactly ITS slice.

    ``rows`` is the concatenation of every device's tile worklist in
    linear device order (length = n_shards * per_device_worklist), so the
    reduce-scatter's natural batch split hands device k precisely the
    tiles its own ragged launch will walk — the whole flush needs ONE
    collective (`multi_row_gather_psum_scatter`) per dtype width.

    Unlike the int32-only fused gather, the compressed arena mixes int16
    hub deltas, bf16/fp16 distances, and int8 levels. Same-width arrays
    are grouped per collective — floats travel bitcast to the matching
    int type (a psum whose addends are one real contribution plus zeros
    is exact for any bit pattern, but bitcasting keeps float special
    values out of the reduction entirely). The uncompressed int32 triple
    stays a single collective."""
    out = [None] * len(shards)
    groups: dict = {}
    for i, sh in enumerate(shards):
        if sh.dtype in (jnp.bfloat16, jnp.float16):
            view = jax.lax.bitcast_convert_type(sh, jnp.int16)
        else:
            view = sh
        groups.setdefault(jnp.dtype(view.dtype), []).append((i, view))
    for members in groups.values():
        got = multi_row_gather_psum_scatter(
            tuple(v for _, v in members), rows, axes, rows_per_shard)
        for (i, _), g in zip(members, got):
            if shards[i].dtype in (jnp.bfloat16, jnp.float16):
                g = jax.lax.bitcast_convert_type(g, shards[i].dtype)
            out[i] = g
    return tuple(out)


def distributed_lse_decode(q, k_shard, v_shard, axis: str,
                           kv_valid_mask=None):
    """q: [B, Hkv, G, Dh]; k_shard/v_shard: [B, Skv_local, Hkv, Dh] (the
    local sequence shard). Returns [B, Hkv, G, Dh] attention output,
    mathematically identical to softmax over the full (gathered) KV.
    Traffic: 2 scalars-per-(B,H,G) psums + one [B,H,G,Dh] psum instead of an
    all-gather of the KV shard."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhgd,bshd->bhgs", (q * scale).astype(jnp.float32),
                        k_shard.astype(jnp.float32))
    if kv_valid_mask is not None:                  # [B, S_local]
        logits = jnp.where(kv_valid_mask[:, None, None, :], logits, -1e30)
    m_loc = logits.max(axis=-1)                                # [B, H, G]
    m = jax.lax.pmax(m_loc, axis)
    p = jnp.exp(logits - m[..., None])
    denom = jax.lax.psum(p.sum(-1), axis)                      # [B, H, G]
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_shard.astype(jnp.float32))
    out = jax.lax.psum(out, axis)
    return (out / denom[..., None]).astype(q.dtype)

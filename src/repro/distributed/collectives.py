"""Collective-schedule helpers for the multi-pod mesh.

hierarchical_psum: two-phase reduction (pod-local psum, then cross-pod) —
on a real fabric the second phase crosses DCN, so phasing keeps the slow
hop payload at 1/pod_size of a flat all-reduce over the combined axis.

distributed_lse_decode: decode attention against a KV cache sharded along
the *sequence* axis without gathering it: each shard computes local
(max, sum, weighted-V) statistics and merges them with two tiny psums —
the log-sum-exp trick. Used by the §Perf decode hillclimb.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hierarchical_psum(x, pod_axis: str, inner_axis: str):
    """psum over (pod_axis x inner_axis) phased: inner first, then pods."""
    x = jax.lax.psum(x, inner_axis)
    return jax.lax.psum(x, pod_axis)


def distributed_lse_decode(q, k_shard, v_shard, axis: str,
                           kv_valid_mask=None):
    """q: [B, Hkv, G, Dh]; k_shard/v_shard: [B, Skv_local, Hkv, Dh] (the
    local sequence shard). Returns [B, Hkv, G, Dh] attention output,
    mathematically identical to softmax over the full (gathered) KV.
    Traffic: 2 scalars-per-(B,H,G) psums + one [B,H,G,Dh] psum instead of an
    all-gather of the KV shard."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhgd,bshd->bhgs", (q * scale).astype(jnp.float32),
                        k_shard.astype(jnp.float32))
    if kv_valid_mask is not None:                  # [B, S_local]
        logits = jnp.where(kv_valid_mask[:, None, None, :], logits, -1e30)
    m_loc = logits.max(axis=-1)                                # [B, H, G]
    m = jax.lax.pmax(m_loc, axis)
    p = jnp.exp(logits - m[..., None])
    denom = jax.lax.psum(p.sum(-1), axis)                      # [B, H, G]
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_shard.astype(jnp.float32))
    out = jax.lax.psum(out, axis)
    return (out / denom[..., None]).astype(q.dtype)

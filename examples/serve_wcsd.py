"""Serving driver: batched WCSD query serving with request batching, memo
cache and the device query engine (the paper's 10k-query experiment as a
service)."""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import WCSDServer, build_wc_index
from repro.core.generators import random_queries, scale_free
from repro.core.ref import wcsd_bfs


def main():
    g = scale_free(2000, 4, num_levels=5, seed=0)
    idx = build_wc_index(g)
    s, t, wl = random_queries(g, 10_000, seed=1)

    # layout="padded": one [V, cap] store; layout="csr": the CSR-packed
    # store served by the ragged megakernel — one kernel launch per flush
    # over the lane-tiled arena (see docs/query-engine.md).
    # backend="sharded" runs the same queries over every attached device
    # (labels replicated, batch sharded; see docs/serving.md) — start with
    # XLA_FLAGS=--xla_force_host_platform_device_count=8 to see it scale.
    out = None
    for tag, kwargs in [("padded", dict(layout="padded")),
                        ("csr", dict(layout="csr")),
                        ("sharded", dict(layout="csr", backend="sharded"))]:
        srv = WCSDServer(idx, max_batch=512, **kwargs)
        srv.query_many(s[:64], t[:64], wl[:64])  # warm compile
        t0 = time.perf_counter()
        got = srv.query_many(s, t, wl)
        dt = time.perf_counter() - t0
        print(f"[{tag:7s}] 10,000 queries in {dt:.2f}s -> "
              f"{len(s)/dt:,.0f} qps ({dt/len(s)*1e6:.0f} us/query), "
              f"batches: {srv.stats.batches}, "
              f"memo hits: {srv.stats.memo_hits}")
        assert out is None or np.array_equal(out, got)
        out = got

    # spot check vs oracle
    for i in range(0, 200, 37):
        assert out[i] == wcsd_bfs(g, int(s[i]), int(t[i]), int(wl[i]))
    print("spot checks vs BFS oracle pass")

    # profile (staircase) queries: every constraint level of a pair in ONE
    # label sweep — the constraint-exploration workload that would
    # otherwise cost num_levels+1 independent queries per pair (see
    # docs/profile-queries.md)
    srv = WCSDServer(idx, max_batch=512, layout="csr")
    n_prof = 2_000
    t0 = time.perf_counter()
    profs = srv.query_profile_many(s[:n_prof], t[:n_prof])
    dt = time.perf_counter() - t0
    levels = profs.shape[1]
    print(f"[profile] {n_prof:,} staircases x {levels} levels in {dt:.2f}s "
          f"-> {n_prof * levels / dt:,.0f} level-answers/s")
    # a cached profile answers any single level without device work
    batches = srv.stats.batches
    for w in range(levels):
        rid = srv.submit(int(s[0]), int(t[0]), w)
        assert srv.result(rid) == profs[0, w]
    assert srv.stats.batches == batches, "memo should have served these"
    print(f"[profile] single-level queries served from the cached "
          f"staircase ({srv.stats.memo_hits} memo hits, 0 extra batches)")
    # staircases are monotone: relaxing the constraint never lengthens
    assert np.all(profs[:, :-1] <= profs[:, 1:])
    for i in range(0, n_prof, 251):   # spot check vs the scalar epoch path
        for w in range(levels - 1):
            assert profs[i, w] == wcsd_bfs(g, int(s[i]), int(t[i]), w)
    print("profile spot checks vs BFS oracle pass")


if __name__ == "__main__":
    main()

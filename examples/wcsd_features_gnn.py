"""The paper's technique as a first-class feature pipeline: WC-INDEX
quality-constrained distance encodings feed a GIN node classifier.

Labels are constructed to depend on quality-constrained proximity to two
"hub" vertices, so the WC-INDEX features carry real signal: the model with
distance encodings should beat the bare-feature model."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_wc_index
from repro.core.generators import scale_free
from repro.data.graphs import distance_encoding
from repro.models import gnn
from repro.train import optim as O
from repro.train.loop import make_train_step


def main():
    g = scale_free(600, 3, num_levels=4, seed=0)
    idx = build_wc_index(g)
    rng = np.random.default_rng(0)

    # labels: is the vertex within quality-2 distance 3 of either hub?
    hubs = np.array([0, 1])
    d = distance_encoding(idx, np.arange(g.num_nodes), hubs, w_levels=[2])
    labels = (d.min(axis=1) <= 3).astype(np.int32)
    print(f"label balance: {labels.mean():.2f}")

    base_feat = rng.standard_normal((g.num_nodes, 8)).astype(np.float32)
    enc = distance_encoding(idx, np.arange(g.num_nodes), hubs,
                            w_levels=[0, 2])
    enc = (enc - enc.mean(0)) / (enc.std(0) + 1e-6)  # standardize

    def run(feat, name):
        cfg = gnn.GNNConfig(name, "gin", n_layers=3, d_hidden=32,
                            d_feat=feat.shape[1], n_classes=2)
        params = gnn.init_params(cfg, jax.random.key(1))
        ocfg = O.OptimizerConfig(lr=2e-3, warmup_steps=10, total_steps=150,
                                 weight_decay=0.0)
        opt = O.init_opt_state(ocfg, params)
        batch = {"feat": jnp.asarray(feat),
                 "edges_src": jnp.asarray(g.edges_src),
                 "edges_dst": jnp.asarray(g.edges_dst),
                 "labels": jnp.asarray(labels)}
        step = jax.jit(make_train_step(
            lambda p, b: gnn.loss_fn(p, cfg, b), ocfg))
        for _ in range(150):
            params, opt, m = step(params, opt, batch)
        logits = gnn.forward(params, cfg, batch)
        acc = float((jnp.argmax(logits, -1) == batch["labels"]).mean())
        print(f"{name:28s} final loss {float(m['loss']):.3f} acc {acc:.3f}")
        return acc

    acc_base = run(base_feat, "bare features")
    acc_wcsd = run(np.concatenate([base_feat, enc], 1),
                   "+ WC-INDEX distance encodings")
    assert acc_wcsd > acc_base
    print("WC-INDEX features improve the GNN — the paper's index as a "
          "data-pipeline stage.")


if __name__ == "__main__":
    main()

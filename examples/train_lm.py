"""End-to-end training driver: a ~100M-param llama-style LM on the synthetic
token pipeline, with AdamW + warmup-cosine, gradient accumulation,
checkpointing and the fault-tolerant runner (a failure is injected to
demonstrate restart)."""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import CheckpointManager
from repro.checkpoint.fault import FaultTolerantRunner
from repro.data.lm import TokenStream
from repro.models import transformer as T
from repro.train import optim as O
from repro.train.loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = T.LMConfig(
        name="lm-100m", n_layers=args.layers, d_model=args.d_model,
        n_heads=8, n_kv_heads=4, d_ff=4 * args.d_model, vocab=32000,
        d_head=args.d_model // 8, tp_size=1)
    print(f"model: {cfg.param_count()/1e6:.1f}M params")

    params = T.init_params(cfg, jax.random.key(0))
    ocfg = O.OptimizerConfig(lr=3e-4, warmup_steps=20,
                             total_steps=args.steps)
    opt = O.init_opt_state(ocfg, params)
    step = jax.jit(make_train_step(lambda p, b: T.loss_fn(p, cfg, b), ocfg))

    stream = TokenStream(cfg.vocab, args.seq, args.batch, seed=0)

    def batch_for_step(s):
        stream.set_cursor(s)
        b = stream.next_batch()
        return {k: jnp.asarray(v) for k, v in b.items()}

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="lm_ckpt_")
    runner = FaultTolerantRunner(
        step, params, opt, CheckpointManager(ckpt_dir), ckpt_every=25,
        failure_schedule={args.steps // 2: RuntimeError("injected failure")})
    log = runner.run(None, max_steps=args.steps,
                     batch_for_step=batch_for_step)

    steps = [l for l in log if l["event"] == "step"]
    fails = [l for l in log if l["event"] == "failure"]
    print(f"ran {len(steps)} steps ({len(fails)} failure(s) survived, "
          f"{runner.restarts} restart(s))")
    print(f"loss: {steps[0]['loss']:.3f} -> {steps[-1]['loss']:.3f}")
    print(f"mean step time {sum(s['time_s'] for s in steps)/len(steps):.3f}s"
          f"; checkpoints in {ckpt_dir}")
    assert steps[-1]["loss"] < steps[0]["loss"]


if __name__ == "__main__":
    main()

"""Quickstart: the paper end-to-end on a synthetic road network.

Builds a WC-INDEX, checks it against the constrained-BFS oracle, compares
baselines, and answers batched queries on device."""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (DeviceQueryEngine, build_wc_index,
                        build_wc_index_batched, clean_index)
from repro.core.baselines import NaiveIndex, cbfs_query
from repro.core.generators import random_queries, road_grid
from repro.core.ref import wcsd_bfs


def main():
    g = road_grid(30, 30, num_levels=5, seed=0)
    print(f"graph: {g.num_nodes} nodes, {g.num_edges} edges, "
          f"|w|={g.num_levels} quality levels {g.levels}")

    t0 = time.time()
    idx = build_wc_index(g, ordering="hybrid")
    print(f"WC-INDEX built in {time.time()-t0:.2f}s: "
          f"{idx.size_entries()} entries ({idx.memory_bytes()/1e6:.2f} MB)")

    naive = NaiveIndex.build(g)
    print(f"naive per-w index: {naive.size_entries()} entries "
          f"({naive.memory_bytes()/1e6:.2f} MB) — "
          f"{naive.memory_bytes()/idx.memory_bytes():.1f}x larger")

    s, t, wl = random_queries(g, 500, seed=1)
    exp = np.array([wcsd_bfs(g, int(a), int(b), int(w))
                    for a, b, w in zip(s, t, wl)])
    assert np.array_equal(idx.query_batch(s, t, wl), exp)
    print("500 random queries match the constrained-BFS oracle")

    q = (int(s[0]), int(t[0]), int(wl[0]))
    print(f"example: dist_w{q[2]}({q[0]}, {q[1]}) = {idx.query_one(*q)} "
          f"(online BFS agrees: {cbfs_query(g, *q)})")

    # device-batched querying (the TPU serving hot path; Pallas kernel
    # in interpret mode on CPU)
    eng = DeviceQueryEngine(idx, use_pallas=True)
    out = np.asarray(eng.query(s, t, wl))
    assert np.array_equal(out, exp)
    print("device (Pallas interpret) batch agrees")

    # beyond-paper: rank-batched construction + cleaning
    bat, stats = build_wc_index_batched(g, ordering="hybrid", batch_size=64)
    cleaned, removed = clean_index(bat)
    print(f"rank-batched build: {stats['rounds']} synchronized rounds vs "
          f"{g.num_nodes} sequential; cleaning removed {removed} entries -> "
          f"{cleaned.size_entries()} (sequential-minimal: "
          f"{idx.size_entries()})")


if __name__ == "__main__":
    main()

"""Paper-table benchmarks for WCSD (Figs. 5-12, laptop-scale graphs).

One function per figure family; each prints CSV rows
``table,dataset,algo,metric,value`` and returns them as dicts. Graphs are
synthetic analogues of the paper's datasets (road grids / scale-free BA),
sized for CPU CI; the trends under test are the paper's claims, not the
absolute numbers.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.baselines import (LCRAdapt, NaiveIndex, WBFS, cbfs_query,
                                  dijkstra_query)
from repro.core.generators import random_queries, road_grid, scale_free
from repro.core.query import DeviceQueryEngine
from repro.core.serve import WCSDServer
from repro.core.wc_index import build_wc_index
from repro.core.wc_index_batched import build_wc_index_batched, clean_index

ROAD = {
    "NY(s)": dict(rows=28, cols=28, levels=5),
    "FLA(s)": dict(rows=45, cols=45, levels=5),
    "CAL(s)": dict(rows=60, cols=60, levels=5),
}
SOCIAL = {
    "MV(s)": dict(n=1500, m=4, levels=5),
    "EU(s)": dict(n=3000, m=5, levels=3),
    "SO(s)": dict(n=5000, m=4, levels=9),
}


def _road(name):
    c = ROAD[name]
    return road_grid(c["rows"], c["cols"], num_levels=c["levels"], seed=42)


def _social(name):
    c = SOCIAL[name]
    return scale_free(c["n"], c["m"], num_levels=c["levels"], seed=42)


def _time(fn, *a, repeat=1, **k):
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*a, **k)
    return (time.perf_counter() - t0) / repeat, out


def bench_indexing(datasets=None, order="auto"):
    """Fig. 5/6 analogue: indexing time + size for Naive / WC-INDEX /
    WC-INDEX+ (= query-efficient + hybrid order) / batched builder."""
    rows = []
    datasets = datasets or {**{k: ("road", k) for k in ROAD},
                            **{k: ("social", k) for k in SOCIAL}}
    for name, (fam, key) in datasets.items():
        g = _road(key) if fam == "road" else _social(key)
        o_basic = "treedec" if fam == "road" else "degree"
        t_naive, naive = _time(NaiveIndex.build, g)
        t_wc, wc = _time(build_wc_index, g, ordering=o_basic, prune=False)
        t_wcp, wcp = _time(build_wc_index, g, ordering="hybrid")
        t_bat, (bat, stats) = _time(build_wc_index_batched, g,
                                    ordering="hybrid", batch_size=32)
        rows += [
            dict(table="fig5_idx_time", dataset=name, algo="naive",
                 value=t_naive),
            dict(table="fig5_idx_time", dataset=name, algo="wc-index",
                 value=t_wc),
            dict(table="fig5_idx_time", dataset=name, algo="wc-index+",
                 value=t_wcp),
            dict(table="fig5_idx_time", dataset=name, algo="wc-batched",
                 value=t_bat),
            dict(table="fig6_idx_size", dataset=name, algo="naive",
                 value=naive.memory_bytes()),
            dict(table="fig6_idx_size", dataset=name, algo="wc-index",
                 value=wc.memory_bytes()),
            dict(table="fig6_idx_size", dataset=name, algo="wc-index+",
                 value=wcp.memory_bytes()),
            dict(table="fig6_idx_size", dataset=name, algo="wc-batched",
                 value=bat.memory_bytes()),
            dict(table="fig6_idx_size", dataset=name, algo="graph",
                 value=g.memory_bytes()),
        ]
    return rows


def bench_query(datasets=None, n_queries=400):
    """Fig. 7/12 analogue: per-query latency for online baselines vs index."""
    rows = []
    datasets = datasets or {"CAL(s)": ("road", "CAL(s)"),
                            "EU(s)": ("social", "EU(s)")}
    for name, (fam, key) in datasets.items():
        g = _road(key) if fam == "road" else _social(key)
        s, t, wl = random_queries(g, n_queries, seed=3)
        idx = build_wc_index(g, ordering="hybrid")
        naive = NaiveIndex.build(g)
        wbfs = WBFS.build(g)
        lcr = LCRAdapt.build(g)
        nq = min(60, n_queries)

        t_cbfs, _ = _time(lambda: [cbfs_query(g, int(a), int(b), int(w))
                                   for a, b, w in zip(s[:nq], t[:nq],
                                                      wl[:nq])])
        t_wbfs, _ = _time(lambda: [wbfs.query(int(a), int(b), int(w))
                                   for a, b, w in zip(s[:nq], t[:nq],
                                                      wl[:nq])])
        t_dij, _ = _time(lambda: [dijkstra_query(g, int(a), int(b), int(w))
                                  for a, b, w in zip(s[:nq], t[:nq],
                                                     wl[:nq])])
        t_lcr, _ = _time(lambda: [lcr.query(int(a), int(b), int(w))
                                  for a, b, w in zip(s[:nq], t[:nq],
                                                     wl[:nq])])
        t_nv, _ = _time(lambda: [naive.query(int(a), int(b), int(w))
                                 for a, b, w in zip(s, t, wl)])
        t_wc, _ = _time(lambda: [idx.query_one(int(a), int(b), int(w))
                                 for a, b, w in zip(s, t, wl)])
        # WC-INDEX+ device-batched path (jnp); measured per query
        eng = DeviceQueryEngine(idx)
        eng.query(s[:8], t[:8], wl[:8])  # warmup compile
        t_dev, _ = _time(lambda: np.asarray(eng.query(s, t, wl)))
        for algo, tt, n in [("c-bfs", t_cbfs, nq), ("w-bfs", t_wbfs, nq),
                            ("dijkstra", t_dij, nq), ("lcr-adapt", t_lcr, nq),
                            ("naive", t_nv, n_queries),
                            ("wc-index", t_wc, n_queries),
                            ("wc-index+dev", t_dev, n_queries)]:
            rows.append(dict(table="fig7_query_time", dataset=name,
                             algo=algo, value=tt / n))
    return rows


def bench_large_w(n_levels=20):
    """Fig. 8/9 analogue: |w| = 20."""
    rows = []
    g = road_grid(40, 40, num_levels=n_levels, seed=7)
    t_naive, naive = _time(NaiveIndex.build, g)
    t_wcp, wcp = _time(build_wc_index, g, ordering="hybrid")
    rows += [
        dict(table="fig8_w20_time", dataset="ROAD40", algo="naive",
             value=t_naive),
        dict(table="fig8_w20_time", dataset="ROAD40", algo="wc-index+",
             value=t_wcp),
        dict(table="fig9_w20_size", dataset="ROAD40", algo="naive",
             value=naive.memory_bytes()),
        dict(table="fig9_w20_size", dataset="ROAD40", algo="wc-index+",
             value=wcp.memory_bytes()),
    ]
    return rows


def bench_batched_builder():
    """Beyond-paper: PSL-style rank-batched construction — host-sync rounds
    vs sequential roots, and the index-size/cleaning trade."""
    rows = []
    g = scale_free(2000, 4, num_levels=5, seed=11)
    t_seq, seq = _time(build_wc_index, g, ordering="degree")
    for B in [8, 32, 128]:
        t_bat, (bat, stats) = _time(build_wc_index_batched, g,
                                    ordering="degree", batch_size=B)
        t_clean, (cleaned, removed) = _time(clean_index, bat)
        rows += [
            dict(table="batched_builder", dataset=f"BA2000/B{B}",
                 algo="rounds", value=stats["rounds"]),
            dict(table="batched_builder", dataset=f"BA2000/B{B}",
                 algo="size_overhead",
                 value=bat.size_entries() / seq.size_entries()),
            dict(table="batched_builder", dataset=f"BA2000/B{B}",
                 algo="size_after_clean",
                 value=cleaned.size_entries() / seq.size_entries()),
            dict(table="batched_builder", dataset=f"BA2000/B{B}",
                 algo="build_time", value=t_bat),
        ]
    rows.append(dict(table="batched_builder", dataset="BA2000/seq",
                     algo="build_time", value=t_seq))
    rows.append(dict(table="batched_builder", dataset="BA2000/seq",
                     algo="rounds", value=g.num_nodes))
    return rows


def bench_label_store(dataset="SO(s)", n_queries=2048):
    """Padded vs CSR-packed label store on a skewed scale-free config:
    store bytes (padded [V, cap] vs flat CSR vs bucket tiles) and µs/query
    of the dense vs segmented device path."""
    rows = []
    g = _social(dataset)
    idx = build_wc_index(g, ordering="degree")
    packed = idx.packed()
    V, cap = idx.num_nodes, idx.label_capacity
    # what the dense pallas engine actually ships: width padded to 128
    from repro.core.wc_index import round_to_lane
    cap128 = round_to_lane(int(idx.count.max()))
    padded_bytes = V * cap128 * 12 + idx.count.nbytes
    rows += [
        dict(table="label_store", dataset=dataset, algo="entries",
             value=idx.size_entries()),
        dict(table="label_store", dataset=dataset, algo="max_label",
             value=int(idx.count.max())),
        dict(table="label_store", dataset=dataset, algo="padded_bytes",
             value=padded_bytes),
        dict(table="label_store", dataset=dataset, algo="csr_bytes",
             value=packed.memory_bytes()),
        dict(table="label_store", dataset=dataset, algo="csr_tile_bytes",
             value=packed.tile_memory_bytes()),
        dict(table="label_store", dataset=dataset, algo="bytes_ratio",
             value=padded_bytes / max(packed.memory_bytes(), 1)),
        dict(table="label_store", dataset=dataset, algo="num_buckets",
             value=packed.num_buckets),
    ]
    s, t, wl = random_queries(g, n_queries, seed=21)
    dense = DeviceQueryEngine(idx)
    seg = DeviceQueryEngine(idx, layout="csr")
    np.asarray(dense.query(s, t, wl))       # warmup compiles
    np.asarray(seg.query(s, t, wl))
    t_dense, _ = _time(lambda: np.asarray(dense.query(s, t, wl)), repeat=3)
    t_seg, _ = _time(lambda: np.asarray(seg.query(s, t, wl)), repeat=3)
    # compare volume: dense pays B * cap128^2, segmented pays the bucket
    # pair widths of each routed sub-batch
    from repro.core.query import plan_query_batch
    widths = packed.bucket_widths.astype(np.int64)
    seg_cmp = sum(len(p.positions) * int(widths[p.bucket_s] * widths[p.bucket_t])
                  for p in plan_query_batch(packed.bucket_of, s, t))
    rows += [
        dict(table="label_store", dataset=dataset, algo="dense_us_per_query",
             value=t_dense / n_queries * 1e6),
        dict(table="label_store", dataset=dataset, algo="seg_us_per_query",
             value=t_seg / n_queries * 1e6),
        dict(table="label_store", dataset=dataset, algo="dense_cmp_volume",
             value=float(n_queries) * cap128 * cap128),
        dict(table="label_store", dataset=dataset, algo="seg_cmp_volume",
             value=float(seg_cmp)),
    ]
    return rows


def bench_serving(batch=4096, n_nodes=3000):
    """Throughput of the serving engine: the single-device batched path vs
    the sharded engine (batch sharded over every attached device, labels
    replicated) — the µs/query comparison CI archives as BENCH_serving.json.
    Run under ``--xla_force_host_platform_device_count=N`` (benchmarks/
    run.py sets it for this suite) to exercise a real multi-device mesh;
    wall-clock on virtual CPU devices measures dispatch overhead, not TPU
    speedup, so the trend under test is correctness of the scaling path.

    Also here: the profile (staircase) workload — every constraint level
    of a pair in ONE label sweep (`query_profile`) vs the L-call
    per-level `query` loop it replaces. The two are asserted bit-identical
    before timing; the acceptance trend is profile_speedup >= 2 at
    L >= 4 levels."""
    import jax

    from repro.core.query import ShardedQueryEngine  # noqa: F401 (doc link)
    from repro.launch.mesh import make_serving_mesh

    rows = []
    name = f"BA{n_nodes}"
    g = scale_free(n_nodes, 4, num_levels=5, seed=13)
    idx = build_wc_index(g, ordering="degree")
    s, t, wl = random_queries(g, batch * 4, seed=5)

    def timed(srv):
        srv.query_many(s[:64], t[:64], wl[:64])  # warm
        t0 = time.perf_counter()
        out = srv.query_many(s, t, wl)
        return time.perf_counter() - t0, out

    dt_single, out_single = timed(WCSDServer(idx, max_batch=batch))
    n_dev = len(jax.devices())
    mesh = make_serving_mesh()
    dt_shard, out_shard = timed(WCSDServer(
        idx, max_batch=batch, backend="sharded", mesh=mesh, layout="padded"))
    assert np.array_equal(out_single, out_shard), \
        "sharded serving diverged from single-device"
    for algo, dt in [("qps", dt_single), ("qps_sharded", dt_shard)]:
        rows.append(dict(table="serving", dataset=name, algo=algo,
                         value=len(s) / dt))
    rows += [
        dict(table="serving", dataset=name, algo="us_per_query",
             value=dt_single / len(s) * 1e6),
        dict(table="serving", dataset=name, algo="us_per_query_sharded",
             value=dt_shard / len(s) * 1e6),
        dict(table="serving", dataset=name, algo="sharded_devices",
             value=n_dev),
        dict(table="serving", dataset=name, algo="sharded_speedup",
             value=dt_single / dt_shard),
    ]
    rows += _bench_continuous_batching(idx, s, t, wl, name,
                                       batch=min(batch, 1024))
    rows += _bench_profile_vs_loop(idx, s[:batch], t[:batch], name)
    rows += _bench_ragged_dispatch()
    rows += _bench_rowsharded_ragged()
    rows += _bench_dma_overlap()
    rows += _bench_dynamic_updates(g, idx, name, batch=min(batch, 1024))
    rows += _bench_resilience(g, idx, name, batch=min(batch, 1024))
    return rows


def _bench_continuous_batching(idx, s, t, wl, name, batch=1024):
    """Continuous-batching serving rows: per-request enqueue->deliver
    latency (p50/p99 µs) of a deadline-flush epoch — submissions trickle
    in one at a time with a `poll` tick between them, so flushes fire at
    min_batch/deadline instead of max_batch (docs/serving.md §1a). The
    p99 ceiling gated by run.py --check is a coarse SLO guard against
    pathological serialization (a flush that re-runs the backlog, a
    request parked forever), not a machine-speed gate — hence its slack."""
    srv = WCSDServer(idx, max_batch=256, max_wait_us=500.0, min_batch=16)
    # warm the compile cache by STREAMING (not bulk query_many): deadline
    # flushes compile the small padded shapes the measured epoch will
    # hit, not just the max_batch one
    warm = min(256, batch)
    wrids = []
    for a, b, c in zip(s[:warm], t[:warm], wl[:warm]):
        wrids.append(srv.submit(int(a), int(b), int(c)))
        srv.poll()
    srv.flush()
    for r in wrids:
        srv.result(r)
    srv.latencies_us.clear()
    lo, hi = warm, warm + batch
    rids = [None] * (hi - lo)
    for i, (a, b, c) in enumerate(zip(s[lo:hi], t[lo:hi], wl[lo:hi])):
        rids[i] = srv.submit(int(a), int(b), int(c))
        srv.poll()
    srv.flush()
    got = np.array([srv.result(r) for r in rids], dtype=np.int32)
    exp = np.asarray(DeviceQueryEngine(idx).query(s[lo:hi], t[lo:hi],
                                                  wl[lo:hi]))
    assert np.array_equal(got, exp), \
        "continuous-batching serving diverged from the device engine"
    lat = srv.latency_summary()
    assert lat["count"] >= len(rids)
    return [
        dict(table="serving", dataset=name, algo="serve_p50_us",
             value=lat["p50_us"]),
        dict(table="serving", dataset=name, algo="serve_p99_us",
             value=lat["p99_us"]),
        dict(table="serving", dataset=name, algo="serve_cb_batches",
             value=srv.stats.batches),
    ]


def _bench_dma_overlap(flush=96, lane=16):
    """The acceptance row of the quad-buffered DMA ring inside the ragged
    megakernel: wall-clock of the SAME worklist through the kernel with
    the production ring depth (``nbuf=4``) vs the single-buffer baseline
    (``nbuf=1``, every tile fetch serialized against the join). The two
    launches are asserted bit-identical first. On TPU the ratio measures
    real fetch/compute overlap; under interpret emulation the copies run
    synchronously either way, so the CI floor only guards the ring
    against ADDING overhead (ratio collapsing well under 1.0)."""
    import jax.numpy as jnp

    import repro.kernels.wcsd_query as wq
    from repro.core.query import emit_ragged_worklist, ragged_worklist_len

    pidx, heavy = make_skewed_store(V=256, W=4, lane=lane, buckets=6)
    ar = pidx.packed(lane=lane).arena(lane=lane)
    rng = np.random.default_rng(11)
    s = rng.integers(0, pidx.num_nodes, flush).astype(np.int32)
    t = rng.integers(0, pidx.num_nodes, flush).astype(np.int32)
    wl = rng.integers(0, pidx.num_levels + 1, flush).astype(np.int32)
    n_salt = min(16, flush // 4)
    s[:n_salt] = np.resize(heavy, n_salt)     # long rows -> deep worklists
    t[n_salt // 2:n_salt + n_salt // 2] = np.resize(heavy, n_salt)
    WLn = ragged_worklist_len(np.asarray(ar.tile_cnt), s, t)
    qidx, stile, ttile, first = emit_ragged_worklist(
        ar.tile_base, ar.tile_cnt, jnp.asarray(s), jnp.asarray(t),
        worklist_len=WLn)
    wq_lvl = jnp.concatenate([jnp.asarray(wl),
                              jnp.full((1,), 1 << 20, jnp.int32)])

    def run(nbuf):
        return np.asarray(wq.wcsd_query_ragged(
            ar.hub, ar.dist, ar.wlev, ar.tile_lo, ar.tile_hi,
            qidx, stile, ttile, first, wq_lvl, nbuf=nbuf))

    out4, out1 = run(4), run(1)               # warmup traces, both depths
    assert np.array_equal(out4, out1), \
        "quad-buffered ragged kernel diverged from the nbuf=1 baseline"
    # the gated metric is a RATIO of two wall-clocks: interleave the
    # trials and keep each side's best (same pattern as the dynamic
    # bench), so a load transient hits both sides
    t_multi = t_single = float("inf")
    for _ in range(3):
        t_multi = min(t_multi, _time(run, 4, repeat=2)[0])
        t_single = min(t_single, _time(run, 1, repeat=2)[0])
    name = f"SKEW{pidx.labels.num_buckets}"
    return [
        dict(table="serving", dataset=name, algo="dma_overlap_speedup",
             value=t_single / max(t_multi, 1e-12)),
        dict(table="serving", dataset=name, algo="dma_worklist_entries",
             value=int(qidx.shape[0])),
    ]


def _bench_dynamic_updates(g, idx, name, batch=1024):
    """Dynamic-index serving rows: the cost of folding a graph update into
    the delta label store (``update_apply_us``), of compacting the delta
    back into a fresh packed base (``compact_us``), and the ragged-query
    tax of serving through a NON-EMPTY delta-extended arena relative to
    the static store (``delta_query_overhead``). The overhead ratio is
    the gated acceptance trend (run.py --check ceiling 1.15x): the delta
    only redirects tile pointers inside the one ragged launch per flush,
    so a non-empty delta must not cost a second kernel launch or a
    disproportionately wider worklist."""
    from repro.core.wc_index import DynamicWCIndex

    s, t, wl = random_queries(g, batch, seed=29)

    dyn = DynamicWCIndex(idx, g)
    lv = float(g.levels[len(g.levels) // 2])
    u0, v0 = int(g.edges_src[0]), int(g.edges_dst[0])
    dt_upd, _ = _time(lambda: dyn.apply_updates(
        inserts=[(0, g.num_nodes // 2, lv)], deletes=[(u0, v0)]))
    assert not dyn.delta.is_empty(), \
        "dynamic bench update produced an empty delta; overhead row " \
        "would measure the static path twice"

    static_eng = DeviceQueryEngine(idx, layout="csr", dispatch="ragged")
    dyn_eng = DeviceQueryEngine(dyn, layout="csr", dispatch="ragged")
    np.asarray(static_eng.query(s, t, wl))      # warmup compiles
    np.asarray(dyn_eng.query(s, t, wl))         # retrace: new tile count
    # the gated metric is a RATIO of two wall-clocks: interleave the
    # trials and keep each side's best, so a load transient on a shared
    # CI runner hits both sides instead of skewing the quotient
    t_static = t_delta = float("inf")
    for _ in range(5):
        t_static = min(t_static, _time(
            lambda: np.asarray(static_eng.query(s, t, wl)), repeat=3)[0])
        t_delta = min(t_delta, _time(
            lambda: np.asarray(dyn_eng.query(s, t, wl)), repeat=3)[0])

    dt_cmp, _ = _time(lambda: dyn.compact(ordering="degree",
                                          use_kernel=False))
    return [
        dict(table="serving", dataset=name, algo="update_apply_us",
             value=dt_upd * 1e6),
        dict(table="serving", dataset=name, algo="compact_us",
             value=dt_cmp * 1e6),
        dict(table="serving", dataset=name, algo="delta_query_overhead",
             value=t_delta / max(t_static, 1e-12)),
    ]


def _bench_resilience(g, idx, name, batch=1024):
    """Resilience rows (docs/resilience.md §benchmarks): the wall-clock
    tax of serving one ladder rung DOWN from the primary engine
    (``degraded_mode_overhead`` — csr-ragged primary vs its bucket_pair
    fallback rung, distinct query sets per side so the memo cannot hide
    either engine), and the per-batch cost of the crash-safe update WAL
    (``wal_append_us`` — mean fsync'd append of a small update record).
    Both ceilings gated by run.py --check are coarse SLO guards: the
    overhead ratio catches a fallback rung that silently became
    catastrophically slower than its primary (the ladder would then trade
    an outage for an effective outage), the append ceiling catches a WAL
    that serializes update ingestion."""
    import tempfile

    from repro.checkpoint.ckpt import UpdateWAL
    from repro.core.generators import random_queries

    srv = WCSDServer(idx, layout="csr", dispatch="ragged", max_batch=batch)
    assert srv.mode == "primary"
    qsets = [random_queries(g, batch, seed=61 + i) for i in range(4)]
    for s, t, wl in qsets:                       # warm both rungs' compiles
        srv.query_many(s, t, wl)
    assert srv._demote() and srv.mode == "bucket_pair"
    for s, t, wl in qsets:
        srv.query_many(s, t, wl)
    srv.mode_index = 0
    srv.engine = srv._make_engine()
    # ratio of two wall-clocks: interleave the trials and keep each
    # side's best, same pattern as the other gated ratios; fresh query
    # sets per trial so neither side serves from the memo
    t_prim = t_deg = float("inf")
    for i, (s, t, wl) in enumerate(qsets[:2]):
        sd, td, wld = qsets[2 + i]
        t_prim = min(t_prim, _time(lambda: srv.query_many(s, t, wl))[0])
        assert srv._demote()
        t_deg = min(t_deg, _time(lambda: srv.query_many(sd, td, wld))[0])
        srv.mode_index = 0
        srv.engine = srv._make_engine()
        srv.memo.clear()
        srv.stats.memo_hits = 0
    rows = [dict(table="serving", dataset=name, algo="degraded_mode_overhead",
                 value=t_deg / max(t_prim, 1e-12))]
    with tempfile.TemporaryDirectory() as tmp:
        wal = UpdateWAL(f"{tmp}/bench_wal.log", base_version=0)
        lv = float(g.levels[0])
        n_app = 32
        t0 = time.perf_counter()
        for i in range(n_app):
            wal.append(inserts=[(i, i + 1, lv)], deletes=[(i + 2, i + 3)],
                       graph_version=i + 1)
        dt = time.perf_counter() - t0
        assert len(wal.records()) == n_app
    rows.append(dict(table="serving", dataset=name, algo="wal_append_us",
                     value=dt / n_app * 1e6))
    return rows


def make_skewed_store(V=2048, W=6, lane=32, buckets=8, seed=17, rng=None):
    """A synthetic CSR label store whose row lengths span exactly
    ``buckets`` geometric length buckets (widths lane * 2^b): mostly
    short rows plus one hub-heavy row per wider bucket — the adversarial
    scale-free shape for which the bucket-pair dispatch loop degenerates
    toward buckets^2 kernel launches per flush while the ragged path
    stays at ONE. Synthetic on purpose: the dispatch tax depends only on
    the length distribution, and building a real index with multi-
    thousand-entry rows is not CI material. Rows keep the hub-sorted
    invariant (I1) the arena's tile early-out relies on.

    Shared with tests/test_ragged.py (the adversarial-skew differential
    block drives it with hypothesis-drawn rngs), so the bench and the
    correctness harness cannot drift apart in what "adversarial skew"
    means. Returns (PackedWCIndex, heavy_vertex_ids)."""
    from repro.core.wc_index import PackedLabels, PackedWCIndex

    rng = np.random.default_rng(seed) if rng is None else rng
    lens = rng.integers(1, lane + 1, size=V)
    heavy = rng.choice(V, size=buckets - 1, replace=False)
    for i, v in enumerate(heavy):
        w = lane << (i + 1)                   # one row per wider bucket
        lens[v] = rng.integers(w // 2 + 1, w + 1)
    hub_space = int(lens.max()) * 4
    hub = np.concatenate(
        [np.sort(rng.choice(hub_space, size=k, replace=False))
         for k in lens]).astype(np.int32)
    offsets = np.zeros(V + 1, dtype=np.int64)
    np.cumsum(lens, out=offsets[1:])
    dist = rng.integers(0, 1000, size=len(hub)).astype(np.int32)
    wlev = rng.integers(0, W + 1, size=len(hub)).astype(np.int32)
    store = PackedLabels.from_flat(hub, dist, wlev, offsets, lane=lane)
    assert store.num_buckets == buckets
    ar = np.arange(V, dtype=np.int32)
    pidx = PackedWCIndex(order=ar, rank=ar.copy(),
                         levels=np.arange(W, dtype=np.float64), labels=store)
    return pidx, heavy


def _bench_ragged_dispatch(flush=2048, lane=32):
    """The acceptance row of the single-launch megakernel: ragged vs
    bucket-pair µs/query on a skewed store spanning >= 8 length buckets,
    at the server's default flush size. Both engines run the XLA paths
    and are asserted bit-identical before timing.

    The quantity under test is the DISPATCH tax — one launch + one fused
    H2D + a device-emitted plan, vs one launch per populated bucket pair,
    a host argsort/unique, and per-sub-batch staging — which is exactly
    what the ragged path removes. ``lane=32`` keeps the O(lane^2) label-
    scan compute (bit-identical work on BOTH paths) from hiding that tax
    under CPU XLA wall-clock; on TPU the same comparison runs at the
    production lane of 128 with the launch overhead in play instead."""
    from repro.core.query import DeviceQueryEngine

    pidx, heavy = make_skewed_store(lane=lane)
    rng = np.random.default_rng(5)
    s = rng.integers(0, pidx.num_nodes, flush).astype(np.int32)
    t = rng.integers(0, pidx.num_nodes, flush).astype(np.int32)
    wl = rng.integers(0, pidx.num_levels + 1, flush).astype(np.int32)
    # salt with hub-heavy endpoints (the celebrity-node pattern) on BOTH
    # sides so the flush populates short x short, short x heavy and
    # heavy x heavy pairs — ~20+ bucket-pair launches per flush
    n_salt = min(64, flush // 4)
    s[:n_salt] = np.resize(heavy, n_salt)
    t[n_salt // 2:n_salt + n_salt // 2] = np.resize(heavy, n_salt)
    packed = pidx.labels
    ragged = DeviceQueryEngine(pidx, layout="csr", lane=lane)
    bp = DeviceQueryEngine(pidx, layout="csr", lane=lane,
                           dispatch="bucket_pair")
    out_r = np.asarray(ragged.query(s, t, wl))              # warmup compiles
    out_b = np.asarray(bp.query(s, t, wl))
    assert np.array_equal(out_r, out_b), \
        "ragged dispatch diverged from the bucket-pair oracle"
    t_rag, _ = _time(lambda: np.asarray(ragged.query(s, t, wl)), repeat=5)
    t_bp, _ = _time(lambda: np.asarray(bp.query(s, t, wl)), repeat=5)
    name = f"SKEW{packed.num_buckets}"
    return [
        dict(table="serving", dataset=name, algo="ragged_buckets",
             value=packed.num_buckets),
        dict(table="serving", dataset=name, algo="ragged_us_per_query",
             value=t_rag / len(s) * 1e6),
        dict(table="serving", dataset=name, algo="bucket_pair_us_per_query",
             value=t_bp / len(s) * 1e6),
        dict(table="serving", dataset=name, algo="ragged_speedup",
             value=t_bp / t_rag),
    ]


def _bench_rowsharded_ragged(flush=2048, lane=32):
    """The acceptance row of the ROW-SHARDED ragged path: ragged vs
    bucket-pair µs/query with the label store tile-row-sharded over the
    mesh (``device_budget_bytes=1`` forces mode="sharded_labels"), on the
    same adversarial skewed store as `_bench_ragged_dispatch`. Both
    engines are asserted bit-identical before timing.

    What the ragged path removes here is the PER-BUCKET-PAIR collective
    loop: the bucket-pair engine pays one staged sub-batch plus its row
    gathers for every populated (bucket_s, bucket_t) pair of the flush,
    while the ragged path runs ONE worklist tile gather plus one launch
    per device regardless of the bucket mix. Also rides along:
    ``compressed_bytes_ratio``, the uncompressed/compressed arena bytes
    on this store (the capacity multiplier a fixed HBM budget gains from
    `CompressedArena`)."""
    from repro.core.query import ShardedQueryEngine
    from repro.launch.mesh import make_serving_mesh

    pidx, heavy = make_skewed_store(lane=lane)
    rng = np.random.default_rng(5)
    s = rng.integers(0, pidx.num_nodes, flush).astype(np.int32)
    t = rng.integers(0, pidx.num_nodes, flush).astype(np.int32)
    wl = rng.integers(0, pidx.num_levels + 1, flush).astype(np.int32)
    n_salt = min(64, flush // 4)
    s[:n_salt] = np.resize(heavy, n_salt)
    t[n_salt // 2:n_salt + n_salt // 2] = np.resize(heavy, n_salt)
    mesh = make_serving_mesh()
    ragged = ShardedQueryEngine(pidx, mesh=mesh, layout="csr", lane=lane,
                                device_budget_bytes=1, dispatch="ragged")
    bp = ShardedQueryEngine(pidx, mesh=mesh, layout="csr", lane=lane,
                            device_budget_bytes=1, dispatch="bucket_pair")
    assert ragged.mode == bp.mode == "sharded_labels"
    out_r = np.asarray(ragged.query(s, t, wl))              # warmup compiles
    out_b = np.asarray(bp.query(s, t, wl))
    assert np.array_equal(out_r, out_b), \
        "row-sharded ragged diverged from the bucket-pair oracle"
    t_rag, _ = _time(lambda: np.asarray(ragged.query(s, t, wl)), repeat=5)
    t_bp, _ = _time(lambda: np.asarray(bp.query(s, t, wl)), repeat=5)
    packed = pidx.packed(lane=lane)
    ar_bytes = packed.arena(lane=lane).memory_bytes()
    comp = packed.compressed_arena(lane=lane)
    name = f"SKEW{pidx.labels.num_buckets}"
    return [
        dict(table="serving", dataset=name,
             algo="rowsharded_ragged_us_per_query",
             value=t_rag / len(s) * 1e6),
        dict(table="serving", dataset=name,
             algo="rowsharded_bucket_pair_us_per_query",
             value=t_bp / len(s) * 1e6),
        dict(table="serving", dataset=name, algo="rowsharded_ragged_speedup",
             value=t_bp / t_rag),
        dict(table="serving", dataset=name, algo="compressed_bytes_ratio",
             value=ar_bytes / comp.memory_bytes()),
    ]


def _bench_profile_vs_loop(idx, s, t, name):
    """Profile staircases one-pass vs the per-level query loop, on the CSR
    engine (the layout the one-pass kernel exists for)."""
    eng = DeviceQueryEngine(idx, layout="csr")
    n_levels = idx.num_levels + 1        # staircase covers 0..W inclusive

    def loop_all_levels():
        return np.stack(
            [np.asarray(eng.query(s, t, np.full(len(s), w, np.int32)))
             for w in range(n_levels)], axis=1)

    np.asarray(eng.query_profile(s, t))              # warmup compiles
    loop_all_levels()                                # (full batch shapes)
    t_prof, prof = _time(lambda: np.asarray(eng.query_profile(s, t)),
                         repeat=3)
    t_loop, loop = _time(loop_all_levels, repeat=3)
    assert np.array_equal(prof, loop), \
        "profile diverged from the per-level query loop"
    return [
        dict(table="serving", dataset=name, algo="profile_levels",
             value=n_levels),
        dict(table="serving", dataset=name, algo="profile_us_per_query",
             value=t_prof / len(s) * 1e6),
        dict(table="serving", dataset=name, algo="profile_loop_us_per_query",
             value=t_loop / len(s) * 1e6),
        dict(table="serving", dataset=name, algo="profile_speedup",
             value=t_loop / t_prof),
    ]

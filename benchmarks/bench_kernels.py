"""Kernel-level benchmarks: the Pallas wcsd_query kernel vs the XLA
fallback. On this CPU container wall-clock is not TPU-meaningful, so the
headline metric is the compiled *bytes-accessed* ratio (the kernel's tiled
VMEM reduction never materializes the [B, L, L] join that XLA's fallback
writes to HBM), plus CPU wall time of the jnp path for scale."""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.generators import random_queries, scale_free
from repro.core.query import DeviceQueryEngine, query_batch_jnp
from repro.core.wc_index import build_wc_index


def bench_query_kernel(B=1024, L=256):
    rows = []
    rng = np.random.default_rng(0)
    hub = np.sort(rng.integers(0, 500, size=(600, L)).astype(np.int32), 1)
    dist = rng.integers(0, 64, size=(600, L)).astype(np.int32)
    wlev = rng.integers(0, 6, size=(600, L)).astype(np.int32)
    count = rng.integers(L // 2, L, size=600).astype(np.int32)
    s = rng.integers(0, 600, B).astype(np.int32)
    t = rng.integers(0, 600, B).astype(np.int32)
    w = rng.integers(0, 6, B).astype(np.int32)
    args = tuple(jnp.asarray(a) for a in (hub, dist, wlev, count, s, t, w))

    compiled = jax.jit(query_batch_jnp).lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jax: one dict per device
        ca = ca[0] if ca else {}
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    # the kernel's HBM traffic: gathered rows in + [B] out (everything else
    # stays in VMEM tiles)
    kernel_bytes = 4.0 * (4 * B * L + B)  # hs/ds/ht/dt + out, int32
    rows.append(dict(table="kernel_wcsd_query", dataset=f"B{B}xL{L}",
                     algo="xla_bytes_accessed", value=xla_bytes))
    rows.append(dict(table="kernel_wcsd_query", dataset=f"B{B}xL{L}",
                     algo="kernel_hbm_bytes", value=kernel_bytes))
    rows.append(dict(table="kernel_wcsd_query", dataset=f"B{B}xL{L}",
                     algo="traffic_ratio", value=xla_bytes / kernel_bytes))

    # CPU wall time of the jnp path (scale reference only)
    f = jax.jit(query_batch_jnp)
    np.asarray(f(*args))
    t0 = time.perf_counter()
    for _ in range(3):
        np.asarray(f(*args))
    rows.append(dict(table="kernel_wcsd_query", dataset=f"B{B}xL{L}",
                     algo="jnp_us_per_query",
                     value=(time.perf_counter() - t0) / 3 / B * 1e6))
    return rows


def bench_segmented_kernel(B=2048, V=4000, seed=0):
    """Segmented (CSR bucket-pair) kernel vs the dense gathered kernel on a
    skewed label-length distribution: HBM traffic and compare volume.

    The dense path pads every label row to the global max width; the
    segmented path routes each query to tiles shaped for its own endpoints
    and gathers rows in-kernel (scalar prefetch), so neither the [B, L]
    gathered copies nor the wide pads ever hit HBM."""
    from repro.core.generators import random_queries, scale_free
    from repro.core.query import DeviceQueryEngine, plan_query_batch
    from repro.core.wc_index import build_wc_index, round_to_lane

    rows = []
    g = scale_free(V, 4, num_levels=9, seed=seed)
    idx = build_wc_index(g, ordering="degree")
    packed = idx.packed()
    s, t, w = random_queries(g, B, seed=seed + 1)
    cap128 = round_to_lane(int(idx.count.max()))
    widths = packed.bucket_widths.astype(np.int64)
    plan = plan_query_batch(packed.bucket_of, s, t)

    # dense gathered kernel: 4 arrays (hs/ds/ht/dt) of [B, cap128] in + [B]
    dense_bytes = 4.0 * (4 * B * cap128 + B)
    dense_cmp = float(B) * cap128 * cap128
    # segmented kernel: per query 3 int32 rows per side at bucket width
    seg_bytes = sum(4.0 * len(p.positions) *
                    (3 * (int(widths[p.bucket_s]) + int(widths[p.bucket_t])) + 1)
                    for p in plan)
    seg_cmp = float(sum(len(p.positions) *
                        int(widths[p.bucket_s] * widths[p.bucket_t])
                        for p in plan))
    name = f"B{B}xV{V}"
    rows += [
        dict(table="kernel_segmented", dataset=name, algo="dense_hbm_bytes",
             value=dense_bytes),
        dict(table="kernel_segmented", dataset=name, algo="seg_hbm_bytes",
             value=seg_bytes),
        dict(table="kernel_segmented", dataset=name, algo="hbm_ratio",
             value=dense_bytes / seg_bytes),
        dict(table="kernel_segmented", dataset=name, algo="dense_cmp_volume",
             value=dense_cmp),
        dict(table="kernel_segmented", dataset=name, algo="seg_cmp_volume",
             value=seg_cmp),
        dict(table="kernel_segmented", dataset=name, algo="cmp_ratio",
             value=dense_cmp / seg_cmp),
        dict(table="kernel_segmented", dataset=name, algo="sub_batches",
             value=len(plan)),
    ]
    # CPU wall time of the XLA fallbacks (scale reference only; pinned to
    # the bucket-pair dispatch this suite's traffic model describes — the
    # ragged-vs-bucket-pair comparison lives in bench_wcsd.bench_serving)
    dense = DeviceQueryEngine(idx)
    seg = DeviceQueryEngine(idx, layout="csr", dispatch="bucket_pair")
    np.asarray(dense.query(s, t, w)); np.asarray(seg.query(s, t, w))
    for algo, eng in [("dense_us_per_query", dense),
                      ("seg_us_per_query", seg)]:
        t0 = time.perf_counter()
        for _ in range(3):
            np.asarray(eng.query(s, t, w))
        rows.append(dict(table="kernel_segmented", dataset=name, algo=algo,
                         value=(time.perf_counter() - t0) / 3 / B * 1e6))
    return rows


def bench_cin_traffic(B=4096, H=200, M=39, D=10, K=200):
    """CIN fused kernel vs naive einsum: intermediate footprint."""
    rows = []
    naive_bytes = 4.0 * B * H * M * D          # the [B,H,M,D] outer product
    fused_bytes = 4.0 * (B * H * D + B * M * D + K * H * M + B * K * D)
    rows.append(dict(table="kernel_cin", dataset=f"B{B}", algo="naive_bytes",
                     value=naive_bytes))
    rows.append(dict(table="kernel_cin", dataset=f"B{B}", algo="fused_bytes",
                     value=fused_bytes))
    rows.append(dict(table="kernel_cin", dataset=f"B{B}", algo="ratio",
                     value=naive_bytes / fused_bytes))
    return rows

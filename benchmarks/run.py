"""Benchmark harness: one function per paper table (Figs. 5-12) plus the
beyond-paper builder/kernel/serving benches. Prints ``table,dataset,algo,
value`` CSV; ``--json PATH`` additionally writes the machine-readable
``{suite: [rows]}`` mapping consumed by the CI perf-trajectory artifacts
(`BENCH_*.json`). ``--quick`` trims dataset sizes for CI; ``--only`` takes
a comma-separated suite list; ``--check`` gates the run against the
COMMITTED baselines at the repo root (fails on > 1.3x regression of any
tracked metric — see CHECK_GATES), seeding the perf trajectory the CI
artifacts extend."""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# ---------------------------------------------------------------- schema
# The --json artifacts (BENCH_serving.json / BENCH_kernels.json) are CI's
# perf trajectory; this schema gate keeps them from silently drifting —
# a suite that stops emitting a tracked metric fails the run instead of
# producing a quietly thinner artifact (tests/test_bench_schema.py holds
# the same gate against a tiny in-process run).
ROW_KEYS = ("table", "dataset", "algo", "value")

# per-suite metrics that must be present in every artifact (subset — new
# rows may always be added; removing one of these is a schema break)
REQUIRED_ALGOS = {
    "serving": {"qps", "qps_sharded", "us_per_query", "us_per_query_sharded",
                "sharded_speedup", "profile_levels", "profile_us_per_query",
                "profile_loop_us_per_query", "profile_speedup",
                "ragged_buckets", "ragged_us_per_query",
                "bucket_pair_us_per_query", "ragged_speedup",
                "rowsharded_ragged_us_per_query",
                "rowsharded_bucket_pair_us_per_query",
                "rowsharded_ragged_speedup", "compressed_bytes_ratio",
                "update_apply_us", "compact_us", "delta_query_overhead",
                "serve_p50_us", "serve_p99_us", "dma_overlap_speedup",
                "degraded_mode_overhead", "wal_append_us"},
    "label_store": {"entries", "padded_bytes", "csr_bytes",
                    "dense_us_per_query", "seg_us_per_query"},
}

# ------------------------------------------------------- regression gates
# ``--check`` re-runs the suites and compares these metrics against the
# COMMITTED baselines at the repo root (BENCH_serving.json /
# BENCH_kernels.json): a tracked metric that got > CHECK_TOLERANCE x worse
# than its committed value fails the run.
CHECK_TOLERANCE = float(os.environ.get("REPRO_BENCH_TOL", "1.3"))

# suite -> {algo: "lower" (smaller is better) | "higher"}. Only metrics
# whose value is comparable ACROSS MACHINES carry the relative gate: the
# kernel suites' analytic traffic/compare ratios are deterministic — any
# drift is a real code regression, never runner noise. Absolute
# wall-clock metrics (us_per_query et al.) are archived in the artifacts
# but NOT relatively gated: the committed baseline and the CI runner are
# different machines, so a 1.3x wall-clock delta measures hardware, not
# code. Wall-clock trends are gated through the same-run speedup FLOORS
# below instead (both sides of a speedup share one process, so machine
# speed cancels).
CHECK_GATES = {
    "kernel_query": {"traffic_ratio": "higher"},
    "kernel_segmented": {"hbm_ratio": "higher", "cmp_ratio": "higher"},
    "kernel_cin": {"ratio": "higher"},
}

# absolute floors independent of the baseline (acceptance trends): the
# ragged megakernel must stay >= 2x over the bucket-pair dispatch loop on
# the >= 8-bucket skewed store (observed 5.8-11.6x), including with the
# store row-sharded (one tile gather + one launch per device vs the
# per-bucket-pair collective loop), and the compressed arena must keep
# >= 1.8x the rows per byte of the uncompressed one (observed ~2.35x).
# dma_overlap_speedup (quad-buffered tile-DMA ring vs the nbuf=1
# single-buffer baseline, same worklist, same run) is a real overlap
# ratio only on TPU; under CI's interpret emulation the copies are
# synchronous either way (observed ~0.7-1.1x with interpret-loop timing
# noise), so its floor of 0.5 only guards the ring against ADDING
# overhead — a 2x collapse, not jitter.
CHECK_FLOORS = {
    "serving": {"ragged_speedup": 2.0, "ragged_buckets": 8.0,
                "rowsharded_ragged_speedup": 2.0,
                "compressed_bytes_ratio": 1.8,
                "dma_overlap_speedup": 0.5},
}

# absolute ceilings, the floors' smaller-is-better mirror: serving
# through a NON-EMPTY delta-extended arena must stay within 1.15x of the
# static ragged path (observed ~1.0x: the delta only redirects tile
# pointers inside the one launch per flush). Like the floors, ceilings
# are same-run ratios, so machine speed cancels — with one exception:
# serve_p99_us is an absolute wall-clock SLO guard on the continuous-
# batching epoch (enqueue->deliver p99). It is deliberately slack (CI
# observes low single-digit ms, but one interpret-mode compile of an
# unseen padded batch shape landing in-band costs ~300ms) because
# runner speed varies; what it catches is pathological serialization —
# a flush re-running the whole backlog, a deadline that never fires, a
# request parked until epoch end — which shows up as many seconds, not
# percent. The resilience rows (docs/resilience.md §benchmarks) ride the
# same logic: degraded_mode_overhead is a same-run ratio (bucket_pair
# fallback rung vs csr-ragged primary on identical-size flushes —
# observed ~1-3x on CI's interpret path; the ceiling of 100x catches a
# fallback rung that silently became an effective outage, not dispatch
# jitter), and wal_append_us is an absolute wall-clock guard on the
# fsync'd per-batch WAL append (observed ~100us-2ms depending on the
# runner's disk; the 50ms ceiling catches a WAL that serializes update
# ingestion, e.g. an accidental rewrite-the-log-per-append).
CHECK_CEILINGS = {
    "serving": {"delta_query_overhead": 1.15,
                "serve_p99_us": 1_000_000.0,
                "degraded_mode_overhead": 100.0,
                "wal_append_us": 50_000.0},
}

# which committed artifact holds each suite's baseline rows
BASELINE_FILES = {
    "serving": "BENCH_serving.json",
    "kernel_query": "BENCH_kernels.json",
    "kernel_segmented": "BENCH_kernels.json",
    "kernel_cin": "BENCH_kernels.json",
}


def check_against_baseline(suite: str, rows, base_rows,
                           tol: float = None) -> list[str]:
    """Failure strings for every gated metric of ``suite`` that regressed
    by more than ``tol`` x vs the baseline rows, or fell under its
    absolute floor. Metrics present only in the fresh run (new rows) are
    ignored; a gated BASELINE metric missing from the fresh run is itself
    a failure (the artifact thinned out)."""
    tol = CHECK_TOLERANCE if tol is None else tol
    gates = CHECK_GATES.get(suite, {})
    fresh = {(r["table"], r["dataset"], r["algo"]): r["value"] for r in rows}
    failures = []
    for r in base_rows:
        key = (r["table"], r["dataset"], r["algo"])
        direction = gates.get(key[2])
        if direction is None:
            continue
        new = fresh.get(key)
        if new is None:
            failures.append(f"{suite} {key}: gated metric missing from "
                            "fresh run")
            continue
        old = r["value"]
        if old <= 0 or new <= 0:
            continue
        worse = (new / old) if direction == "lower" else (old / new)
        if worse > tol:
            failures.append(
                f"{suite} {key}: {worse:.2f}x worse than baseline "
                f"({old:.6g} -> {new:.6g}, tolerance {tol}x)")
    for algo, floor in CHECK_FLOORS.get(suite, {}).items():
        vals = [v for k, v in fresh.items() if k[2] == algo]
        for v in vals:
            if v < floor:
                failures.append(f"{suite} {algo}: {v:.6g} under the "
                                f"absolute floor {floor}")
    for algo, ceiling in CHECK_CEILINGS.get(suite, {}).items():
        vals = [v for k, v in fresh.items() if k[2] == algo]
        for v in vals:
            if v > ceiling:
                failures.append(f"{suite} {algo}: {v:.6g} over the "
                                f"absolute ceiling {ceiling}")
    return failures


def validate_rows(suite: str, rows) -> None:
    """Raise ValueError unless ``rows`` conforms to the artifact schema:
    a non-empty list of {table, dataset, algo, value} with string labels
    and real-number values, carrying every required metric of ``suite``."""
    if not isinstance(rows, list) or not rows:
        raise ValueError(f"suite {suite!r}: expected a non-empty row list, "
                         f"got {type(rows).__name__}")
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            raise ValueError(f"suite {suite!r} row {i}: not a dict")
        missing = [k for k in ROW_KEYS if k not in row]
        if missing:
            raise ValueError(f"suite {suite!r} row {i}: missing {missing}")
        for k in ("table", "dataset", "algo"):
            if not isinstance(row[k], str) or not row[k]:
                raise ValueError(f"suite {suite!r} row {i}: {k!r} must be a "
                                 f"non-empty string, got {row[k]!r}")
        if isinstance(row["value"], bool) or \
                not isinstance(row["value"], (int, float)):
            raise ValueError(f"suite {suite!r} row {i}: value must be a "
                             f"number, got {row['value']!r}")
    have = {r["algo"] for r in rows}
    lost = REQUIRED_ALGOS.get(suite, set()) - have
    if lost:
        raise ValueError(f"suite {suite!r} artifact dropped tracked "
                         f"metrics: {sorted(lost)}")


def _serving_in_subprocess(args) -> list:
    """Run the serving suite in a child process so its virtual-device
    topology (`xla_force_host_platform_device_count`) cannot leak into the
    other suites' measurements — jax locks the device count at first
    initialization, so one process cannot serve both."""
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        path = f.name
    cmd = [sys.executable, "-m", "benchmarks.run", "--only", "serving",
           "--json", path, "--host-devices", str(args.host_devices)]
    if args.quick:
        cmd.append("--quick")
    r = subprocess.run(cmd, capture_output=True, text=True, cwd=REPO_ROOT,
                       env={**os.environ})
    if r.returncode != 0:
        raise RuntimeError(f"serving sub-bench failed:\n{r.stdout}\n"
                           f"{r.stderr}")
    with open(path) as f:
        rows = json.load(f)["serving"]
    os.unlink(path)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", help="comma-separated suite names")
    ap.add_argument("--json", dest="json_path", metavar="PATH",
                    help="write {suite: [rows]} JSON next to the CSV")
    ap.add_argument("--host-devices", type=int, default=8,
                    help="virtual host devices for the sharded serving "
                         "bench (must be set before jax initializes)")
    ap.add_argument("--check", action="store_true",
                    help="compare the run against the committed perf "
                         "baselines (BENCH_serving.json / "
                         "BENCH_kernels.json at the repo root) and fail "
                         f"on a > {CHECK_TOLERANCE}x regression of any "
                         "gated metric. Baselines are read BEFORE the run "
                         "writes --json, so the same paths may be reused.")
    args = ap.parse_args()

    baselines = {}
    if args.check:
        # read the committed baselines up front: --json may legitimately
        # point at the same files this run regenerates
        for fname in set(BASELINE_FILES.values()):
            path = os.path.join(REPO_ROOT, fname)
            if os.path.exists(path):
                with open(path) as f:
                    baselines[fname] = json.load(f)

    only = set(args.only.split(",")) if args.only else None
    # the serving suite compares the sharded engine against single-device
    # on a multi-device topology. When it is the ONLY suite, fix the
    # virtual device count in-process (appending — never clobbering — any
    # pre-existing XLA_FLAGS) BEFORE anything imports jax; when it runs
    # alongside other suites it goes to a subprocess instead, so every
    # other row keeps the default topology.
    serving_in_proc = only == {"serving"}
    if serving_in_proc and args.host_devices > 1:
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.host_devices}").strip()

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from benchmarks import bench_indexing, bench_kernels, bench_wcsd

    suites = {
        "indexing": lambda: bench_wcsd.bench_indexing(
            datasets={"NY(s)": ("road", "NY(s)"),
                      "MV(s)": ("social", "MV(s)")} if args.quick else None),
        "query": lambda: bench_wcsd.bench_query(
            n_queries=100 if args.quick else 400),
        "large_w": lambda: bench_wcsd.bench_large_w(
            n_levels=8 if args.quick else 20),
        "batched": bench_wcsd.bench_batched_builder,
        "index_build": lambda: bench_indexing.bench_build_paths(
            configs=bench_indexing.QUICK_CONFIGS if args.quick else None),
        "serving": (lambda: bench_wcsd.bench_serving(
            batch=1024 if args.quick else 4096)) if serving_in_proc
        else lambda: _serving_in_subprocess(args),
        "label_store": lambda: bench_wcsd.bench_label_store(
            dataset="MV(s)" if args.quick else "SO(s)",
            n_queries=256 if args.quick else 2048),
        "kernel_query": bench_kernels.bench_query_kernel,
        "kernel_segmented": lambda: bench_kernels.bench_segmented_kernel(
            B=256 if args.quick else 2048, V=800 if args.quick else 4000),
        "kernel_cin": bench_kernels.bench_cin_traffic,
    }
    if only:
        unknown = only - suites.keys()
        if unknown:
            raise SystemExit(f"unknown suites: {sorted(unknown)}; "
                             f"available: {sorted(suites)}")
        suites = {k: v for k, v in suites.items() if k in only}
    results: dict[str, list] = {}
    print("table,dataset,algo,value")
    for name, fn in suites.items():
        rows = fn()
        validate_rows(name, rows)
        results[name] = rows
        for row in rows:
            print(f"{row['table']},{row['dataset']},{row['algo']},"
                  f"{row['value']:.6g}", flush=True)
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(results, f, indent=1)
        print(f"# wrote {args.json_path} ({sum(map(len, results.values()))} "
              f"rows, {len(results)} suites)", file=sys.stderr)
    if args.check:
        failures = []
        checked = 0
        for suite, rows in results.items():
            fname = BASELINE_FILES.get(suite)
            if fname is None:
                continue
            base = baselines.get(fname, {}).get(suite)
            if base is None and CHECK_GATES.get(suite):
                # a gated suite without committed baseline rows must not
                # silently pass — the gate would rot open
                failures.append(f"{suite}: no committed baseline rows in "
                                f"{fname}; seed them with --json {fname}")
            checked += 1
            # floors are baseline-independent: they apply to the fresh
            # rows even when no baseline exists yet
            failures += check_against_baseline(suite, rows, base or [])
        print(f"# --check: {checked} suites vs committed baselines, "
              f"{len(failures)} regressions", file=sys.stderr)
        if failures:
            for f_ in failures:
                print(f"REGRESSION: {f_}", file=sys.stderr)
            raise SystemExit(1)


if __name__ == "__main__":
    main()

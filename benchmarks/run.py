"""Benchmark harness: one function per paper table (Figs. 5-12) plus the
beyond-paper builder/kernel/serving benches. Prints ``table,dataset,algo,
value`` CSV. ``--quick`` trims dataset sizes for CI."""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import bench_indexing, bench_kernels, bench_wcsd  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only")
    args = ap.parse_args()

    suites = {
        "indexing": lambda: bench_wcsd.bench_indexing(
            datasets={"NY(s)": ("road", "NY(s)"),
                      "MV(s)": ("social", "MV(s)")} if args.quick else None),
        "query": lambda: bench_wcsd.bench_query(
            n_queries=100 if args.quick else 400),
        "large_w": lambda: bench_wcsd.bench_large_w(
            n_levels=8 if args.quick else 20),
        "batched": bench_wcsd.bench_batched_builder,
        "index_build": lambda: bench_indexing.bench_build_paths(
            configs=bench_indexing.QUICK_CONFIGS if args.quick else None),
        "serving": bench_wcsd.bench_serving,
        "label_store": lambda: bench_wcsd.bench_label_store(
            dataset="MV(s)" if args.quick else "SO(s)",
            n_queries=256 if args.quick else 2048),
        "kernel_query": bench_kernels.bench_query_kernel,
        "kernel_segmented": lambda: bench_kernels.bench_segmented_kernel(
            B=256 if args.quick else 2048, V=800 if args.quick else 4000),
        "kernel_cin": bench_kernels.bench_cin_traffic,
    }
    if args.only:
        suites = {k: v for k, v in suites.items() if k == args.only}
    print("table,dataset,algo,value")
    for name, fn in suites.items():
        for row in fn():
            print(f"{row['table']},{row['dataset']},{row['algo']},"
                  f"{row['value']:.6g}", flush=True)


if __name__ == "__main__":
    main()

"""Old-vs-new rank-batched construction: the host-roundtrip builder
(padded labels, pack after build) against the device-resident pipeline
(Pallas round kernels, on-device F/R/T/E state, direct CSR emission).

Reports, per graph config:
  - build wall-clock for both paths (the old path includes the `.packed()`
    repack it forces on serving);
  - host sync counts: device->host ARRAY transfers (the old path downloads
    a [B, V] emission mask every round; the new path downloads one
    [B, V, W+1] table per batch) and scalar termination checks (identical
    by construction — same number of rounds);
  - store equality: the direct-CSR store must match pack-after-build on
    every array (1.0 == identical).

On CPU the Pallas kernels run in interpret mode, so the new path's
wall-clock carries emulation overhead and the sync counts are the
hardware-relevant comparison: on a real accelerator each array sync is a
device round-trip stall, and the old path pays one per BFS round.

CSV rows `table,dataset,algo,value` like the other benches.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.generators import erdos_renyi, road_grid, scale_free
from repro.core.wc_index_batched import (build_wc_index_batched,
                                         build_wc_index_batched_packed)

CONFIGS = {
    "GRID(s)": lambda: road_grid(16, 16, num_levels=5, seed=42),
    "ER(s)": lambda: erdos_renyi(320, 4.0, num_levels=5, seed=42),
    "BA(s)": lambda: scale_free(320, 3, num_levels=4, seed=42),
}
QUICK_CONFIGS = {
    "GRID(s)": lambda: road_grid(10, 10, num_levels=4, seed=42),
    "BA(s)": lambda: scale_free(150, 3, num_levels=4, seed=42),
}

_STORE_FIELDS = ("hub_rank", "dist", "wlev", "offsets", "bucket_widths",
                 "bucket_of", "slot_of")


def bench_build_paths(configs=None, batch_size=32):
    rows = []
    for name, make in (configs or CONFIGS).items():
        g = make()

        t0 = time.perf_counter()
        old, so = build_wc_index_batched(g, batch_size=batch_size)
        packed_old = old.packed()      # the repack serving had to pay
        t_old = time.perf_counter() - t0

        t0 = time.perf_counter()
        new, sn = build_wc_index_batched_packed(g, batch_size=batch_size)
        t_new = time.perf_counter() - t0

        identical = all(np.array_equal(getattr(packed_old, f),
                                       getattr(new.labels, f))
                        for f in _STORE_FIELDS)
        rows += [
            dict(table="idxbuild_wall_s", dataset=name,
                 algo="host-roundtrip+pack", value=t_old),
            dict(table="idxbuild_wall_s", dataset=name,
                 algo="device-resident-csr", value=t_new),
            dict(table="idxbuild_host_array_syncs", dataset=name,
                 algo="host-roundtrip+pack", value=so["host_array_syncs"]),
            dict(table="idxbuild_host_array_syncs", dataset=name,
                 algo="device-resident-csr", value=sn["host_array_syncs"]),
            dict(table="idxbuild_host_scalar_syncs", dataset=name,
                 algo="host-roundtrip+pack", value=so["host_scalar_syncs"]),
            dict(table="idxbuild_host_scalar_syncs", dataset=name,
                 algo="device-resident-csr", value=sn["host_scalar_syncs"]),
            dict(table="idxbuild_store_identical", dataset=name,
                 algo="csr-vs-pack", value=float(identical)),
            dict(table="idxbuild_entries", dataset=name,
                 algo="device-resident-csr", value=new.size_entries()),
        ]
    return rows


if __name__ == "__main__":
    print("table,dataset,algo,value")
    for row in bench_build_paths():
        print(f"{row['table']},{row['dataset']},{row['algo']},"
              f"{row['value']:.6g}", flush=True)
